"""Index substrate: residual codec, k-means, IVF, SPLADE postings,
PagedStore mmap/ram equivalence + page accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.index import residual
from repro.index.ivf import build_ivf
from repro.index.kmeans import assign, train_kmeans
from repro.index.splade_index import (build_splade_index,
                                      splade_score_jax_padded)
from repro.core.store import PagedStore


# ---------------------------------------------------------------------------
# residual codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", [2, 4])
def test_codec_roundtrip_error_bounded(nbits, rng):
    dim, K, N = 32, 16, 500
    cent = rng.normal(size=(K, dim)).astype(np.float32)
    cent /= np.linalg.norm(cent, axis=-1, keepdims=True)
    embs = rng.normal(size=(N, dim)).astype(np.float32) * 0.3
    embs = cent[rng.integers(0, K, N)] + embs * 0.1
    cids, _ = assign(jnp.asarray(embs), jnp.asarray(cent))
    codec = residual.fit_codec(cent, embs, np.asarray(cids), nbits)
    packed = residual.encode_residuals(jnp.asarray(embs), cids,
                                       codec.centroids,
                                       codec.bucket_cutoffs, nbits)
    dec = residual.decode_embeddings(packed, cids, codec.centroids,
                                     codec.bucket_weights, nbits)
    res = embs - np.asarray(codec.centroids)[np.asarray(cids)]
    # max error bounded by the largest bucket width
    cuts = np.asarray(codec.bucket_cutoffs)
    spans = np.diff(np.concatenate([[res.min()], cuts, [res.max()]]))
    err = np.abs(np.asarray(dec) - embs)
    assert err.max() <= spans.max() + 1e-5
    # 4-bit must beat 2-bit on MSE
    if nbits == 4:
        assert err.mean() < 0.05


def test_codec_packing_is_lossless():
    nbits = 4
    codes = jnp.arange(16, dtype=jnp.uint8).reshape(1, 16)
    cpb = 8 // nbits
    grouped = codes.reshape(1, 16 // cpb, cpb)
    shifts = jnp.arange(cpb, dtype=jnp.uint8) * nbits
    packed = jnp.sum(grouped.astype(jnp.uint32) << shifts.astype(jnp.uint32),
                     axis=-1).astype(jnp.uint8)
    unpacked = residual.unpack_codes(packed, nbits)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(codes))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4]))
def test_unpack_inverts_pack(seed, nbits):
    rng = np.random.default_rng(seed)
    N, dim = 7, 16
    codes = rng.integers(0, 2 ** nbits, (N, dim)).astype(np.uint8)
    cpb = 8 // nbits
    grouped = codes.reshape(N, dim // cpb, cpb).astype(np.uint32)
    shifts = (np.arange(cpb) * nbits).astype(np.uint32)
    packed = np.sum(grouped << shifts, axis=-1).astype(np.uint8)
    out = np.asarray(residual.unpack_codes(jnp.asarray(packed), nbits))
    np.testing.assert_array_equal(out, codes)


def test_compression_ratio():
    # 128-dim fp32 = 512 B vs 4-bit codes (64 B) + 4 B cid = 68 B ≈ 7.5×
    assert 7 < residual.compression_ratio(128, 4) < 8


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------

def test_kmeans_recovers_separated_clusters(rng):
    centers = np.eye(8, dtype=np.float32)[:4]  # 4 orthogonal directions
    pts = np.repeat(centers, 100, axis=0)
    pts += rng.normal(size=pts.shape).astype(np.float32) * 0.05
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    cent = train_kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 4, 10)
    ids, sims = assign(jnp.asarray(pts), cent)
    # points from the same true cluster land in the same learned cluster
    ids = np.asarray(ids).reshape(4, 100)
    for row in ids:
        assert len(np.unique(row)) == 1
    assert float(jnp.mean(sims)) > 0.95


def test_assign_is_argmax(rng):
    pts = rng.normal(size=(50, 8)).astype(np.float32)
    cent = rng.normal(size=(6, 8)).astype(np.float32)
    ids, _ = assign(jnp.asarray(pts), jnp.asarray(cent))
    expected = np.argmax(pts @ cent.T, axis=-1)
    np.testing.assert_array_equal(np.asarray(ids), expected)


# ---------------------------------------------------------------------------
# IVF
# ---------------------------------------------------------------------------

def test_ivf_contains_exactly_token_centroid_pairs():
    cids = np.array([0, 0, 1, 2, 2, 2, 1])
    pids = np.array([0, 0, 0, 1, 1, 2, 2])
    ivf = build_ivf(cids, pids, 3)
    assert set(ivf.postings(0)) == {0}
    assert set(ivf.postings(1)) == {0, 2}
    assert set(ivf.postings(2)) == {1, 2}
    padded = ivf.as_padded(4)
    assert padded.shape == (3, 4)
    assert set(padded[1][padded[1] >= 0]) == {0, 2}


# ---------------------------------------------------------------------------
# SPLADE index
# ---------------------------------------------------------------------------

def test_splade_host_vs_jax_scoring(rng):
    n_docs, vocab, T = 200, 300, 12
    ids = rng.integers(0, vocab, (n_docs, T)).astype(np.int32)
    w = (rng.random((n_docs, T)) + 0.1).astype(np.float32)
    idx = build_splade_index(ids, w, vocab, n_docs)
    q_ids = rng.integers(0, vocab, 8).astype(np.int32)
    q_w = (rng.random(8) + 0.2).astype(np.float32)
    pids_h, scores_h = idx.score_host(q_ids, q_w, k=20)
    padded_p, padded_i = idx.as_padded(idx.term_offsets.max() + 1
                                       if len(idx.pids) else 1)
    max_df = int(np.diff(idx.term_offsets).max())
    padded_p, padded_i = idx.as_padded(max_df)
    pids_j, scores_j = splade_score_jax_padded(
        jnp.asarray(padded_p), jnp.asarray(padded_i), idx.quantum,
        n_docs, jnp.asarray(q_ids), jnp.asarray(q_w), 20)
    np.testing.assert_allclose(np.sort(scores_h)[::-1],
                               np.sort(np.asarray(scores_j))[::-1],
                               rtol=1e-4, atol=1e-4)


def test_splade_quantisation_error_small(rng):
    n_docs, vocab, T = 100, 200, 8
    ids = rng.integers(0, vocab, (n_docs, T)).astype(np.int32)
    w = (rng.random((n_docs, T)) + 0.1).astype(np.float32)
    idx = build_splade_index(ids, w, vocab, n_docs)
    # reconstruct each document's term weights from the postings
    recon = np.zeros((n_docs, vocab), np.float32)
    for t in range(vocab):
        s, e = idx.term_offsets[t], idx.term_offsets[t + 1]
        np.add.at(recon[:, t], idx.pids[s:e],
                  idx.impacts[s:e].astype(np.float32) * idx.quantum)
    dense = np.zeros_like(recon)
    for d in range(n_docs):
        np.add.at(dense[d], ids[d], w[d])
    assert np.abs(recon - dense).max() <= idx.quantum


# ---------------------------------------------------------------------------
# PagedStore
# ---------------------------------------------------------------------------

def test_store_mmap_equals_ram(tmp_path, rng):
    n, pd = 300, 16
    res = rng.integers(0, 256, (n, pd)).astype(np.uint8)
    codes = rng.integers(0, 64, n).astype(np.int32)
    PagedStore.write(tmp_path, codes, res, dim=32, nbits=4)
    ram = PagedStore(tmp_path, mode="ram")
    mm = PagedStore(tmp_path, mode="mmap")
    ids = rng.integers(0, n, 40)
    c1, r1 = ram.gather_tokens(ids)
    c2, r2 = mm.gather_tokens(ids)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(r1, r2)


def test_store_page_accounting(tmp_path, rng):
    n, pd = 4096, 64   # row = 64 B → 64 rows per 4 KiB page
    res = rng.integers(0, 256, (n, pd)).astype(np.uint8)
    codes = np.zeros(n, np.int32)
    PagedStore.write(tmp_path, codes, res, dim=128, nbits=4)
    st_ = PagedStore(tmp_path, mode="mmap")
    st_.stats.reset()
    st_.gather_tokens(np.arange(64))          # exactly one page
    assert st_.stats.pages_touched == 1
    st_.gather_tokens(np.arange(64))          # same page again
    assert len(st_.stats.unique_pages) == 1
    st_.gather_tokens(np.array([0, 64, 128]))  # three pages (one seen)
    assert len(st_.stats.unique_pages) == 3
    assert 0 < st_.resident_fraction_estimate() < 1
