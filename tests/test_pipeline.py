"""Stage-graph pipeline executor: plan compilation, depth>1 parity with
the synchronous path (all four methods + mixed batches), backpressure,
instrumentation (merged stage stats + AccessStats, overlap fraction),
and clean shutdown."""

import threading
import time

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.store import AccessStats
from repro.index.builder import ColBERTIndex
from repro.index.splade_index import build_splade_index
from repro.serving.engine import Request, ServeEngine
from repro.serving.pipeline import (
    DEVICE,
    HOST,
    CandidateBatch,
    PipelineExecutor,
    PipelineStopped,
    Stage,
    StagePlan,
)
from repro.serving.server import RetrievalServer

METHODS = ("colbert", "splade", "rerank", "hybrid")


@pytest.fixture(scope="module")
def stack(built_index, small_corpus):
    index = ColBERTIndex(built_index, mode="mmap")
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                                ndocs=128, k=50))
    sidx = build_splade_index(small_corpus["doc_term_ids"],
                              small_corpus["doc_term_weights"],
                              small_corpus["cfg"].vocab,
                              small_corpus["cfg"].n_docs)
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=50, k=20))
    return index, searcher, retr


def _requests(small_corpus, n, k=10, methods=METHODS):
    return [Request(qid=i, method=methods[i % len(methods)],
                    q_emb=small_corpus["q_embs"][i],
                    term_ids=small_corpus["q_term_ids"][i],
                    term_weights=small_corpus["q_term_weights"][i], k=k)
            for i in range(n)]


# ---------------------------------------------------------------------------
# plan compilation
# ---------------------------------------------------------------------------

def test_plans_use_typed_stage_vocabulary(stack):
    _, _, retr = stack
    expect = {
        "colbert": ("plaid_probe", "host_gather:codes",
                    "device_score:approx", "host_gather:residuals",
                    "fused_rerank"),
        "splade": ("splade_stage1", "fuse_splade"),
        "rerank": ("splade_stage1", "host_gather:residuals",
                   "fused_rerank", "fused_rerank:sync"),
        "hybrid": ("splade_stage1", "host_gather:residuals",
                   "fused_rerank", "fused_rerank:sync"),
    }
    for method, names in expect.items():
        plan = retr.compile_plan(method)
        assert plan.stage_names() == names
        # mmap store: gathers are host-bound, scoring device-bound
        kinds = {s.name: s.kind for s in plan.stages}
        for name in names:
            if name.startswith("host_gather"):
                assert kinds[name] == HOST
            if name.startswith(("device_score", "plaid_probe",
                                "fused_rerank")):
                assert kinds[name] == DEVICE
    with pytest.raises(ValueError):
        retr.compile_plan("no-such-method")


def test_split_backend_keeps_legacy_stage_vocabulary(stack):
    _, _, retr = stack
    expect = {
        "colbert": ("plaid_probe", "host_gather:codes",
                    "device_score:approx", "host_gather:residuals",
                    "device_score:exact", "fuse_topk"),
        "rerank": ("splade_stage1", "host_gather:residuals",
                   "device_score:maxsim", "fuse_topk"),
        "hybrid": ("splade_stage1", "host_gather:residuals",
                   "device_score:maxsim", "fuse_topk"),
    }
    retr.set_rerank_backend("split")
    try:
        for method, names in expect.items():
            assert retr.compile_plan(method).stage_names() == names
        with pytest.raises(ValueError):
            retr.set_rerank_backend("no-such-backend")
    finally:
        retr.set_rerank_backend(retr.params.rerank_backend)


def test_plans_cached_per_method_and_backend(stack):
    _, _, retr = stack
    assert retr.compile_plan("hybrid") is retr.compile_plan("hybrid")
    assert retr.compile_plan("hybrid") is not retr.compile_plan("rerank")


# ---------------------------------------------------------------------------
# parity: pipelined execution == synchronous plan run == search_batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("depth,workers", [(2, "single"), (3, "single"),
                                           (2, "kind")])
def test_executor_parity_with_sync(stack, small_corpus, method, depth,
                                   workers):
    _, _, retr = stack
    B, n_batches = 4, 3
    plan = retr.compile_plan(method)

    def batch(bi):
        idx = [(bi * B + j) % 40 for j in range(B)]
        return retr.build_batch(
            method,
            q_embs=[small_corpus["q_embs"][i] for i in idx],
            term_ids=[small_corpus["q_term_ids"][i] for i in idx],
            term_weights=[small_corpus["q_term_weights"][i] for i in idx],
            alphas=retr._alpha_array(None, B), k=15)

    sync = [plan.run(batch(bi)) for bi in range(n_batches)]
    px = PipelineExecutor(plan, depth=depth, stats=retr.pipeline_stats,
                          workers=workers)
    try:
        futs = [px.submit(batch(bi)) for bi in range(n_batches)]
        piped = [f.result(timeout=120) for f in futs]
    finally:
        px.stop()
    for s, p in zip(sync, piped):
        np.testing.assert_array_equal(s.pids, p.pids)
        np.testing.assert_allclose(s.scores, p.scores, rtol=1e-5,
                                   atol=1e-5)


def test_server_pipelined_equals_sequential_mixed(stack, small_corpus):
    """Depth-2 pipelined serving of mixed-method micro-batches returns
    exactly what the synchronous server returns."""
    _, _, retr = stack
    n = 16
    seq_srv = RetrievalServer(ServeEngine(retr), n_threads=1)
    seq_srv.start()
    seq = [seq_srv.submit(r).result(timeout=60)
           for r in _requests(small_corpus, n)]
    seq_srv.stop()

    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2),
                          n_threads=1, max_batch=4, batch_timeout_ms=25)
    srv.start()
    futs = [srv.submit(r) for r in _requests(small_corpus, n)]
    piped = [f.result(timeout=60) for f in futs]
    assert srv.health()["served"] == n
    srv.stop()

    for r_seq, r_pipe in zip(seq, piped):
        assert r_seq.qid == r_pipe.qid
        np.testing.assert_array_equal(r_seq.pids, r_pipe.pids)
        np.testing.assert_allclose(r_seq.scores, r_pipe.scores,
                                   rtol=1e-4, atol=1e-4)


def test_pipelined_respects_per_request_k_and_alpha(stack, small_corpus):
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2),
                          n_threads=1, max_batch=4, batch_timeout_ms=25)
    srv.start()
    reqs = _requests(small_corpus, 4, methods=("hybrid",))
    for r, want in zip(reqs, (3, 10, 7, 1)):
        r.k = want
    reqs[1].alpha = 0.9
    futs = [srv.submit(r) for r in reqs]
    for r, fut in zip(reqs, futs):
        assert len(fut.result(timeout=60).pids) == r.k
    expect = retr.search("hybrid", q_emb=reqs[1].q_emb,
                         term_ids=reqs[1].term_ids,
                         term_weights=reqs[1].term_weights,
                         alpha=0.9, k=10)[0]
    np.testing.assert_array_equal(futs[1].result().pids, expect)
    srv.stop()


def test_pipelined_isolates_poisoned_request(stack, small_corpus):
    """One bad request in a pipelined batch fails alone; co-batched
    neighbours are retried and still succeed."""
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2),
                          n_threads=1, max_batch=4, batch_timeout_ms=25)
    srv.start()
    reqs = _requests(small_corpus, 4)
    reqs[2].method = "no-such-method"
    futs = [srv.submit(r) for r in reqs]
    with pytest.raises(ValueError):
        futs[2].result(timeout=60)
    for i in (0, 1, 3):
        assert len(futs[i].result(timeout=60).pids) > 0
    srv.stop()


# ---------------------------------------------------------------------------
# backpressure + shutdown (on a synthetic plan so timing is controlled)
# ---------------------------------------------------------------------------

def _slow_plan(delay_a=0.0, delay_b=0.0):
    def a(cb):
        time.sleep(delay_a)
        return cb.with_state(a_done=True)

    def b(cb):
        time.sleep(delay_b)
        return cb.with_state(b_done=True)

    return StagePlan(method="slow", stages=(Stage("host_gather", HOST, a),
                                            Stage("device_score", DEVICE,
                                                  b)))


def _cb(i=0):
    return CandidateBatch(method="slow", k=1,
                          term_ids=(np.asarray([i]),))


def test_bounded_pipeline_backpressures_producer():
    """depth bounds the batches in flight: with depth=1 every submit
    after the first blocks until the previous batch clears the whole
    pipeline — producers are backpressured, memory stays bounded."""
    delay = 0.05
    px = PipelineExecutor(_slow_plan(delay_a=delay, delay_b=delay),
                          depth=1)
    try:
        t0 = time.perf_counter()
        futs = [px.submit(_cb(i)) for i in range(4)]
        submit_wall = time.perf_counter() - t0
        # submits 2..4 each wait one full pipeline traversal (2 stages)
        assert submit_wall >= 3 * 2 * delay * 0.8, submit_wall
        assert sum(px.queue_depths().values()) <= 1
        for f in futs:
            assert f.result(timeout=30).state["b_done"]
    finally:
        px.stop()


def test_depth2_overlaps_two_stage_plan_threaded():
    """Threaded (kind-worker) mode, depth=2: GIL-releasing stages of
    consecutive batches run concurrently, so total wall for N batches
    approaches N+1 stage-times instead of 2N (serial)."""
    delay = 0.05
    n = 6
    px = PipelineExecutor(_slow_plan(delay_a=delay, delay_b=delay),
                          depth=2, workers="kind")
    try:
        t0 = time.perf_counter()
        futs = [px.submit(_cb(i)) for i in range(n)]
        for f in futs:
            f.result(timeout=30)
        wall = time.perf_counter() - t0
    finally:
        px.stop()
    serial = 2 * n * delay
    assert wall < serial * 0.85, (wall, serial)


def test_single_worker_parks_at_sync_for_lookahead():
    """Software pipelining: with stages marked opens_async/closes_async,
    the single worker runs batch N+1's pre-sync stages before batch N's
    sync stage, hiding the async device execution behind host work."""
    order = []

    def dispatch(cb):
        order.append(("dispatch", int(cb.term_ids[0][0])))
        return cb

    def sync(cb):
        order.append(("sync", int(cb.term_ids[0][0])))
        return cb.evolve(pids=np.zeros((1, 1), np.int64))

    plan = StagePlan(method="x", stages=(
        Stage("host_gather", HOST, dispatch, opens_async=True),
        Stage("fuse_topk", HOST, sync, closes_async=True)))
    px = PipelineExecutor(plan, depth=2, workers="single")
    try:
        futs = [px.submit(_cb(i)) for i in range(3)]
        for f in futs:
            f.result(timeout=30)
    finally:
        px.stop()
    # batch 1's dispatch must precede batch 0's sync (lookahead), and
    # every batch still runs dispatch before its own sync
    assert order.index(("dispatch", 1)) < order.index(("sync", 0)), order
    for i in range(3):
        assert order.index(("dispatch", i)) < order.index(("sync", i))


def test_stop_resolves_or_fails_inflight():
    """stop() with batches queued and mid-stage: every future completes
    promptly — finished batches resolve, the rest fail PipelineStopped;
    nothing hangs."""
    px = PipelineExecutor(_slow_plan(delay_a=0.15), depth=2)
    futs = [px.submit(_cb(i)) for i in range(3)]
    time.sleep(0.05)                   # first batch is mid-stage
    t0 = time.perf_counter()
    px.stop()
    assert time.perf_counter() - t0 < 5.0
    states = []
    for f in futs:
        assert f.done()
        states.append("ok" if f.exception() is None else "stopped")
        if f.exception() is not None:
            assert isinstance(f.exception(), PipelineStopped)
    assert "stopped" in states        # at least the queued ones failed
    with pytest.raises(PipelineStopped):
        px.submit(_cb())


def test_stage_exception_fails_only_that_batch():
    def boom(cb):
        if int(cb.term_ids[0][0]) == 1:
            raise RuntimeError("injected")
        return cb.evolve(pids=np.zeros((1, 1), np.int64))

    plan = StagePlan(method="boom", stages=(Stage("fuse_topk", HOST,
                                                  boom),))
    px = PipelineExecutor(plan, depth=2)
    try:
        futs = [px.submit(_cb(i)) for i in range(3)]
        with pytest.raises(RuntimeError, match="injected"):
            futs[1].result(timeout=10)
        assert futs[0].result(timeout=10).pids is not None
        assert futs[2].result(timeout=10).pids is not None
    finally:
        px.stop()


def test_stop_with_parked_async_window_does_not_corrupt_overlap():
    """A batch killed between its opens_async and closes_async stages
    must close its async window, or every later (even strictly serial)
    run on the shared stats would read as ~100% overlapped."""
    from repro.serving.pipeline import PipelineStats

    stats = PipelineStats()

    def dispatch(cb):
        return cb

    def sync(cb):
        time.sleep(0.2)                 # keep batch 2 parked behind it
        return cb.evolve(pids=np.zeros((1, 1), np.int64))

    plan = StagePlan(method="x", stages=(
        Stage("host_gather", HOST, dispatch, opens_async=True),
        Stage("fuse_topk", HOST, sync, closes_async=True)))
    px = PipelineExecutor(plan, depth=2, stats=stats, workers="single")
    futs = [px.submit(_cb(i)) for i in range(2)]
    time.sleep(0.05)                    # batch 1 parked, window open
    px.stop()
    for f in futs:
        assert f.done()
    # a purely serial run afterwards must not read as overlapped
    stats.reset()
    plan.run(_cb(0), stats=stats)
    assert stats.snapshot()["overlap_fraction"] == 0.0


def test_server_restart_over_same_engine(stack, small_corpus):
    """stop() must not wedge the caller-owned engine: a restarted (or
    new) server over the same pipelined engine keeps serving."""
    _, _, retr = stack
    eng = ServeEngine(retr, pipeline_depth=2)
    srv = RetrievalServer(eng, n_threads=1, max_batch=4,
                          batch_timeout_ms=10)
    srv.start()
    assert len(srv.submit(_requests(small_corpus, 1)[0])
               .result(timeout=60).pids) > 0
    srv.stop()
    srv2 = RetrievalServer(eng, n_threads=1, max_batch=4,
                           batch_timeout_ms=10)
    srv2.start()
    futs = [srv2.submit(r) for r in _requests(small_corpus, 4)]
    for f in futs:
        assert len(f.result(timeout=60).pids) > 0
    srv2.stop()


def test_server_stop_with_pipeline_fails_unserved(stack, small_corpus):
    """Server stop() under pipelining: no client future is left
    pending."""
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2), n_threads=1)
    # never started: nothing drains the queue
    futs = [srv.submit(r) for r in _requests(small_corpus, 3)]
    srv.stop()
    for fut in futs:
        assert fut.done()
        with pytest.raises(RuntimeError, match="server stopped"):
            fut.result(timeout=1)


# ---------------------------------------------------------------------------
# instrumentation: merged per-stage record + overlap fraction
# ---------------------------------------------------------------------------

def test_stage_records_merge_access_stats(stack, small_corpus):
    """The per-stage record folds mmap page/token accounting into the
    same structure as wall time — and only gather stages touch pages."""
    index, _, retr = stack
    index.store.stats.reset()
    retr.reset_stage_stats()
    B = 4
    retr.search_batch(
        "hybrid", k=10,
        q_embs=[small_corpus["q_embs"][i] for i in range(B)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(B)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(B)])
    snap = retr.pipeline_stats.snapshot()
    gather = snap["stages"]["host_gather:residuals"]
    assert gather["pages_touched"] > 0
    assert gather["tokens_read"] > 0
    assert gather["dispatches"] == 1 and gather["queries"] == B
    assert snap["stages"]["fused_rerank"]["pages_touched"] == 0
    assert snap["stages"]["fused_rerank"]["device_dispatches"] == 1
    assert snap["stages"]["splade_stage1"]["dispatches"] == 1
    # synchronous run: no two stages ever execute concurrently
    assert snap["overlap_fraction"] == 0.0


def test_pipelined_overlap_fraction_positive(stack, small_corpus):
    """Depth-2 execution must actually overlap stages across
    micro-batches (the whole point of the pipeline)."""
    _, _, retr = stack
    plan = retr.compile_plan("hybrid")
    mk = lambda bi: retr.build_batch(
        "hybrid",
        q_embs=[small_corpus["q_embs"][(bi + j) % 40] for j in range(4)],
        term_ids=[small_corpus["q_term_ids"][(bi + j) % 40]
                  for j in range(4)],
        term_weights=[small_corpus["q_term_weights"][(bi + j) % 40]
                      for j in range(4)],
        alphas=retr._alpha_array(None, 4), k=10)
    plan.run(mk(0))                   # warm compiled shapes
    retr.reset_stage_stats()
    px = PipelineExecutor(plan, depth=2, stats=retr.pipeline_stats)
    try:
        futs = [px.submit(mk(bi)) for bi in range(8)]
        for f in futs:
            f.result(timeout=120)
    finally:
        px.stop()
    snap = retr.pipeline_stats.snapshot()
    assert 0.0 < snap["overlap_fraction"] <= 1.0
    assert snap["stages"]["splade_stage1"]["dispatches"] == 8


def test_health_reports_stage_queues_and_ewma(stack, small_corpus):
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2),
                          n_threads=1, max_batch=4, batch_timeout_ms=10)
    srv.start()
    for f in [srv.submit(r) for r in _requests(small_corpus, 8,
                                               methods=("hybrid",))]:
        f.result(timeout=60)
    h = srv.health()
    srv.stop()
    assert h["pipeline"]["depth"] == 2
    q = h["pipeline"]["queues"]["hybrid"]
    assert set(q) == {"splade_stage1", "host_gather:residuals",
                      "fused_rerank", "fused_rerank:sync"}
    assert all(depth >= 0 for depth in q.values())
    assert h["stages"]["splade_stage1"]["ewma_ms"] is not None
    # fused tail: one declared device launch per dispatch, none in the
    # sync stage, and no fuse_topk stage anywhere on the fused path
    st = h["stages"]
    assert st["fused_rerank"]["device_dispatches"] == \
        st["fused_rerank"]["dispatches"]
    assert st["fused_rerank:sync"]["device_dispatches"] == 0
    assert "fuse_topk" not in st
    assert "overlap_fraction" in h


# ---------------------------------------------------------------------------
# AccessStats thread safety
# ---------------------------------------------------------------------------

def test_access_stats_concurrent_account_and_snapshot():
    """Concurrent gather-stage accounting must not lose updates or
    corrupt the unique-page set while readers snapshot."""
    stats = AccessStats()
    stats.reset()
    N_THREADS, N_ITERS = 4, 200
    ids = np.arange(64, dtype=np.int64)

    def writer(t):
        for i in range(N_ITERS):
            stats.account(ids + t * 10_000 + i, 16,
                          residuals=(i % 2 == 0))

    def reader():
        for _ in range(N_ITERS):
            snap = stats.snapshot()
            assert snap["tokens_read"] >= snap["residual_tokens_read"]

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(N_THREADS)] + \
              [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = stats.snapshot()
    assert snap["gathers"] == N_THREADS * N_ITERS
    assert snap["tokens_read"] == N_THREADS * N_ITERS * len(ids)
    assert snap["residual_gathers"] == N_THREADS * N_ITERS // 2


def test_sync_run_balances_async_window_on_error():
    """A batch dying between its opens_async dispatch and closes_async
    sync (failed device sync, crashed shard worker) must not leave the
    shared overlap accounting stuck at 'dispatch in flight'."""
    from repro.serving.pipeline import PipelineStats

    stats = PipelineStats()

    def boom(cb):
        raise RuntimeError("dies while the async window is open")

    plan = StagePlan(method="x", stages=(
        Stage("dispatch", DEVICE, lambda cb: cb, opens_async=True),
        Stage("mid", HOST, boom),
        Stage("wait", DEVICE, lambda cb: cb, closes_async=True)))
    with pytest.raises(RuntimeError):
        plan.run(CandidateBatch(method="x", k=1), stats=stats)
    assert stats._async == 0

    # raising inside the closes_async stage itself must not
    # double-close (run_stage closes the window before calling it)
    plan2 = StagePlan(method="x", stages=(
        Stage("dispatch", DEVICE, lambda cb: cb, opens_async=True),
        Stage("wait", DEVICE, boom, closes_async=True)))
    stats2 = PipelineStats()
    stats2.async_open()          # an unrelated window stays untouched
    with pytest.raises(RuntimeError):
        plan2.run(CandidateBatch(method="x", k=1), stats=stats2)
    # the plan's own window closed exactly once (net 0); the unrelated
    # window is untouched — a double-close would have zeroed it
    assert stats2._async == 1
