"""Replicated shard fabric: replica-set routing units (EWMA order,
circuit breaker, quarantine budgets, hedge budgets), per-op deadlines
(``DeadlineExceeded``), seeded fault injection (``FaultSpec`` /
``FaultyChannel`` determinism + prob-0 transparency), remote-endpoint
attach parity, SIGKILL failover with zero lost batches, and the
degraded path — all replicas of a shard down → flagged partial
answers over the survivors, healing back to bitwise parity."""

import dataclasses
import os
import signal
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import build_shard_group
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import load_group, split_index_tree
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.serving.engine import Request, ServeEngine
from repro.serving.replica import ReplicaSet, _Replica
from repro.serving.server import RetrievalServer, tcp_query
from repro.serving.transport import (DeadlineExceeded, FaultSpec,
                                     FaultyChannel, ShardUnavailable,
                                     ShardWorkerDied, StreamChannel)
from repro.serving.transport.client import ShardWorkerClient

PLAID = PlaidParams(nprobe=8, candidate_cap=512, ndocs=128, k=50)
MS = MultiStageParams(first_k=50, k=20)


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------

def test_error_taxonomy():
    """Deadlines are connection-class failures (the failover machinery
    treats both alike) and ShardUnavailable is a ShardWorkerDied so
    legacy ``except ShardWorkerDied`` handlers keep working."""
    assert issubclass(DeadlineExceeded, ConnectionError)
    e = ShardUnavailable("gone", shard=3, last_error=ValueError("x"))
    assert isinstance(e, ShardWorkerDied)
    assert e.shard == 3
    assert isinstance(e.last_error, ValueError)


# ---------------------------------------------------------------------------
# _Replica / ReplicaSet units (no processes)
# ---------------------------------------------------------------------------

class _FakeCli:
    def __init__(self, alive=True, spawn_fail=False):
        self._alive = alive
        self.spawn_fail = spawn_fail
        self.terminated = False

    pid = 1234

    def alive(self):
        return self._alive

    def spawn(self):
        if self.spawn_fail:
            raise RuntimeError("spawn boom")
        self._alive = True
        return {}

    def terminate(self, grace_s=5.0):
        self.terminated = True
        return -9


def _mk_replica(endpoint=None, **cli_kw):
    return _Replica(0, 0, lambda gen: _FakeCli(**cli_kw),
                    endpoint=endpoint)


def test_replica_fail_fast_reaps_then_raises_then_respawns():
    r = _mk_replica()
    cli = r.ensure(fail_fast=True)
    cli._alive = False                        # the worker died
    with pytest.raises(ShardWorkerDied, match="healing on next use"):
        r.ensure(fail_fast=True)
    assert cli.terminated and r.restarts == 1 and r.serve_failures == 1
    assert r.ensure(fail_fast=True).alive()   # next use respawns


def test_replica_local_serve_quarantine_budget():
    r = _mk_replica()
    for _ in range(2):                        # die, respawn, die again
        r.ensure(fail_fast=False)._alive = False
        try:
            r.ensure(fail_fast=False)
        except ShardWorkerDied:
            pass
    assert r.quarantined()
    with pytest.raises(ShardWorkerDied, match="not respawning"):
        r.ensure(fail_fast=False)


def test_replica_local_spawn_quarantine_budget_is_separate():
    r = _mk_replica(spawn_fail=True)
    for _ in range(2):
        with pytest.raises(RuntimeError):
            r.ensure(fail_fast=False)
    assert r.spawn_failures == 2 and r.serve_failures == 0
    assert r.quarantined()
    with pytest.raises(ShardWorkerDied,
                       match="failed to spawn twice"):
        r.ensure(fail_fast=False)


def test_remote_replica_never_quarantines():
    r = _mk_replica(endpoint="127.0.0.1:1")
    for _ in range(5):
        r.ensure(fail_fast=False)._alive = False
        r.consec_serve_failures += 0          # streak grows via ensure
        r.ensure(fail_fast=False)             # reconnect revives inline
    assert not r.quarantined()
    # a successful reconnect wiped the streak every time
    assert r.consec_serve_failures == 0


def test_route_order_prefers_fast_live_closed_breakers():
    reps = [_mk_replica() for _ in range(4)]
    rs = ReplicaSet(0, reps)
    for r in reps[:3]:
        r.ensure(fail_fast=False)
    reps[0].ewma_ms, reps[1].ewma_ms = 30.0, 5.0
    reps[2].breaker_open_until = time.monotonic() + 10.0   # cooling
    # reps[3] never spawned: dead-but-spawnable, no EWMA
    order = rs.route_order()
    assert order[0] is reps[1]                # fastest live first
    assert order[1] is reps[0]
    assert order[2] is reps[3]                # spawnable before cooling
    assert order[3] is reps[2]                # half-open probe last
    assert reps[1] not in rs.route_order(exclude=reps[1])


def test_breaker_cooldown_grows_and_success_resets():
    rs = ReplicaSet(0, [_mk_replica()], breaker_base_ms=100.0,
                    breaker_max_ms=400.0)
    r = rs.primary
    cools = []
    for _ in range(4):
        rs.record_failure(r)
        cools.append(r.breaker_open_until - time.monotonic())
    assert cools[1] > cools[0] and cools[2] > cools[1]
    assert cools[3] <= 0.401 + 0.05           # capped at breaker_max
    rs.record_success(r, elapsed_ms=12.0)
    assert r.breaker_level == 0 and r.breaker_open_until == 0.0
    assert r.ewma_ms == 12.0
    rs.record_success(r, elapsed_ms=24.0)     # EWMA alpha = 0.2
    assert abs(r.ewma_ms - (0.8 * 12.0 + 0.2 * 24.0)) < 1e-9


def test_acquire_exhaustion_raises_shard_unavailable():
    reps = [_mk_replica(spawn_fail=True) for _ in range(2)]
    rs = ReplicaSet(4, reps)
    with pytest.raises(RuntimeError):
        # spawn failures propagate their own error the first time; two
        # of them quarantine each local replica
        rs.acquire()
    for r in reps:
        r.consec_spawn_failures = 2
    with pytest.raises(ShardUnavailable) as ei:
        rs.acquire()
    assert ei.value.shard == 4
    assert "all 2 replica(s) unavailable" in str(ei.value)


def test_hedge_budget_gating():
    reps = [_mk_replica(), _mk_replica()]
    rs = ReplicaSet(0, reps, hedge_factor=2.0, hedge_floor_ms=50.0)
    r = reps[0]
    assert rs.hedge_budget_ms(r) is None      # no EWMA yet
    r.ewma_ms = 100.0
    assert rs.hedge_budget_ms(r) is None      # no live sibling
    reps[1].ensure(fail_fast=False)
    assert rs.hedge_budget_ms(r) == 200.0     # factor * ewma
    r.ewma_ms = 10.0
    assert rs.hedge_budget_ms(r) == 50.0      # floor wins
    assert ReplicaSet(0, reps).hedge_budget_ms(r) is None  # hedging off
    assert ReplicaSet(0, [reps[0]], hedge_factor=2.0) \
        .hedge_budget_ms(r) is None           # no siblings at all


# ---------------------------------------------------------------------------
# FaultSpec / FaultyChannel
# ---------------------------------------------------------------------------

class _RecChannel:
    sock = None
    transport = "fake"

    def __init__(self):
        self.sent = []

    def send(self, obj):
        self.sent.append(obj)
        return 7

    def stats(self):
        return {"transport": "fake", "bytes_sent": 0, "bytes_recv": 0,
                "bytes_copied": 0, "bytes_zero_copy": 0}

    def close(self):
        pass


def test_fault_spec_parse():
    s = FaultSpec.parse("seed=42,drop=0.05,delay=20:0.1,"
                        "truncate=0.02,corrupt=0.03")
    assert (s.seed, s.drop, s.delay_ms, s.delay_p, s.truncate,
            s.corrupt) == (42, 0.05, 20.0, 0.1, 0.02, 0.03)
    assert FaultSpec.parse("delay=5").delay_p == 1.0   # bare delay
    with pytest.raises(ValueError, match="unknown fault field"):
        FaultSpec.parse("jitter=0.5")


def test_prob_zero_faulty_channel_is_transparent():
    inner = _RecChannel()
    ch = FaultyChannel(inner, FaultSpec())
    for i in range(20):
        assert ch.send({"i": i}) == 7
    assert len(inner.sent) == 20
    assert all(v == 0 for v in ch.faults.values())
    assert ch.stats()["faults_injected"] == ch.faults
    assert ch.transport == "fake"             # delegation intact


def test_faulty_channel_schedule_is_seed_deterministic():
    def run():
        inner = _RecChannel()
        ch = FaultyChannel(inner, FaultSpec(seed=9, drop=0.3,
                                            delay_ms=1.0, delay_p=0.2))
        delivered = []
        for i in range(60):
            ch.send({"i": i})
            delivered.append(len(inner.sent))
        return delivered, dict(ch.faults)

    a, b = run(), run()
    assert a == b                             # pure fn of (seed, index)
    assert a[1]["drop"] > 0                   # and it actually fired


# ---------------------------------------------------------------------------
# per-op deadlines against a stalling worker
# ---------------------------------------------------------------------------

@pytest.fixture
def stall_worker():
    """A fake remote worker that answers the readiness ping and then
    never replies again — a hung process as seen from the wire."""
    srv = socket.create_server(("127.0.0.1", 0))
    stop = threading.Event()

    def run():
        srv.settimeout(0.2)
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            ch = StreamChannel(conn)
            try:
                while not stop.is_set():
                    msg = ch.recv(timeout=0.5)
                    if msg is None:
                        continue
                    if msg["op"] == "ping":
                        ch.send({"ok": True, "result": {"pid": 0}})
                    # any other op: stall forever
            except Exception:
                pass
            finally:
                ch.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    yield srv.getsockname()[1]
    stop.set()
    t.join(timeout=5)
    srv.close()


def test_per_op_deadline_raises_and_marks_dead(stall_worker):
    cli = ShardWorkerClient(0, "unused", endpoint=f"127.0.0.1:"
                            f"{stall_worker}")
    cli.spawn()
    assert cli.alive()
    t0 = time.monotonic()
    with pytest.raises(DeadlineExceeded, match="per-op deadline"):
        cli.call("splade", {}, timeout_ms=200.0)
    assert time.monotonic() - t0 < 5.0        # the deadline, not the
    assert not cli.alive()                    # 300s call timeout
    cli.terminate(grace_s=0.1)


def test_no_deadline_uses_soft_timeout_path(stall_worker):
    cli = ShardWorkerClient(0, "unused", endpoint=f"127.0.0.1:"
                            f"{stall_worker}")
    cli.spawn()
    from repro.serving.rpc import ShardWorkerError
    with pytest.raises(ShardWorkerError, match="soft RPC deadline"):
        cli.call("splade", {}, timeout=0.3, kill_on_timeout=False)
    assert cli.alive()                        # soft expiry never kills
    cli.terminate(grace_s=0.1)


# ---------------------------------------------------------------------------
# fixtures: shard split + a fleet of standalone remote workers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, small_corpus):
    base = tmp_path_factory.mktemp("replica_base")
    build_colbert_index(base / "colbert", small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    build_splade_index(small_corpus["doc_term_ids"],
                       small_corpus["doc_term_weights"],
                       small_corpus["cfg"].vocab,
                       small_corpus["cfg"].n_docs).save(base / "splade")
    return base


@pytest.fixture(scope="module")
def split(base_dir):
    group = split_index_tree(base_dir, 2)
    return load_group(group)


@pytest.fixture(scope="module")
def thread_ref(split):
    dirs, bounds = split
    return build_shard_group(dirs, bounds, workers="thread",
                             mode="mmap", plaid_params=PLAID,
                             multistage_params=MS)


@pytest.fixture(scope="module")
def remote_fleet(split):
    """2 shards x 2 replicas of standalone TCP workers, each its own
    independently killable process. Tests that kill workers restore
    them (same ports) before yielding back."""
    from repro.serving.worker import spawn_standalone

    dirs, bounds = split

    def spawn(shard, port=0):
        return spawn_standalone(
            dirs[shard], shard, port=port,
            plaid_params=dataclasses.asdict(PLAID),
            ms_params=dataclasses.asdict(MS))

    slots = [(i, r) for i in range(2) for r in range(2)]
    with ThreadPoolExecutor(4) as tp:
        procs = list(tp.map(lambda s: spawn(s[0]), slots))
    fleet = {"dirs": dirs, "bounds": bounds, "spawn": spawn,
             "workers": {s: {"proc": p, "port": port}
                         for s, (p, port) in zip(slots, procs)}}
    yield fleet
    for w in fleet["workers"].values():
        w["proc"].kill()
    for w in fleet["workers"].values():
        try:
            w["proc"].wait(timeout=10)
        except Exception:
            pass


def _endpoints(fleet):
    return [[f"127.0.0.1:{fleet['workers'][(i, r)]['port']}"
             for r in range(2)] for i in range(2)]


def _coordinator(fleet, **kw):
    return build_shard_group(
        fleet["dirs"], fleet["bounds"], workers="process", mode="mmap",
        plaid_params=PLAID, multistage_params=MS, replicas=0,
        replica_endpoints=_endpoints(fleet), **kw)


def _kill(fleet, shard, rid):
    w = fleet["workers"][(shard, rid)]
    w["proc"].kill()
    w["proc"].wait(timeout=10)


def _restore(fleet):
    for (i, r), w in fleet["workers"].items():
        if w["proc"].poll() is not None:
            w["proc"], w["port"] = fleet["spawn"](i, w["port"])


def _batch(corpus, lo, hi):
    return dict(q_embs=corpus["q_embs"][lo:hi],
                term_ids=corpus["q_term_ids"][lo:hi],
                term_weights=corpus["q_term_weights"][lo:hi])


# ---------------------------------------------------------------------------
# remote endpoints: attach parity, SIGKILL failover, degraded + heal
# ---------------------------------------------------------------------------

def test_remote_attach_parity(remote_fleet, thread_ref, small_corpus):
    """A coordinator over TCP endpoints returns bitwise the thread
    group's results — remote transport changes nothing."""
    g = _coordinator(remote_fleet)
    try:
        kw = _batch(small_corpus, 0, 6)
        for method in ("splade", "hybrid"):
            ref = thread_ref.search_batch(method, **kw, k=10)
            got = g.search_batch(method, **kw, k=10)
            np.testing.assert_array_equal(ref[0], got[0])
            np.testing.assert_array_equal(ref[1], got[1])
        h = g.worker_health()
        assert all(rec["alive_replicas"] == 2 for rec in h)
        assert all(len(rec["replicas"]) == 2 for rec in h)
        assert all("spawn_failures" in rec and "serve_failures" in rec
                   for rec in h)
        assert all(r["endpoint"] for rec in h for r in rec["replicas"])
    finally:
        g.close()
        _restore(remote_fleet)


def test_remote_sigkill_failover_keeps_serving(remote_fleet, thread_ref,
                                               small_corpus):
    """SIGKILL one replica of every shard between batches: the next
    batches must succeed bitwise via the sibling replicas — zero
    failed requests, failover counted."""
    g = _coordinator(remote_fleet, op_deadline_ms=10_000.0)
    try:
        kw = _batch(small_corpus, 0, 4)
        ref = thread_ref.search_batch("hybrid", **kw, k=10)
        got = g.search_batch("hybrid", **kw, k=10)
        np.testing.assert_array_equal(ref[0], got[0])
        for shard in range(2):
            _kill(remote_fleet, shard, 0)
            # a killed remote worker is invisible until an op lands on
            # it (liveness is the connection); zero the corpse's EWMA
            # so routing deterministically picks it first and the
            # failover path — not lucky sibling routing — is what
            # keeps the batch alive
            g._replica_sets[shard].replicas[0].ewma_ms = 0.0
        for _ in range(3):                    # several batches post-kill
            got = g.search_batch("hybrid", **kw, k=10)
            np.testing.assert_array_equal(ref[0], got[0])
            np.testing.assert_array_equal(ref[1], got[1])
        counters = g.pipeline_stats.snapshot()["counters"]
        assert counters.get("failover_retries", 0) >= 1
        assert g.degraded_shards() == []      # siblings kept both up
        # restart the killed workers at their old ports; routing (or
        # the healer) reconnects and the full replica set comes back
        _restore(remote_fleet)
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            g.search_batch("hybrid", **kw, k=10)
            if all(rec["alive_replicas"] == 2
                   for rec in g.worker_health()):
                break
            time.sleep(0.5)
        assert all(rec["alive_replicas"] == 2
                   for rec in g.worker_health())
        got = g.search_batch("hybrid", **kw, k=10)
        np.testing.assert_array_equal(ref[0], got[0])
        np.testing.assert_array_equal(ref[1], got[1])
    finally:
        g.close()
        _restore(remote_fleet)


def test_all_replicas_down_degrades_then_heals(remote_fleet, split,
                                               small_corpus):
    """Both replicas of shard 1 SIGKILLed: an ``allow_degraded``
    coordinator answers from shard 0 alone — flagged, with the missing
    shard named, surviving results exact — and returns to bitwise
    parity once the workers come back."""
    dirs, bounds = split
    g = _coordinator(remote_fleet, allow_degraded=True,
                     op_deadline_ms=10_000.0)
    try:
        kw = _batch(small_corpus, 0, 4)
        ref = g.search_batch("splade", **kw, k=10)
        assert g.last_missing_shards() == ()
        _kill(remote_fleet, 1, 0)
        _kill(remote_fleet, 1, 1)
        out = g.search_batch("splade", **kw, k=10)
        missing = g.last_missing_shards()
        assert missing == (1,)
        assert g.degraded_shards() == [1]
        assert (out[0][out[0] >= 0] < bounds[1]).all()
        # surviving-shard exactness: the degraded answer IS shard 0's
        # own top-k (shard 0 starts at pid 0, so local pids == global)
        shard0 = MultiStageRetriever(
            SpladeIndex.load(dirs[0] / "splade", mmap=True),
            PLAIDSearcher(ColBERTIndex(dirs[0] / "colbert",
                                       mode="mmap"), PLAID), MS)
        ref0 = shard0.search_batch("splade", **kw, k=10)
        np.testing.assert_array_equal(ref0[0], out[0])
        np.testing.assert_array_equal(ref0[1], out[1])
        assert g.pipeline_stats.snapshot()["counters"][
            "degraded_batches"] >= 1
        # recovery: restart both workers → full bitwise parity again
        _restore(remote_fleet)
        deadline = time.monotonic() + 60
        healed = out
        while time.monotonic() < deadline:
            healed = g.search_batch("splade", **kw, k=10)
            if g.last_missing_shards() == ():
                break
            time.sleep(0.5)
        assert g.degraded_shards() == []
        np.testing.assert_array_equal(ref[0], healed[0])
        np.testing.assert_array_equal(ref[1], healed[1])
    finally:
        g.close()
        _restore(remote_fleet)


# ---------------------------------------------------------------------------
# local replicas: sibling routing + the healer thread
# ---------------------------------------------------------------------------

def test_local_replicas_route_around_dead_primary(split, thread_ref,
                                                  small_corpus):
    """2 local replicas per shard: SIGKILL the primary child — traffic
    routes to the live sibling with no failed batch, and the healer
    respawns the corpse in the background."""
    dirs, bounds = split
    g = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                          plaid_params=PLAID, multistage_params=MS,
                          replicas=2)
    try:
        kw = _batch(small_corpus, 0, 4)
        ref = thread_ref.search_batch("splade", **kw, k=10)
        got = g.search_batch("splade", **kw, k=10)
        np.testing.assert_array_equal(ref[0], got[0])
        victim = g._replica_sets[0].primary.client
        os.kill(victim.pid, signal.SIGKILL)
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            if victim.proc.poll() is not None:
                break
            time.sleep(0.05)
        got = g.search_batch("splade", **kw, k=10)   # sibling serves
        np.testing.assert_array_equal(ref[0], got[0])
        np.testing.assert_array_equal(ref[1], got[1])
        deadline = time.monotonic() + 60             # healer respawns
        while time.monotonic() < deadline:
            if g._replica_sets[0].alive_count() == 2:
                break
            time.sleep(0.25)
        assert g._replica_sets[0].alive_count() == 2
        assert g.pipeline_stats.snapshot()["counters"].get(
            "replica_heals", 0) >= 1
        rec = g.worker_health()[0]
        assert rec["restarts"] >= 1 and rec["serve_failures"] >= 1
        got = g.search_batch("splade", **kw, k=10)
        np.testing.assert_array_equal(ref[0], got[0])
    finally:
        g.close()


# ---------------------------------------------------------------------------
# degraded results through engine + server (local single-replica group)
# ---------------------------------------------------------------------------

def test_degraded_flags_through_engine_server_and_tcp(split, thread_ref,
                                                      small_corpus):
    """Single-replica group with allow_degraded: kill shard 1 → the
    next request is a flagged partial answer (engine Result fields,
    server health, TCP response); the request after that heals back to
    the full answer."""
    dirs, bounds = split
    g = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                          plaid_params=PLAID, multistage_params=MS,
                          allow_degraded=True)
    engine = ServeEngine(g, own_retriever=True)
    srv = RetrievalServer(engine, n_threads=1)
    srv.start()
    tcp = srv.serve_tcp("127.0.0.1", 0)
    tcp_thread = threading.Thread(target=tcp.serve_forever, daemon=True)
    tcp_thread.start()
    try:
        def req(qid):
            return Request(qid=qid, method="splade",
                           term_ids=small_corpus["q_term_ids"][qid],
                           term_weights=small_corpus[
                               "q_term_weights"][qid], k=10)

        def kill_shard1():
            victim = g._clients[1]
            os.kill(victim.pid, signal.SIGKILL)
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                if victim.proc.poll() is not None:
                    return
                time.sleep(0.05)
            raise AssertionError("worker refused to die")

        full = srv.submit(req(0)).result(timeout=120)
        assert not full.degraded and full.missing_shards == ()

        kill_shard1()
        part = srv.submit(req(0)).result(timeout=120)
        assert part.degraded and part.missing_shards == (1,)
        assert (part.pids[part.pids >= 0] < bounds[1]).all()

        # the degraded request reaped the corpse and has not respawned
        # it yet (heal-on-next-use), so health names the missing shard
        h = srv.health()
        assert h["allow_degraded"] is True
        assert h["degraded_shards"] == [1]

        healed = srv.submit(req(0)).result(timeout=120)
        assert not healed.degraded
        np.testing.assert_array_equal(full.pids, healed.pids)
        np.testing.assert_array_equal(full.scores, healed.scores)

        # same choreography through the TCP front: the degraded reply
        # carries the flag + missing ids, the healed one carries neither
        kill_shard1()
        deg = tcp_query("127.0.0.1", srv.tcp_port, {
            "qid": 7, "method": "splade",
            "term_ids": small_corpus["q_term_ids"][7].tolist(),
            "term_weights":
                small_corpus["q_term_weights"][7].tolist(), "k": 10})
        assert "error" not in deg
        assert deg["degraded"] is True and deg["missing_shards"] == [1]
        ok = tcp_query("127.0.0.1", srv.tcp_port, {
            "qid": 8, "method": "splade",
            "term_ids": small_corpus["q_term_ids"][8].tolist(),
            "term_weights":
                small_corpus["q_term_weights"][8].tolist(), "k": 10})
        assert "degraded" not in ok and "error" not in ok
    finally:
        tcp.shutdown()
        tcp.server_close()
        srv.stop()
        engine.close()
