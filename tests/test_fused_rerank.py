"""Serving-level parity for the fused stage-4 tail: the
``rerank_backend="fused"`` plans must return *bitwise* the split-path
results — pids AND score bits — for all four methods, mixed batches,
per-query alpha, ragged candidate lists, shard groups, and the
process-worker backend. Also covers the Pallas-unavailable fallback
and the dispatch-count accounting the fusion exists to shrink."""

import numpy as np
import pytest

from repro.core.multistage import (
    METHODS,
    MultiStageParams,
    MultiStageRetriever,
)
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import build_shard_group, build_sharded_retriever
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import shard_boundaries, split_index_tree
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.kernels.fused_rerank import ops as fused_ops

PLAID = PlaidParams(nprobe=8, candidate_cap=512, ndocs=128, k=50)
MS = MultiStageParams(first_k=50, k=20)


@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, small_corpus):
    base = tmp_path_factory.mktemp("fused_base")
    build_colbert_index(base / "colbert", small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    build_splade_index(small_corpus["doc_term_ids"],
                       small_corpus["doc_term_weights"],
                       small_corpus["cfg"].vocab,
                       small_corpus["cfg"].n_docs).save(base / "splade")
    return base


@pytest.fixture(scope="module")
def retr(base_dir):
    index = ColBERTIndex(base_dir / "colbert", mode="mmap")
    sidx = SpladeIndex.load(base_dir / "splade", mmap=True)
    return MultiStageRetriever(sidx, PLAIDSearcher(index, PLAID), MS)


def _batch(corpus, lo, hi):
    return dict(q_embs=corpus["q_embs"][lo:hi],
                term_ids=corpus["q_term_ids"][lo:hi],
                term_weights=corpus["q_term_weights"][lo:hi])


def _both_backends(retriever, *args, **kw):
    """Run search_batch under fused then split, restoring the default."""
    retriever.set_rerank_backend("fused")
    fused = retriever.search_batch(*args, **kw)
    retriever.set_rerank_backend("split")
    try:
        split = retriever.search_batch(*args, **kw)
    finally:
        retriever.set_rerank_backend(retriever.params.rerank_backend)
    return fused, split


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(
        np.asarray(a[1], np.float32).view(np.uint32),
        np.asarray(b[1], np.float32).view(np.uint32))


# ---------------------------------------------------------------------------
# fused == split, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_fused_matches_split_bitwise(retr, small_corpus, method):
    fused, split = _both_backends(retr, method, k=10,
                                  **_batch(small_corpus, 0, 12))
    _assert_bitwise(fused, split)


def test_fused_matches_split_mixed_batch(retr, small_corpus):
    methods = [METHODS[i % 4] for i in range(12)]
    fused, split = _both_backends(retr, methods, k=10,
                                  **_batch(small_corpus, 0, 12))
    _assert_bitwise(fused, split)


def test_fused_matches_split_per_query_alpha(retr, small_corpus):
    alphas = [0.0, 0.25, None, 1.0, 0.6, 0.1]
    fused, split = _both_backends(retr, "hybrid", alpha=alphas, k=15,
                                  **_batch(small_corpus, 6, 12))
    _assert_bitwise(fused, split)


@pytest.mark.parametrize("k", [1, 50, 200])
def test_fused_matches_split_depth_extremes(retr, small_corpus, k):
    """k == 1, k == first_k, and k far past the candidate count (ragged
    -1-padded candidate lists, (-inf, -1) tails)."""
    for method in ("rerank", "hybrid", "colbert"):
        fused, split = _both_backends(retr, method, k=k,
                                      **_batch(small_corpus, 0, 5))
        _assert_bitwise(fused, split)


def test_fused_single_query_matches_batch_row(retr, small_corpus):
    retr.set_rerank_backend("fused")
    batch = retr.search_batch("hybrid", k=10, **_batch(small_corpus, 0, 4))
    for i in range(4):
        one = retr.search_batch("hybrid", k=10,
                                **_batch(small_corpus, i, i + 1))
        np.testing.assert_array_equal(batch[0][i], one[0][0])
        np.testing.assert_array_equal(batch[1][i], one[1][0])


# ---------------------------------------------------------------------------
# shard groups
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_fused_matches_split_sharded(base_dir, small_corpus, n_shards):
    n_docs = small_corpus["cfg"].n_docs
    if n_shards == 1:
        dirs = [base_dir]
    else:
        group = split_index_tree(base_dir, n_shards,
                                 group_dir=base_dir / f"fs{n_shards}")
        dirs = [group / str(i) for i in range(n_shards)]
    g = build_sharded_retriever(dirs, shard_boundaries(n_docs, n_shards),
                                mode="mmap", plaid_params=PLAID,
                                multistage_params=MS)
    assert g.rerank_backend in ("fused", "split")   # resolved at init
    for method in METHODS:
        fused, split = _both_backends(g, method, k=10,
                                      **_batch(small_corpus, 0, 8))
        _assert_bitwise(fused, split)


def test_fused_matches_split_process_group(base_dir, small_corpus):
    group = split_index_tree(base_dir, 2, group_dir=base_dir / "fsp2")
    g = build_shard_group(
        [group / str(i) for i in range(2)],
        shard_boundaries(small_corpus["cfg"].n_docs, 2),
        workers="process", mode="mmap", plaid_params=PLAID,
        multistage_params=MS)
    try:
        assert g.rerank_backend in ("fused", "split")
        for method in ("hybrid", "colbert"):
            fused, split = _both_backends(g, method, k=10,
                                          **_batch(small_corpus, 0, 6))
            _assert_bitwise(fused, split)
    finally:
        g.close()


# ---------------------------------------------------------------------------
# knob semantics + accounting
# ---------------------------------------------------------------------------

def test_rerank_backend_validation_and_fallback(retr, monkeypatch):
    with pytest.raises(ValueError):
        retr.set_rerank_backend("nope")
    monkeypatch.setattr(fused_ops, "HAVE_PALLAS", False)
    retr.set_rerank_backend("fused")
    assert retr.rerank_backend == "split"       # graceful degrade
    monkeypatch.undo()
    retr.set_rerank_backend(retr.params.rerank_backend)
    assert retr.rerank_backend == "fused"


def test_fused_path_records_single_device_dispatch(retr, small_corpus):
    retr.set_rerank_backend("fused")
    retr.reset_stage_stats()
    retr.search_batch("rerank", k=10, **_batch(small_corpus, 0, 4))
    retr.search_batch("colbert", k=10, **_batch(small_corpus, 0, 4))
    stages = retr.pipeline_stats.snapshot()["stages"]
    assert "fuse_topk" not in stages            # zero on the fused path
    assert stages["fused_rerank"]["dispatches"] == 2
    assert stages["fused_rerank"]["device_dispatches"] == 2
    assert stages["fused_rerank:sync"]["device_dispatches"] == 0

    retr.set_rerank_backend("split")
    try:
        retr.reset_stage_stats()
        retr.search_batch("hybrid", k=10, **_batch(small_corpus, 0, 4))
        stages = retr.pipeline_stats.snapshot()["stages"]
        assert stages["device_score:maxsim"]["device_dispatches"] == 4
        assert stages["fuse_topk"]["device_dispatches"] == 0
    finally:
        retr.set_rerank_backend(retr.params.rerank_backend)
