"""The paper's core: PLAID multi-stage search, hybrid scoring,
multi-stage pipeline quality ordering, mmap access minimisation."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hybrid as H
from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.eval import metrics
from repro.index.builder import ColBERTIndex
from repro.index.residual import decode_embeddings
from repro.index.splade_index import build_splade_index
from repro.kernels.maxsim.ref import maxsim_scores_ref


# ---------------------------------------------------------------------------
# hybrid normalisers
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(3, 40), st.floats(0.1, 50.0), st.floats(-20.0, 20.0),
       st.integers(0, 2 ** 31 - 1))
def test_znorm_affine_invariant(n, a, b, seed):
    """z-norm kills scale/shift — the property that lets it fuse SPLADE
    and ColBERT scores 'of drastically different distributions'."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mask = jnp.ones(n, bool)
    n1 = H.znorm(x, mask)
    n2 = H.znorm(a * x + b, mask)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-2,
                               atol=1e-3)


def test_normalizers_respect_mask():
    x = jnp.asarray([1.0, 2.0, 3.0, 1e9])     # huge padded entry
    mask = jnp.asarray([True, True, True, False])
    for name, fn in H.NORMALIZERS.items():
        out = np.asarray(fn(x, mask))[:3]
        assert np.all(np.isfinite(out)), name
        assert np.abs(out).max() < 10, name    # padding did not leak


def test_hybrid_alpha_limits():
    s = jnp.asarray([3.0, 1.0, 2.0])
    c = jnp.asarray([1.0, 3.0, 2.0])
    mask = jnp.ones(3, bool)
    # α=0 → ColBERT (rerank) order; α=1 → SPLADE order
    h0 = np.asarray(H.hybrid_scores(s, c, mask, alpha=0.0))
    h1 = np.asarray(H.hybrid_scores(s, c, mask, alpha=1.0))
    assert np.argmax(h0) == 1
    assert np.argmax(h1) == 0


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_hybrid_padding_is_neg_inf(alpha, seed):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(size=6).astype(np.float32))
    c = jnp.asarray(rng.normal(size=6).astype(np.float32))
    mask = jnp.asarray([True, True, False, True, False, True])
    out = np.asarray(H.hybrid_scores(s, c, mask, alpha=alpha))
    assert np.all(np.isinf(out[~np.asarray(mask)]))


# ---------------------------------------------------------------------------
# PLAID
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def searcher(built_index):
    index = ColBERTIndex(built_index, mode="mmap")
    return index, PLAIDSearcher(index, PlaidParams(
        nprobe=8, candidate_cap=512, ndocs=128, k=50))


def brute_force(index: ColBERTIndex, q_emb):
    """Exact MaxSim over every decompressed doc in the index."""
    pids = np.arange(index.n_docs)
    c, r, v = index.gather_doc_tokens(pids)
    emb = decode_embeddings(jnp.asarray(r), jnp.asarray(c),
                            jnp.asarray(index.centroids),
                            jnp.asarray(index.bucket_weights), index.nbits)
    emb = emb * jnp.asarray(v)[..., None]
    scores = maxsim_scores_ref(jnp.asarray(q_emb), emb, jnp.asarray(v))
    return np.asarray(scores)


def test_plaid_agrees_with_brute_force(searcher, small_corpus):
    index, s = searcher
    hits = 0
    for qi in range(20):
        q = small_corpus["q_embs"][qi]
        exact = brute_force(index, q)
        pids, scores, _ = s.search(q, k=10)
        # top-1 of PLAID is within the true top-3 (approximation in
        # stages 1-3 can reorder near-ties)
        true_top3 = set(np.argsort(-exact)[:3].tolist())
        hits += int(pids[0]) in true_top3
    assert hits >= 18


def test_rerank_equals_exact_scoring(searcher, small_corpus):
    index, s = searcher
    q = small_corpus["q_embs"][0]
    pids = np.arange(40)
    exact = brute_force(index, q)[:40]
    got = s.rerank(q, pids)
    np.testing.assert_allclose(got, exact, rtol=1e-3, atol=1e-3)


def test_rerank_respects_padding(searcher, small_corpus):
    _, s = searcher
    q = small_corpus["q_embs"][1]
    pids = np.array([3, -1, 7, -1])
    out = s.rerank(q, pids)
    assert np.isinf(out[1]) and np.isinf(out[3])
    assert np.isfinite(out[0]) and np.isfinite(out[2])


def test_mmap_and_ram_modes_identical(built_index, small_corpus):
    res = {}
    for mode in ("ram", "mmap"):
        index = ColBERTIndex(built_index, mode=mode)
        s = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                             ndocs=128, k=20))
        pids, scores, _ = s.search(small_corpus["q_embs"][2], k=20)
        res[mode] = (pids, scores)
    np.testing.assert_array_equal(res["ram"][0], res["mmap"][0])
    np.testing.assert_allclose(res["ram"][1], res["mmap"][1], rtol=1e-6)


def test_device_resident_matches_host_path(built_index, small_corpus):
    index = ColBERTIndex(built_index, mode="ram")
    host = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                            ndocs=128, k=20))
    dev = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                           ndocs=128, k=20),
                        device_resident=True)
    q = small_corpus["q_embs"][3]
    p1, s1, _ = host.search(q, k=20)
    p2, s2, _ = dev.search(q, k=20)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# multi-stage: the paper's access-minimisation claim
# ---------------------------------------------------------------------------

def test_rerank_touches_fewer_pages_than_full_plaid(built_index,
                                                    small_corpus):
    index = ColBERTIndex(built_index, mode="mmap")
    s = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                         ndocs=256, k=20))
    sidx = build_splade_index(small_corpus["doc_term_ids"],
                              small_corpus["doc_term_weights"],
                              small_corpus["cfg"].vocab,
                              small_corpus["cfg"].n_docs)
    retr = MultiStageRetriever(sidx, s, MultiStageParams(first_k=50, k=20))

    index.store.stats.reset()
    for qi in range(10):
        retr.search("colbert", q_emb=small_corpus["q_embs"][qi])
    full_tokens = index.store.stats.tokens_read

    index.store.stats.reset()
    for qi in range(10):
        retr.search("rerank", q_emb=small_corpus["q_embs"][qi],
                    term_ids=small_corpus["q_term_ids"][qi],
                    term_weights=small_corpus["q_term_weights"][qi])
    rerank_tokens = index.store.stats.tokens_read
    # SPLADE top-50 rerank reads far less of the pool than full PLAID
    assert rerank_tokens < 0.5 * full_tokens


def test_quality_ordering_matches_paper(built_index, small_corpus):
    """Table 2's relationships on the controlled corpus: Hybrid beats
    Rerank and SPLADE; Rerank ≈ ColBERT; SPLADE is the weakest."""
    index = ColBERTIndex(built_index, mode="mmap")
    s = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                         ndocs=256, k=50))
    sidx = build_splade_index(small_corpus["doc_term_ids"],
                              small_corpus["doc_term_weights"],
                              small_corpus["cfg"].vocab,
                              small_corpus["cfg"].n_docs)
    retr = MultiStageRetriever(sidx, s,
                               MultiStageParams(first_k=100, k=50,
                                                alpha=0.3))
    ranked = {m: [] for m in ("colbert", "splade", "rerank", "hybrid")}
    n_q = 40
    for qi in range(n_q):
        for m in ranked:
            pids, _ = retr.search(
                m, q_emb=small_corpus["q_embs"][qi],
                term_ids=small_corpus["q_term_ids"][qi],
                term_weights=small_corpus["q_term_weights"][qi])
            ranked[m].append(pids)
    qrels = small_corpus["qrels"][:n_q]
    mrr = {m: metrics.mrr_at_k(np.stack(v), qrels, 10)
           for m, v in ranked.items()}
    assert mrr["hybrid"] >= mrr["rerank"] - 1e-9
    assert mrr["hybrid"] > mrr["splade"]
    assert mrr["rerank"] >= 0.9 * mrr["colbert"]
    assert mrr["colbert"] > mrr["splade"]
