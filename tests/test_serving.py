"""Concurrent serving: queue/worker mechanics, latency accounting,
failure replacement, drain, Poisson load generation, TCP front."""

import threading
import time

import numpy as np
import pytest

from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import (run_closed_loop, run_open_loop,
                                   run_poisson_load)
from repro.serving.server import RetrievalServer, TCPRetrievalServer, tcp_query


class FakeRetriever:
    """Deterministic-latency stand-in for MultiStageRetriever."""

    def __init__(self, service_s=0.002, fail_qids=()):
        self.service_s = service_s
        self.fail_qids = set(fail_qids)
        self.calls = 0

    def search(self, method, q_emb=None, term_ids=None, term_weights=None,
               alpha=None, k=10):
        self.calls += 1
        if self.service_s:
            time.sleep(self.service_s)
        if q_emb is not None and int(q_emb[0]) in self.fail_qids:
            raise RuntimeError("injected failure")
        return np.arange(k), np.linspace(1, 0, k)


def make_server(n_threads=2, **kw):
    srv = RetrievalServer(ServeEngine(FakeRetriever(**kw)),
                          n_threads=n_threads)
    srv.start()
    return srv


def test_serves_concurrent_requests():
    srv = make_server(n_threads=4)
    futs = [srv.submit(Request(qid=i, method="hybrid",
                               q_emb=np.zeros(2))) for i in range(32)]
    results = [f.result(timeout=30) for f in futs]
    assert len(results) == 32
    assert all(r.latency >= r.service_time - 1e-6 for r in results)
    assert srv.health()["served"] == 32
    srv.stop()


def test_failure_is_isolated_and_counted():
    srv = make_server(n_threads=2, fail_qids={5})
    ok = [srv.submit(Request(qid=i, method="hybrid",
                             q_emb=np.full(2, i))) for i in range(8)]
    with pytest.raises(RuntimeError):
        ok[5].result(timeout=10)
    for i, f in enumerate(ok):
        if i != 5:
            f.result(timeout=10)
    h = srv.health()
    assert h["failed"] == 1
    assert h["workers"] == 2     # workers survive failures
    srv.stop()


def test_cancel_while_processing_cannot_race_worker():
    """Workers claim futures (set_running_or_notify_cancel) before
    scoring, so a client cancel() mid-service fails instead of racing
    the worker's set_result into an InvalidStateError."""
    srv = make_server(n_threads=1, service_s=0.05)
    fut = srv.submit(Request(qid=0, method="hybrid", q_emb=np.zeros(2)))
    deadline = time.time() + 5
    while not fut.running() and time.time() < deadline:
        time.sleep(0.001)
    assert fut.running()
    assert not fut.cancel()              # claimed: cancel must lose
    assert fut.result(timeout=10).qid == 0
    assert srv.health()["workers"] == 1  # worker survived
    srv.stop()


def test_drain_completes_queue():
    srv = make_server(n_threads=1, service_s=0.005)
    futs = [srv.submit(Request(qid=i, method="rerank",
                               q_emb=np.zeros(2))) for i in range(10)]
    srv.drain()
    assert all(f.done() for f in futs)
    srv.stop()


def test_poisson_load_reports_percentiles():
    srv = make_server(n_threads=1, service_s=0.002)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(40)]
    res = run_poisson_load(srv, reqs, qps=400.0, seed=0)
    assert res.p95 >= res.p50 > 0
    assert len(res.latencies) == 40
    assert res.achieved_qps > 0
    srv.stop()


def test_open_loop_reports_tail_percentiles():
    """Open-loop arrivals: offered load is honoured regardless of
    service rate, and p50 <= p95 <= p99 come out of the summary."""
    srv = make_server(n_threads=1, service_s=0.001)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(30)]
    res = run_open_loop(srv, reqs, arrival_rate=500.0, seed=3)
    s = res.summary()
    assert s["n"] == 30
    assert s["p50"] <= s["p95"] <= s["p99"]
    assert res.offered_qps == 500.0
    srv.stop()


def test_open_loop_overload_grows_tail():
    """An open-loop generator must not self-throttle: offered >> service
    rate makes the tail explode relative to a light load."""
    service = 0.004
    light_srv = make_server(n_threads=1, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(40)]
    light = run_open_loop(light_srv, reqs, arrival_rate=50.0, seed=5)
    light_srv.stop()
    heavy_srv = make_server(n_threads=1, service_s=service)
    heavy = run_open_loop(heavy_srv, reqs, arrival_rate=2000.0, seed=5)
    heavy_srv.stop()
    assert heavy.p99 > 3 * light.p99


def test_closed_loop_survives_failed_requests():
    """A failing request must not silently kill the client thread: the
    rest of the workload still runs and is measured."""
    srv = make_server(n_threads=1, service_s=0.0, fail_qids={3})
    reqs = [Request(qid=i, method="hybrid", q_emb=np.full(2, i))
            for i in range(10)]
    res = run_closed_loop(srv, reqs, concurrency=1)
    assert len(res.latencies) == 9       # only the poisoned one missing
    srv.stop()


def test_closed_loop_self_limits():
    """Closed-loop clients never queue more than ``concurrency`` deep,
    so latency stays ~service time even though the server is slow."""
    service = 0.003
    srv = make_server(n_threads=2, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(24)]
    res = run_closed_loop(srv, reqs, concurrency=2)
    assert len(res.latencies) == 24
    assert res.p95 < 10 * service       # no unbounded queueing
    assert res.achieved_qps > 0
    srv.stop()


def test_saturation_raises_latency():
    """Offered load ≫ service rate ⇒ queueing dominates p95 — the knee
    the paper's Fig 1/2 shows."""
    service = 0.004   # 250 QPS capacity single-thread
    low_srv = make_server(n_threads=1, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(60)]
    low = run_poisson_load(low_srv, reqs, qps=50.0, seed=1)
    low_srv.stop()
    hi_srv = make_server(n_threads=1, service_s=service)
    hi = run_poisson_load(hi_srv, reqs, qps=2000.0, seed=1)
    hi_srv.stop()
    assert hi.p95 > 3 * low.p95


def test_tcp_front_roundtrip():
    srv = make_server(n_threads=1)
    tcp = TCPRetrievalServer(("127.0.0.1", 0), srv)
    port = tcp.server_address[1]
    t = threading.Thread(target=tcp.serve_forever, daemon=True)
    t.start()
    try:
        out = tcp_query("127.0.0.1", port,
                        {"qid": 7, "method": "hybrid",
                         "q_emb": [0.0, 0.0], "k": 5})
        assert out["qid"] == 7
        assert len(out["pids"]) == 5
        assert out["latency"] > 0
    finally:
        tcp.shutdown()
        srv.stop()


def test_tcp_error_response_carries_qid():
    """A failing request still tells the client which qid failed."""
    srv = make_server(n_threads=1, fail_qids={9})
    tcp = TCPRetrievalServer(("127.0.0.1", 0), srv)
    port = tcp.server_address[1]
    t = threading.Thread(target=tcp.serve_forever, daemon=True)
    t.start()
    try:
        out = tcp_query("127.0.0.1", port,
                        {"qid": 9, "method": "hybrid",
                         "q_emb": [9.0, 9.0], "k": 5})
        assert "error" in out
        assert out["qid"] == 9
    finally:
        tcp.shutdown()
        srv.stop()
