"""Concurrent serving: queue/worker mechanics, latency accounting,
failure replacement, drain, Poisson load generation, TCP front."""

import threading
import time

import numpy as np
import pytest

from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import (run_closed_loop, run_open_loop,
                                   run_poisson_load)
from repro.serving.server import RetrievalServer, TCPRetrievalServer, tcp_query


class FakeRetriever:
    """Deterministic-latency stand-in for MultiStageRetriever."""

    def __init__(self, service_s=0.002, fail_qids=()):
        self.service_s = service_s
        self.fail_qids = set(fail_qids)
        self.calls = 0

    def search(self, method, q_emb=None, term_ids=None, term_weights=None,
               alpha=None, k=10):
        self.calls += 1
        if self.service_s:
            time.sleep(self.service_s)
        if q_emb is not None and int(q_emb[0]) in self.fail_qids:
            raise RuntimeError("injected failure")
        return np.arange(k), np.linspace(1, 0, k)


def make_server(n_threads=2, **kw):
    srv = RetrievalServer(ServeEngine(FakeRetriever(**kw)),
                          n_threads=n_threads)
    srv.start()
    return srv


def test_serves_concurrent_requests():
    srv = make_server(n_threads=4)
    futs = [srv.submit(Request(qid=i, method="hybrid",
                               q_emb=np.zeros(2))) for i in range(32)]
    results = [f.result(timeout=30) for f in futs]
    assert len(results) == 32
    assert all(r.latency >= r.service_time - 1e-6 for r in results)
    assert srv.health()["served"] == 32
    srv.stop()


def test_failure_is_isolated_and_counted():
    srv = make_server(n_threads=2, fail_qids={5})
    ok = [srv.submit(Request(qid=i, method="hybrid",
                             q_emb=np.full(2, i))) for i in range(8)]
    with pytest.raises(RuntimeError):
        ok[5].result(timeout=10)
    for i, f in enumerate(ok):
        if i != 5:
            f.result(timeout=10)
    h = srv.health()
    assert h["failed"] == 1
    assert h["workers"] == 2     # workers survive failures
    srv.stop()


def test_cancel_while_processing_cannot_race_worker():
    """Workers claim futures (set_running_or_notify_cancel) before
    scoring, so a client cancel() mid-service fails instead of racing
    the worker's set_result into an InvalidStateError."""
    srv = make_server(n_threads=1, service_s=0.05)
    fut = srv.submit(Request(qid=0, method="hybrid", q_emb=np.zeros(2)))
    deadline = time.time() + 5
    while not fut.running() and time.time() < deadline:
        time.sleep(0.001)
    assert fut.running()
    assert not fut.cancel()              # claimed: cancel must lose
    assert fut.result(timeout=10).qid == 0
    assert srv.health()["workers"] == 1  # worker survived
    srv.stop()


def test_drain_completes_queue():
    srv = make_server(n_threads=1, service_s=0.005)
    futs = [srv.submit(Request(qid=i, method="rerank",
                               q_emb=np.zeros(2))) for i in range(10)]
    srv.drain()
    assert all(f.done() for f in futs)
    srv.stop()


def test_poisson_load_reports_percentiles():
    srv = make_server(n_threads=1, service_s=0.002)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(40)]
    res = run_poisson_load(srv, reqs, qps=400.0, seed=0)
    assert res.p95 >= res.p50 > 0
    assert len(res.latencies) == 40
    assert res.achieved_qps > 0
    srv.stop()


def test_open_loop_reports_tail_percentiles():
    """Open-loop arrivals: offered load is honoured regardless of
    service rate, and p50 <= p95 <= p99 come out of the summary."""
    srv = make_server(n_threads=1, service_s=0.001)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(30)]
    res = run_open_loop(srv, reqs, arrival_rate=500.0, seed=3)
    s = res.summary()
    assert s["n"] == 30
    assert s["p50"] <= s["p95"] <= s["p99"]
    assert res.offered_qps == 500.0
    srv.stop()


def test_open_loop_overload_grows_tail():
    """An open-loop generator must not self-throttle: offered >> service
    rate makes the tail explode relative to a light load."""
    service = 0.004
    light_srv = make_server(n_threads=1, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(40)]
    light = run_open_loop(light_srv, reqs, arrival_rate=50.0, seed=5)
    light_srv.stop()
    heavy_srv = make_server(n_threads=1, service_s=service)
    heavy = run_open_loop(heavy_srv, reqs, arrival_rate=2000.0, seed=5)
    heavy_srv.stop()
    assert heavy.p99 > 3 * light.p99


def test_closed_loop_survives_failed_requests():
    """A failing request must not silently kill the client thread: the
    rest of the workload still runs and is measured."""
    srv = make_server(n_threads=1, service_s=0.0, fail_qids={3})
    reqs = [Request(qid=i, method="hybrid", q_emb=np.full(2, i))
            for i in range(10)]
    res = run_closed_loop(srv, reqs, concurrency=1)
    assert len(res.latencies) == 9       # only the poisoned one missing
    srv.stop()


def test_closed_loop_self_limits():
    """Closed-loop clients never queue more than ``concurrency`` deep,
    so latency stays ~service time even though the server is slow."""
    service = 0.003
    srv = make_server(n_threads=2, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(24)]
    res = run_closed_loop(srv, reqs, concurrency=2)
    assert len(res.latencies) == 24
    assert res.p95 < 10 * service       # no unbounded queueing
    assert res.achieved_qps > 0
    srv.stop()


def test_poisson_offered_rate_does_not_sag():
    """Absolute-schedule arrivals: offered ≈ achieved for a fast no-op
    engine. The old relative ``sleep(gap)`` accumulated scheduler lag
    and submit overhead per arrival (coordinated omission), so at
    sub-millisecond gaps the offered rate silently sagged well below
    the requested QPS."""
    srv = make_server(n_threads=4, service_s=0.0)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(300)]
    qps = 2000.0
    res = run_poisson_load(srv, reqs, qps=qps, seed=2)
    srv.stop()
    # ideal wall ≈ last scheduled arrival; generous floor because the
    # submitting thread shares 2 cores with the servers
    assert res.achieved_qps >= 0.7 * qps, res.summary()


def test_batch_cap_resize_races_collection():
    """`_collect_batch` reads the adaptive cap under the same lock
    `_observe_latency` resizes it under; a mutator thread hammering the
    cap while batches are collected must never corrupt it (cap stays in
    [1, max_batch]) or lose requests."""
    srv = RetrievalServer(ServeEngine(FakeRetriever(service_s=0.001)),
                          n_threads=2, max_batch=8, batch_timeout_ms=1.0,
                          latency_slo_ms=5.0)
    srv.start()
    stop = threading.Event()

    def mutate():
        flip = True
        while not stop.is_set():
            with srv._lock:
                srv.batch_cap = 1 if flip else srv.max_batch
            flip = not flip

    t = threading.Thread(target=mutate, daemon=True)
    t.start()
    try:
        futs = [srv.submit(Request(qid=i, method="hybrid",
                                   q_emb=np.zeros(2)))
                for i in range(64)]
        results = [f.result(timeout=30) for f in futs]
        assert len(results) == 64
        assert 1 <= srv.batch_cap <= srv.max_batch
    finally:
        stop.set()
        t.join(timeout=5)
        srv.stop()


class _FlippableEngine:
    """Engine whose ``pipelined`` flag can change at runtime (e.g. a
    stage-1 backend switch rebuilding the pipeline)."""

    def __init__(self):
        self.pipelined = False
        self.served = 0
        self.sync_calls = 0
        self.async_calls = 0

    def _result(self, req):
        from repro.serving.engine import Result
        now = time.perf_counter()
        return Result(qid=req.qid, pids=np.arange(req.k),
                      scores=np.linspace(1, 0, req.k),
                      t_arrival=req.t_arrival, t_start=now, t_done=now)

    def process(self, req):
        self.sync_calls += 1
        self.served += 1
        return self._result(req)

    def process_batch(self, reqs):
        self.sync_calls += len(reqs)
        self.served += len(reqs)
        return [self._result(r) for r in reqs]

    def process_batch_async(self, reqs):
        from concurrent.futures import Future
        self.async_calls += len(reqs)
        self.served += len(reqs)
        fut = Future()
        fut.set_running_or_notify_cancel()
        fut.set_result([self._result(r) for r in reqs])
        return fut

    def stop_pipelines(self):
        pass

    def drain_pipelines(self, timeout=None):
        pass


def test_worker_reevaluates_pipelined_flag_mid_serve():
    """The dispatch path must follow the engine's *current* ``pipelined``
    flag, not the one captured when the worker thread started."""
    eng = _FlippableEngine()
    srv = RetrievalServer(eng, n_threads=1, max_batch=4,
                          batch_timeout_ms=1.0)
    srv.start()
    try:
        for i in range(6):
            srv.submit(Request(qid=i, method="hybrid",
                               q_emb=np.zeros(2), k=5)).result(timeout=10)
        assert eng.sync_calls == 6 and eng.async_calls == 0
        eng.pipelined = True          # rebuild happens mid-serve
        for i in range(6):
            srv.submit(Request(qid=10 + i, method="hybrid",
                               q_emb=np.zeros(2), k=5)).result(timeout=10)
        assert eng.async_calls == 6
        assert eng.sync_calls == 6    # no new sync dispatches
        eng.pipelined = False         # and back
        srv.submit(Request(qid=99, method="hybrid", q_emb=np.zeros(2),
                           k=5)).result(timeout=10)
        assert eng.sync_calls == 7
    finally:
        srv.stop()


def test_saturation_raises_latency():
    """Offered load ≫ service rate ⇒ queueing dominates p95 — the knee
    the paper's Fig 1/2 shows."""
    service = 0.004   # 250 QPS capacity single-thread
    low_srv = make_server(n_threads=1, service_s=service)
    reqs = [Request(qid=i, method="hybrid", q_emb=np.zeros(2))
            for i in range(60)]
    low = run_poisson_load(low_srv, reqs, qps=50.0, seed=1)
    low_srv.stop()
    hi_srv = make_server(n_threads=1, service_s=service)
    hi = run_poisson_load(hi_srv, reqs, qps=2000.0, seed=1)
    hi_srv.stop()
    assert hi.p95 > 3 * low.p95


def test_tcp_front_roundtrip():
    srv = make_server(n_threads=1)
    tcp = TCPRetrievalServer(("127.0.0.1", 0), srv)
    port = tcp.server_address[1]
    t = threading.Thread(target=tcp.serve_forever, daemon=True)
    t.start()
    try:
        out = tcp_query("127.0.0.1", port,
                        {"qid": 7, "method": "hybrid",
                         "q_emb": [0.0, 0.0], "k": 5})
        assert out["qid"] == 7
        assert len(out["pids"]) == 5
        assert out["latency"] > 0
    finally:
        tcp.shutdown()
        srv.stop()


def test_tcp_error_response_carries_qid():
    """A failing request still tells the client which qid failed."""
    srv = make_server(n_threads=1, fail_qids={9})
    tcp = TCPRetrievalServer(("127.0.0.1", 0), srv)
    port = tcp.server_address[1]
    t = threading.Thread(target=tcp.serve_forever, daemon=True)
    t.start()
    try:
        out = tcp_query("127.0.0.1", port,
                        {"qid": 9, "method": "hybrid",
                         "q_emb": [9.0, 9.0], "k": 5})
        assert "error" in out
        assert out["qid"] == 9
    finally:
        tcp.shutdown()
        srv.stop()
