"""ColBERT / SPLADE encoder semantics: query augmentation, doc masking,
unit norms, SPLADE sparsity + max-pool, contrastive trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.colbert_serve import smoke_cfg
from repro.models import colbert as CB
from repro.models import splade as SP
from repro.models.encoder import EncoderCfg
from repro.models import encoder as E


@pytest.fixture(scope="module")
def ccfg():
    return smoke_cfg().colbert


@pytest.fixture(scope="module")
def cparams(ccfg):
    return CB.init(jax.random.PRNGKey(0), ccfg)


def test_query_augmentation_all_positions_valid(ccfg, cparams):
    """[MASK]-augmented query slots produce embeddings that score."""
    B, Lq = 3, ccfg.query_maxlen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Lq), 4,
                              ccfg.encoder.vocab)
    lens = jnp.asarray([2, Lq, 5])
    q = CB.encode_queries(cparams, ccfg, toks, lens)
    assert q.shape == (B, Lq, ccfg.dim)
    norms = jnp.linalg.norm(q, axis=-1)
    np.testing.assert_allclose(np.asarray(norms), 1.0, rtol=1e-3)


def test_doc_padding_is_zeroed(ccfg, cparams):
    B, Ld = 2, ccfg.doc_maxlen
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, Ld), 4,
                              ccfg.encoder.vocab)
    lens = jnp.asarray([4, Ld])
    emb, valid = CB.encode_docs(cparams, ccfg, toks, lens)
    assert emb.shape == (B, Ld, ccfg.dim)
    # padded positions contribute exactly zero vectors
    pad = np.asarray(emb[0, 5:])
    np.testing.assert_allclose(pad, 0.0, atol=1e-6)
    assert bool(valid[0, :5].all()) and not bool(valid[0, 5:].any())


def test_doc_content_beyond_len_ignored(ccfg, cparams):
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, ccfg.doc_maxlen),
                              4, ccfg.encoder.vocab)
    lens = jnp.asarray([6])
    e1, _ = CB.encode_docs(cparams, ccfg, toks, lens)
    toks2 = toks.at[:, 10:].set(5)
    e2, _ = CB.encode_docs(cparams, ccfg, toks2, lens)
    np.testing.assert_allclose(np.asarray(e1[:, :6]),
                               np.asarray(e2[:, :6]), rtol=2e-4, atol=2e-4)


def test_maxsim_self_retrieval(ccfg, cparams):
    """Querying with a doc's own token embeddings ranks the doc top-1
    (unit-norm self-match maximises every per-token max)."""
    rng = np.random.default_rng(0)
    n, Lq = 16, 8
    toks = rng.integers(4, ccfg.encoder.vocab,
                        (n, ccfg.doc_maxlen)).astype(np.int32)
    lens = np.full(n, ccfg.doc_maxlen, np.int32)
    d_emb, d_valid = CB.encode_docs(cparams, ccfg, jnp.asarray(toks),
                                    jnp.asarray(lens))
    for i in range(n):
        q = d_emb[i, :Lq]                    # the doc's own embeddings
        s = CB.maxsim(q, d_emb, d_valid)
        assert int(jnp.argmax(s)) == i
        np.testing.assert_allclose(float(s[i]), Lq, rtol=1e-3)


def test_splade_sparse_nonneg_and_masked():
    enc = EncoderCfg(name="t", vocab=128, d_model=32, n_layers=1,
                     n_heads=2, d_ff=64, max_len=32)
    cfg = SP.SpladeCfg(encoder=enc, top_terms=8)
    params = SP.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 4, 128)
    mask = jnp.asarray([[True] * 10, [True] * 4 + [False] * 6])
    vec = SP.encode(params, cfg, toks, mask)
    assert vec.shape == (2, 128)
    assert float(vec.min()) >= 0.0          # log1p(relu) ≥ 0
    ids, w = SP.sparsify(vec, cfg.top_terms)
    assert ids.shape == (2, 8)
    assert float(w.min()) >= 0.0
    reg = SP.flops_reg(vec)
    assert float(reg) > 0


def test_splade_masked_tokens_do_not_leak():
    enc = EncoderCfg(name="t", vocab=128, d_model=32, n_layers=1,
                     n_heads=2, d_ff=64, max_len=32)
    cfg = SP.SpladeCfg(encoder=enc)
    params = SP.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 4, 128)
    mask = jnp.asarray([[True] * 5 + [False] * 5])
    v1 = SP.encode(params, cfg, toks, mask)
    toks2 = toks.at[:, 5:].set(9)
    v2 = SP.encode(params, cfg, toks2, mask)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=2e-4,
                               atol=2e-4)


def test_contrastive_step_reduces_loss(ccfg, cparams):
    """One epoch of in-batch-negative training on a tiny corpus lowers
    the NLL — the end-to-end trainability check."""
    from repro.training.optimizer import AdamWCfg, adamw_init, adamw_update
    rng = np.random.default_rng(1)
    B = 8
    q_toks = jnp.asarray(rng.integers(4, ccfg.encoder.vocab,
                                      (B, ccfg.query_maxlen)), jnp.int32)
    q_lens = jnp.full((B,), ccfg.query_maxlen, jnp.int32)
    d_toks = jnp.concatenate([q_toks, q_toks, q_toks[:, :ccfg.doc_maxlen
                                                     - 2 * ccfg.query_maxlen]],
                             axis=1)
    d_lens = jnp.full((B,), ccfg.doc_maxlen, jnp.int32)

    def loss_fn(params):
        q = CB.encode_queries(params, ccfg, q_toks, q_lens)
        d, dv = CB.encode_docs(params, ccfg, d_toks, d_lens)
        s = jnp.einsum("qik,bjk->qbij", q, d)
        s = jnp.where(dv[None, :, None, :], s, -1e30)
        scores = jnp.sum(jnp.maximum(jnp.max(s, -1), 0.0), -1)
        logp = jax.nn.log_softmax(scores, axis=-1)
        return -jnp.mean(jnp.diag(logp))

    params = cparams
    cfg = AdamWCfg(lr=1e-3, weight_decay=0.0, warmup_steps=0,
                   total_steps=100, min_lr_frac=1.0)
    state = adamw_init(params, cfg)
    l0 = float(loss_fn(params))
    step = jax.jit(lambda p, s: (lambda g: adamw_update(g, s, p, cfg))(
        jax.grad(loss_fn)(p)))
    for _ in range(10):
        params, state, _ = step(params, state)
    l1 = float(loss_fn(params))
    assert l1 < l0
