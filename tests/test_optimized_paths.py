"""The hillclimbed execution paths must be numerically equivalent to
their baselines — the §Perf gains are resharding, not approximation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess_jax
from repro.configs.registry import ARCHS
from repro.models import layers as L
from repro.models import transformer as T


def test_moe_local_dispatch_equals_global():
    cfg = L.MoECfg(d_model=12, d_ff_expert=16, n_experts=4, top_k=2,
                   capacity_factor=8.0)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 12))
    y_global, _ = L.moe_apply(params, cfg, x, select_threshold=0)
    for slices in (2, 4, 8):
        y_local, _ = L.moe_apply(params, cfg, x, select_threshold=0,
                                 dp_slices=slices)
        np.testing.assert_allclose(np.asarray(y_global),
                                   np.asarray(y_local), rtol=2e-5,
                                   atol=2e-5)


def test_moe_selected_expert_equals_buffer():
    """Low-batch decode path: gathering only routed experts gives the
    same outputs as the full buffer dispatch (no capacity drops)."""
    cfg = L.MoECfg(d_model=10, d_ff_expert=12, n_experts=6, top_k=2,
                   n_shared=1, d_ff_shared=12, capacity_factor=8.0)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 10))  # T·k=8 ≤ 16
    y_sel, aux_sel = L.moe_apply(params, cfg, x)               # select path
    y_buf, aux_buf = L.moe_apply(params, cfg, x, select_threshold=0)
    np.testing.assert_allclose(np.asarray(y_sel), np.asarray(y_buf),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_sel["aux_loss"]),
                               float(aux_buf["aux_loss"]), rtol=1e-5)


def test_decode_opt_window_slice_matches_full():
    """llama4-style chunked-local decode: the window-slice path scores
    identically to masked full-cache attention."""
    cfg = dataclasses.replace(ARCHS["llama4-maverick-400b-a17b"]
                              .smoke_cfg(), remat="none")
    assert any(b.attn is not None and b.attn.window > 0
               for blocks, _ in cfg.segments for b in blocks)
    params = T.init(jax.random.PRNGKey(0), cfg)
    B, steps = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, steps), 0,
                              cfg.vocab)
    outs = {}
    for opt in (False, True):
        c = dataclasses.replace(cfg, decode_opt=opt)
        caches = T.init_cache(c, B, 32)
        logits_seq = []
        for t in range(steps):
            pos = jnp.full((B, 1), t, jnp.int32)
            logits, caches = T.decode_step(params, c, toks[:, t:t + 1],
                                           pos, caches)
            logits_seq.append(np.asarray(logits))
        outs[opt] = np.stack(logits_seq)
    np.testing.assert_allclose(outs[False], outs[True], rtol=3e-4,
                               atol=3e-4)


def test_sharded_ce_formulation_equals_take_along_axis():
    cfg = ARCHS["qwen3-14b"].smoke_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1).at[0, :3].set(-100)
    base, _ = T.lm_loss(params, cfg, toks, labels)
    cfg2 = dataclasses.replace(cfg, sharded_ce=True)   # no mesh: pure math
    # sharded_ce applies a constraint only when batch_spec is set via
    # P(...); with batch_spec=None P(None, None, 'model') still needs a
    # mesh — emulate the fused formulation directly instead:
    hidden, _ = T.forward(params, cfg, toks)
    logits = T.logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    onehot = safe[..., None] == jnp.arange(cfg.vocab)
    la = jnp.sum(logits * onehot.astype(logits.dtype), -1)
    mask = (labels >= 0).astype(jnp.float32)
    fused = jnp.sum((lse - la) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    np.testing.assert_allclose(float(base), float(fused), rtol=1e-5)


@pytest.mark.slow
def test_opt_cells_compile_on_small_mesh():
    """The shard_map'd owner-compute cells lower+compile on a (2,2,2)
    multi-pod mesh with smoke configs."""
    out = run_subprocess_jax("""
import dataclasses, jax
from repro.configs.registry import ARCHS
from repro.configs import cells_opt as CO

from repro.common.compat import make_mesh
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
with mesh:
    arch = ARCHS['colbert-serve']
    cfg = arch.smoke_cfg()
    # pad the smoke index so pool rows divide the mesh
    icfg = dataclasses.replace(cfg.index, n_docs=64, avg_doclen=16)
    cfg = dataclasses.replace(cfg, index=icfg)
    cell = CO.build_plaid_opt(arch, 'serve_plaid', mesh, cfg=cfg,
                              dims={'batch': 4, 'nprobe': 2,
                                    'candidate_cap': 16, 'ndocs': 8})
    jax.jit(cell.fn).lower(*cell.args).compile()
    print('PLAID OPT OK')

    arch = ARCHS['sasrec']
    cfg = dataclasses.replace(arch.smoke_cfg(), n_items=512)
    cell = CO.build_seqrec_retrieval_opt(
        arch, 'retrieval_cand', mesh, cfg=cfg,
        dims={'batch': 1, 'n_candidates': 256})
    jax.jit(cell.fn).lower(*cell.args).compile()
    print('SEQREC OPT OK')
""")
    assert "PLAID OPT OK" in out and "SEQREC OPT OK" in out
