"""RecSys substrate: EmbeddingBag equivalences (hypothesis), per-arch
smoke train/serve/retrieval steps, TieredEmbedding paging, two-stage
retrieval recall."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import ARCHS
from repro.models.recsys import (autoint, bert4rec, dien, embedding as EB,
                                 sasrec)
from repro.models.recsys.retrieval import TwoStageParams, two_stage_retrieve

MODS = {"autoint": autoint, "dien": dien, "bert4rec": bert4rec,
        "sasrec": sasrec}


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 10),
       st.sampled_from(["sum", "mean", "max"]),
       st.integers(0, 2 ** 31 - 1))
def test_bag_lookup_matches_numpy(B, bag, mode, seed):
    rng = np.random.default_rng(seed)
    R, d = 50, 8
    table = rng.normal(size=(R, d)).astype(np.float32)
    ids = rng.integers(0, R, (B, bag)).astype(np.int32)
    valid = rng.random((B, bag)) < 0.7
    got = np.asarray(EB.bag_lookup(jnp.asarray(table), jnp.asarray(ids),
                                   jnp.asarray(valid), mode=mode))
    for b in range(B):
        rows = table[ids[b][valid[b]]]
        if len(rows) == 0:
            expected = np.zeros(d, np.float32)
        elif mode == "sum":
            expected = rows.sum(0)
        elif mode == "mean":
            expected = rows.mean(0)
        else:
            expected = rows.max(0)
        np.testing.assert_allclose(got[b], expected, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_padded_bag_equals_ragged_segment_sum(B, seed):
    """The two EmbeddingBag formulations agree (torch-parity check)."""
    rng = np.random.default_rng(seed)
    R, d, bag = 30, 4, 6
    table = jnp.asarray(rng.normal(size=(R, d)).astype(np.float32))
    ids = rng.integers(0, R, (B, bag)).astype(np.int32)
    lens = rng.integers(1, bag + 1, B)
    valid = np.arange(bag)[None] < lens[:, None]
    padded = EB.bag_lookup(table, jnp.asarray(ids), jnp.asarray(valid))
    flat_ids = np.concatenate([ids[b, :lens[b]] for b in range(B)])
    seg = np.concatenate([np.full(lens[b], b) for b in range(B)])
    ragged = EB.ragged_bag_lookup(table, jnp.asarray(flat_ids),
                                  jnp.asarray(seg), B)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(ragged),
                               rtol=1e-5, atol=1e-5)


def test_per_sample_weights():
    table = jnp.eye(4, dtype=jnp.float32)
    ids = jnp.asarray([[0, 1, 2]])
    valid = jnp.ones((1, 3), bool)
    w = jnp.asarray([[2.0, 3.0, 0.5]])
    out = EB.bag_lookup(table, ids, valid, weights=w)
    np.testing.assert_allclose(np.asarray(out[0]), [2.0, 3.0, 0.5, 0.0])


def test_pack_field_ids_offsets():
    spec = EB.FieldSpec((10, 20, 5))
    ids = jnp.asarray([[1, 2, 3]])
    rows = EB.pack_field_ids(spec, ids)
    np.testing.assert_array_equal(np.asarray(rows[0]), [1, 12, 33])
    assert spec.total_rows == 35


# ---------------------------------------------------------------------------
# TieredEmbedding — the paper's technique on tables
# ---------------------------------------------------------------------------

def test_tiered_embedding_matches_table_and_pages(tmp_path, rng):
    R, d = 1000, 16
    table = rng.normal(size=(R, d)).astype(np.float32)
    EB.TieredEmbedding.write(tmp_path, table)
    te = EB.TieredEmbedding(tmp_path, mode="mmap", block_rows=64,
                            capacity_blocks=16)
    ids = rng.integers(0, R, (3, 7))
    np.testing.assert_allclose(te.lookup_host(ids), table[ids], rtol=1e-6)
    assert te.misses > 0
    # everything fits (16 blocks): re-lookup is all cache hits
    m0 = te.misses
    te.lookup_host(ids)
    assert te.misses == m0
    assert te.hits > 0
    # a capacity-4 cache evicts under the same traffic but stays correct
    te4 = EB.TieredEmbedding(tmp_path, mode="mmap", block_rows=64,
                             capacity_blocks=4)
    np.testing.assert_allclose(te4.lookup_host(ids), table[ids], rtol=1e-6)
    assert te4.resident_bytes() <= 4 * 64 * d * 4
    assert te4.resident_bytes() < te4.total_bytes()


# ---------------------------------------------------------------------------
# per-arch smoke steps
# ---------------------------------------------------------------------------

def _smoke_batch(name, cfg, rng, B=8, kind="train"):
    if name == "autoint":
        b = {"fields": jnp.asarray(rng.integers(
            0, 60, (B, cfg.n_fields)), jnp.int32)}
        if kind == "train":
            b["label"] = jnp.asarray(rng.random(B) < 0.5, jnp.float32)
        return b
    if name == "dien":
        L = cfg.seq_len
        b = {"user": jnp.asarray(rng.integers(0, cfg.n_users, B)),
             "target_item": jnp.asarray(rng.integers(0, cfg.n_items, B)),
             "target_cate": jnp.asarray(rng.integers(0, cfg.n_cates, B)),
             "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, (B, L))),
             "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (B, L))),
             "hist_len": jnp.asarray(rng.integers(1, L, B))}
        if kind == "train":
            b["label"] = jnp.asarray(rng.random(B) < 0.5, jnp.float32)
        return b
    if name == "sasrec":
        L = cfg.seq_len
        if kind == "serve":
            return {"items": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
                    "lengths": jnp.asarray(rng.integers(1, L, B)),
                    "cand": jnp.asarray(rng.integers(1, cfg.n_items, (B, 16)))}
        return {"items": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
                "pos_labels": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
                "neg_labels": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
                "valid": jnp.ones((B, L), bool)}
    L = cfg.seq_len
    if kind == "serve":
        return {"items": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
                "lengths": jnp.asarray(rng.integers(1, L, B)),
                "cand": jnp.asarray(rng.integers(1, cfg.n_items, (B, 16)))}
    return {"items": jnp.asarray(rng.integers(1, cfg.n_items, (B, L))),
            "valid": jnp.ones((B, L), bool),
            "mask_positions": jnp.asarray(
                rng.integers(0, L, (B, cfg.n_masked))),
            "mask_labels": jnp.asarray(
                rng.integers(1, cfg.n_items, (B, cfg.n_masked))),
            "negatives": jnp.asarray(
                rng.integers(1, cfg.n_items, cfg.n_negatives))}


@pytest.mark.parametrize("name", list(MODS))
def test_recsys_smoke_train_step(name, rng):
    mod = MODS[name]
    cfg = ARCHS[name].smoke_cfg()
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(name, cfg, rng)
    (loss, m), grads = jax.value_and_grad(
        lambda p: mod.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.isfinite(x).all())
               for x in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("name", list(MODS))
def test_recsys_smoke_serve(name, rng):
    mod = MODS[name]
    cfg = ARCHS[name].smoke_cfg()
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(name, cfg, rng, kind="serve")
    out = mod.serve_score(params, cfg, batch)
    assert bool(jnp.isfinite(out).all())
    if name in ("autoint", "dien"):
        assert out.shape == (8,)
        assert float(out.min()) >= 0 and float(out.max()) <= 1
    else:
        assert out.shape == (8, 16)


def test_two_stage_retrieval_recall():
    """Stage-1 narrowing keeps the exact-model top item whenever coarse
    and exact scores correlate (the paper's rerank premise)."""
    rng = np.random.default_rng(0)
    N = 2000
    quality = rng.normal(size=N).astype(np.float32)
    coarse = quality + 0.3 * rng.normal(size=N).astype(np.float32)
    exact_full = quality + 0.05 * rng.normal(size=N).astype(np.float32)
    cand = jnp.arange(N, dtype=jnp.int32)

    def exact_fn(ids):
        return jnp.asarray(exact_full)[ids]

    ids, scores = two_stage_retrieve(jnp.asarray(coarse), exact_fn, cand,
                                     TwoStageParams(first_k=200, k=10),
                                     fuse=False)
    true_best = int(np.argmax(exact_full))
    # exact winner survives stage 1 unless coarse noise buried it
    coarse_rank = int((coarse > coarse[true_best]).sum())
    if coarse_rank < 200:
        assert true_best in np.asarray(ids)
    assert np.all(np.diff(np.asarray(scores)) <= 1e-6)


def test_sasrec_user_state_uses_last_valid_position(rng):
    cfg = ARCHS["sasrec"].smoke_cfg()
    params = sasrec.init(jax.random.PRNGKey(0), cfg)
    items = jnp.asarray(rng.integers(1, cfg.n_items, (2, cfg.seq_len)))
    u_short = sasrec.user_state(params, cfg, items, jnp.asarray([3, 3]))
    # changing items beyond the length must not change the state
    items2 = items.at[:, 5:].set(7)
    u_short2 = sasrec.user_state(params, cfg, items2, jnp.asarray([3, 3]))
    np.testing.assert_allclose(np.asarray(u_short), np.asarray(u_short2),
                               rtol=1e-5, atol=1e-5)
