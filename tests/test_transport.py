"""Layered shard transport: codec locators, fallback-codec roundtrips
(parametrized + property-based), segment-gather framing, shared-memory
ring arena mechanics (alloc/wrap/release, back-pressure, liveness,
generations), and channel-level send/recv on both transports."""

import socket
import struct
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.transport import (
    ArenaDead,
    SegmentSink,
    ShmArena,
    ShmChannel,
    StreamChannel,
    arena_path,
    decode,
    encode,
    frame_buffers,
    parse_payload,
    sendmsg_gather,
)
from repro.serving.transport import codec as tcodec
from repro.serving.transport.shm import _ALIGN, _align

# ---------------------------------------------------------------------------
# fallback codec: explicit edge-case coverage (runs without hypothesis)
# ---------------------------------------------------------------------------

_EDGE_ARRAYS = [
    np.array(3.5, dtype=np.float32),                  # 0-d
    np.zeros((), dtype=np.int64),                     # 0-d int
    np.arange(24, dtype=np.float32).reshape(4, 6)[::2, ::3],  # strided
    np.arange(10)[::-1],                              # negative stride
    np.array([True, False, True]),                    # bool
    np.arange(6, dtype=np.float16),                   # float16
    np.arange(-3, 3, dtype=np.int8),                  # int8
    np.zeros((0,), dtype=np.float64),                 # empty 1-d
    np.zeros((3, 0, 2), dtype=np.int32),              # empty mid-axis
]


def _roundtrip(val, force):
    got = decode(encode(val, force_fallback=force))
    _assert_equal(val, got)


def _assert_equal(a, b):
    if isinstance(a, np.ndarray):
        assert isinstance(b, np.ndarray)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)
    elif isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_equal(x, y)
    else:
        assert a == b


@pytest.mark.parametrize("force", [False, True],
                         ids=["msgpack", "fallback"])
@pytest.mark.parametrize("idx", range(len(_EDGE_ARRAYS)))
def test_codec_edge_arrays(force, idx):
    arr = _EDGE_ARRAYS[idx]
    _roundtrip({"a": arr, "nested": [arr, {"x": arr}]}, force)


@pytest.mark.parametrize("force", [False, True],
                         ids=["msgpack", "fallback"])
def test_codec_deeply_nested(force):
    val = {"leaf": None}
    for i in range(40):
        val = {"level": i, "inner": val, "sib": [i, str(i), float(i)]}
    _roundtrip(val, force)


def test_codec_length_guard_over_4gib():
    """4-byte count/length fields must refuse values over 4 GiB —
    a silent struct wrap would desynchronise the stream."""
    with pytest.raises(ValueError, match="4 GiB"):
        tcodec._check_u32((4 << 30) + 1, "bytes")
    assert tcodec._check_u32(4096, "bytes") == 4096

    class _FakeBig(bytes):
        def __len__(self):
            return 5 << 30

    with pytest.raises(ValueError, match="4 GiB"):
        encode({"b": _FakeBig()}, force_fallback=True)


def test_codec_locator_roundtrip_via_sink_resolver():
    """The layering seam: a sink replaces tensor bytes with locators
    at encode time; a resolver materialises them at decode time."""
    stash = {}

    class Sink:
        def put(self, arr):
            key = len(stash)
            stash[key] = np.ascontiguousarray(arr)
            return ("arena", 7, key, 0, arr.nbytes)

    def resolver(kind, dtype_str, shape, fields):
        assert kind == "arena" and fields[0] == 7
        return stash[fields[1]].reshape(shape)

    big = np.random.default_rng(0).random((50, 8)).astype(np.float32)
    msg = {"big": big, "tiny": 3}
    for force in (False, True):
        control = tcodec.encode_control(msg, Sink(),
                                        force_fallback=force)
        assert len(control) < big.nbytes       # bytes did NOT inline
        got = tcodec.decode_control(control, resolver)
        np.testing.assert_array_equal(got["big"], big)
        # without a resolver, a locator-bearing message must refuse to
        # half-decode
        with pytest.raises(ValueError, match="locator"):
            tcodec.decode_control(control, None)


# ---------------------------------------------------------------------------
# property-based roundtrips (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.recursive(
    st.one_of(
        st.none(), st.booleans(),
        st.integers(min_value=-2 ** 62, max_value=2 ** 62),
        st.floats(allow_nan=False, width=64), st.text(max_size=16),
        st.binary(max_size=24)),
    lambda leaf: st.one_of(
        st.lists(leaf, max_size=4),
        st.dictionaries(st.text(max_size=6), leaf, max_size=4)),
    max_leaves=16))
def test_fallback_codec_property_nested(value):
    _roundtrip(value, True)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["|b1", "<f2", "|i1", "<f4", "<i8", "<u2"]),
       st.lists(st.integers(min_value=0, max_value=4), min_size=0,
                max_size=3),
       st.integers(min_value=0, max_value=2 ** 31),
       st.booleans())
def test_fallback_codec_property_ndarray(dtype_str, shape, seed,
                                         transpose):
    rng = np.random.default_rng(seed)
    arr = rng.integers(-100, 100,
                       size=shape).astype(np.dtype(dtype_str))
    if transpose and arr.ndim >= 2:
        arr = arr.T                             # non-contiguous
    _roundtrip({"arr": arr}, True)


# ---------------------------------------------------------------------------
# framing: segment gather, multi-part frames
# ---------------------------------------------------------------------------

def test_segment_sink_declines_tiny_and_copies_strided():
    sink = SegmentSink(min_bytes=64)
    assert sink.put(np.arange(3, dtype=np.int8)) is None   # tiny
    strided = np.arange(64, dtype=np.float64).reshape(8, 8)[:, ::2]
    loc = sink.put(strided)
    assert loc == ("seg", 0, strided.nbytes)
    contig = np.arange(32, dtype=np.float64)
    assert sink.put(contig) == ("seg", strided.nbytes, contig.nbytes)
    assert sink.nbytes == strided.nbytes + contig.nbytes


def test_frame_gather_roundtrip_over_socketpair():
    """Multi-part frame: control + segments gathered via sendmsg on one
    side, parsed back to bitwise-equal arrays on the other."""
    a, b = socket.socketpair()
    try:
        sink = SegmentSink()
        msg = {"q": np.random.default_rng(1).random(
                   (37, 16)).astype(np.float32),
               "sel": np.arange(100, dtype=np.int64).reshape(4, 25)[:, ::5],
               "small": np.int32(7), "tag": "x"}
        control = tcodec.encode_control(msg, sink)
        bufs = frame_buffers(control, sink)
        n = sendmsg_gather(a, bufs)
        raw = b""
        while len(raw) < n:
            raw += b.recv(n - len(raw))
        assert len(raw) == n
        (length,) = struct.unpack(">Q", raw[:8])
        assert length == len(raw) - 8
        got = parse_payload(raw[8:])
        np.testing.assert_array_equal(got["q"], msg["q"])
        np.testing.assert_array_equal(got["sel"], msg["sel"])
        assert got["small"] == 7 and got["tag"] == "x"
    finally:
        a.close()
        b.close()


def test_legacy_single_part_frames_still_decode():
    from repro.serving.transport import recv_msg, send_msg

    a, b = socket.socketpair()
    try:
        msg = {"op": "ping", "payload": {"x": np.arange(5)}}
        send_msg(a, msg)
        got = recv_msg(b, timeout=5)
        np.testing.assert_array_equal(got["payload"]["x"],
                                      msg["payload"]["x"])
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# shm ring arena
# ---------------------------------------------------------------------------

def _make_arena(tmp_path, ring_bytes=1 << 20, generation=1):
    path = str(tmp_path / f"test-g{generation}.arena")
    return ShmArena.create(path, ring_bytes, generation)


def test_ring_put_take_release_and_wrap(tmp_path):
    ar = _make_arena(tmp_path)
    ring = ar.ring(0)
    rng = np.random.default_rng(2)
    # push far more bytes than the ring holds; immediate release keeps
    # it flowing and exercises the wrap-with-pad path many times over
    for i in range(400):
        arr = rng.random(1 + (i * 37) % 3000).astype(np.float64)
        kind, gen, start, span, nbytes = ring.put(arr, timeout_s=5)
        assert kind == "arena" and gen == 1 and nbytes == arr.nbytes
        assert span % _ALIGN == 0 and span >= _align(max(1, nbytes))
        view = ring.take(start, span, nbytes, arr.dtype.str,
                         list(arr.shape))
        np.testing.assert_array_equal(view, arr)
        assert not view.flags.writeable
        del view                       # finalizer releases the span
    assert ring.used_bytes() == 0
    ar.close()


def test_ring_out_of_order_release(tmp_path):
    ar = _make_arena(tmp_path)
    ring = ar.ring(1)
    locs = [ring.put(np.arange(100, dtype=np.int64), timeout_s=5)
            for _ in range(4)]
    views = [ring.take(l[2], l[3], l[4], "<i8", [100]) for l in locs]
    used = ring.used_bytes()
    assert used > 0
    del views[2]                       # hole: tail cannot advance yet
    assert ring.used_bytes() == used
    del views[0]                       # frees 0, frontier stops at 1
    assert ring.used_bytes() < used
    del views
    assert ring.used_bytes() == 0
    ar.close()


def test_ring_backpressure_times_out_as_arena_dead(tmp_path):
    ar = _make_arena(tmp_path, ring_bytes=1 << 20)
    ring = ar.ring(0)
    big = np.zeros(200_000, dtype=np.float32)      # 800 KB of 1 MB
    loc = ring.put(big, timeout_s=5)
    view = ring.take(loc[2], loc[3], loc[4], "<f4", [200_000])
    t0 = time.monotonic()
    with pytest.raises(ArenaDead, match="timed out"):
        ring.put(big, timeout_s=0.2)
    assert time.monotonic() - t0 < 2.0             # prompt, not hung
    del view
    assert ring.put(big, timeout_s=5)[0] == "arena"   # space again
    ar.close()


def test_ring_backpressure_liveness_aborts_promptly(tmp_path):
    ar = _make_arena(tmp_path, ring_bytes=1 << 20)
    ring = ar.ring(0)
    big = np.zeros(200_000, dtype=np.float32)
    loc = ring.put(big, timeout_s=5)
    view = ring.take(loc[2], loc[3], loc[4], "<f4", [200_000])  # noqa: F841
    flag = {"dead": False}

    def liveness():
        return "peer exited" if flag["dead"] else None

    def killer():
        time.sleep(0.1)
        flag["dead"] = True

    threading.Thread(target=killer, daemon=True).start()
    t0 = time.monotonic()
    with pytest.raises(ArenaDead, match="peer exited"):
        ring.put(big, timeout_s=30, liveness=liveness)
    # the 30 s deadline was NOT what fired — liveness cut it short
    assert time.monotonic() - t0 < 5.0
    ar.close()


def test_arena_open_validates_and_carries_generation(tmp_path):
    ar = _make_arena(tmp_path, generation=3)
    peer = ShmArena.open(ar.path)
    assert peer.generation == 3
    assert peer.ring_bytes == ar.ring_bytes
    loc = ar.ring(0).put(np.arange(64, dtype=np.int32), timeout_s=5)
    view = peer.ring(0).take(loc[2], loc[3], loc[4], "<i4", [64])
    np.testing.assert_array_equal(view, np.arange(64, dtype=np.int32))
    bad = tmp_path / "junk.arena"
    bad.write_bytes(b"\x00" * 4096)
    with pytest.raises(ValueError, match="not a shard arena"):
        ShmArena.open(str(bad))
    del view
    ar.unlink()
    ar.close()
    peer.close()


def test_oversize_array_falls_back_to_segment(tmp_path):
    """An array bigger than half the ring must never enter the
    back-pressure loop (it could starve forever) — it rides the socket
    frame as a segment instead."""
    from repro.serving.transport import ArenaSink

    ar = _make_arena(tmp_path, ring_bytes=1 << 20)
    seg = SegmentSink()
    sink = ArenaSink(ar.ring(0), seg, timeout_s=1)
    huge = np.zeros(300_000, dtype=np.float32)       # 1.2 MB > cap/2
    loc = sink.put(huge)
    assert loc is not None and loc[0] == "seg"
    assert sink.put(np.arange(4, dtype=np.int8)) is None   # tiny inline
    # under ARENA_MIN_BYTES the span bookkeeping costs more than the
    # memcpy it saves — mid-size arrays inline in the control frame
    assert sink.put(np.zeros(1000, dtype=np.float32)) is None
    normal = np.zeros(32768, dtype=np.float32)       # 128 KB < cap/2
    assert sink.put(normal)[0] == "arena"
    assert sink.arena_bytes == normal.nbytes
    ar.close()


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------

def _shm_pair(tmp_path, ring_bytes=4 << 20):
    path = arena_path(0, 1, str(tmp_path))
    ar = ShmArena.create(path, ring_bytes, 1)
    peer = ShmArena.open(path)
    ar.unlink()
    a, b = socket.socketpair()
    coord = ShmChannel(a, ar)
    work = ShmChannel(b, peer, tx_ring=1, rx_ring=0)
    return coord, work


@pytest.mark.parametrize("kind", ["socket", "shm"])
def test_channel_roundtrip_and_copy_accounting(tmp_path, kind):
    if kind == "socket":
        a, b = socket.socketpair()
        tx, rx = StreamChannel(a), StreamChannel(b)
    else:
        tx, rx = _shm_pair(tmp_path)
    try:
        big = np.random.default_rng(3).random(
            (2048, 32)).astype(np.float32)           # 256 KB: over the
        # inline threshold, so the shm channel must take the ring path
        msg = {"op": "score", "payload": {"q": big,
                                          "k": 10, "alpha": 0.5}}
        t = threading.Thread(target=tx.send, args=(msg,))
        t.start()
        got = rx.recv(timeout=10)
        t.join(timeout=10)
        np.testing.assert_array_equal(got["payload"]["q"], big)
        assert got["payload"]["k"] == 10
        ts = tx.stats()
        if kind == "socket":
            assert ts["bytes_copied"] >= big.nbytes
            assert ts["bytes_zero_copy"] == 0
            assert ts["bytes_sent"] >= big.nbytes
        else:
            assert ts["bytes_zero_copy"] >= big.nbytes
            assert ts["bytes_copied"] == 0
            # the socket carried only the control frame
            assert ts["bytes_sent"] < 4096
    finally:
        tx.close()
        rx.close()


def test_shm_channel_rejects_stale_generation(tmp_path):
    coord, work = _shm_pair(tmp_path)
    try:
        coord.send({"x": np.arange(32768, dtype=np.float64)})
        # corrupt the locator's generation by patching the receiver's
        # arena generation (as after a respawn landed a fresh arena)
        work.arena.generation = 2
        for ring in work.arena._rings:
            ring.generation = 2
        with pytest.raises(ArenaDead, match="generation"):
            work.recv(timeout=5)
    finally:
        coord.close()
        work.close()
