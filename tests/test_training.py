"""Training substrate: optimizer semantics (incl. 8-bit state),
checkpoint/restart exactness, preemption, straggler detection, gradient
compression with error feedback."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.training import checkpoint as C
from repro.training.compression import (CompressionCfg, compress_tree,
                                        compression_ratio, ef_init)
from repro.training.optimizer import (AdamWCfg, adamw_init, adamw_update,
                                      dequantize_q8, lr_schedule,
                                      quantize_q8)
from repro.training.train_loop import LoopCfg, SeekableData, run


# ---------------------------------------------------------------------------
# quantisation
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 300), st.floats(0.01, 100.0),
       st.integers(0, 2 ** 31 - 1))
def test_q8_roundtrip_error_bound(rows, cols, scale, seed):
    """Block-quantised roundtrip error ≤ blockmax/254 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)
    xr = dequantize_q8(quantize_q8(x), x.shape)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - xr))) <= blockmax / 127.0 + 1e-7


def test_quantized_adam_tracks_exact_adam():
    """8-bit state optimizer converges to the same optimum on a convex
    problem (within quantisation noise)."""
    W = jax.random.normal(jax.random.PRNGKey(0), (6, 1))

    def loss(params, X):
        return jnp.mean((X @ params["w"] - X @ W) ** 2)

    X = jax.random.normal(jax.random.PRNGKey(1), (128, 6))
    results = {}
    for quant in (False, True):
        cfg = AdamWCfg(lr=0.03, weight_decay=0.0, quantize_state=quant,
                       warmup_steps=5, total_steps=400)
        params = {"w": jnp.zeros((6, 1))}
        state = adamw_init(params, cfg)
        for _ in range(150):
            g = jax.grad(loss)(params, X)
            params, state, _ = adamw_update(g, state, params, cfg)
        results[quant] = float(loss(params, X))
    assert results[True] < 1e-2
    assert results[False] < 1e-3


def test_lr_schedule_shape():
    cfg = AdamWCfg(lr=1.0, warmup_steps=10, total_steps=100,
                   min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.15        # peaks near warmup end
    assert abs(lrs[-1] - 0.1) < 1e-3         # decays to min_lr_frac
    assert all(a >= b - 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # mono dec


def test_grad_clip_applied():
    cfg = AdamWCfg(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(g, state, params, cfg)
    assert float(m["grad_norm"]) > 1.0   # pre-clip norm reported


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------

def _make_problem():
    W = jax.random.normal(jax.random.PRNGKey(42), (5, 1))

    def make_batch(step):
        k = jax.random.PRNGKey(1000 + step)
        X = jax.random.normal(k, (16, 5))
        return {"x": X, "y": X @ W}

    def loss_fn(params, batch):
        l = jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
        return l, {"mse": l}

    return make_batch, loss_fn


def test_restart_is_bit_exact(tmp_path):
    """5 steps + restart + 5 steps == 10 straight steps."""
    make_batch, loss_fn = _make_problem()
    opt = AdamWCfg(lr=0.05, weight_decay=0.0, warmup_steps=0,
                   total_steps=1000, min_lr_frac=1.0)
    p0 = {"w": jnp.zeros((5, 1))}

    straight, _, rep_s = run(loss_fn, p0, SeekableData(make_batch), opt,
                             LoopCfg(total_steps=10, ckpt_every=100))

    d = tmp_path / "ck"
    run(loss_fn, p0, SeekableData(make_batch), opt,
        LoopCfg(total_steps=5, ckpt_every=5, ckpt_dir=str(d)))
    resumed, _, rep_r = run(loss_fn, p0, SeekableData(make_batch), opt,
                            LoopCfg(total_steps=10, ckpt_every=5,
                                    ckpt_dir=str(d)))
    assert rep_r.resumed_from == 5
    np.testing.assert_array_equal(np.asarray(straight["w"]),
                                  np.asarray(resumed["w"]))


def test_preemption_saves_and_resumes(tmp_path):
    make_batch, loss_fn = _make_problem()
    opt = AdamWCfg(lr=0.05, weight_decay=0.0)
    p0 = {"w": jnp.zeros((5, 1))}
    d = tmp_path / "ck"
    counter = {"n": 0}

    def preempt():
        counter["n"] += 1
        return counter["n"] > 3     # preempt after 3 steps

    _, _, rep = run(loss_fn, p0, SeekableData(make_batch), opt,
                    LoopCfg(total_steps=50, ckpt_every=100,
                            ckpt_dir=str(d)), preempt_flag=preempt)
    assert rep.preempted
    assert C.latest_step(d) == rep.final_step
    _, _, rep2 = run(loss_fn, p0, SeekableData(make_batch), opt,
                     LoopCfg(total_steps=6, ckpt_every=100,
                             ckpt_dir=str(d)))
    assert rep2.resumed_from == rep.final_step
    assert rep2.final_step == 6


def test_atomic_commit_never_leaves_partial(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    C.save_checkpoint(tmp_path, 1, tree)
    C.save_checkpoint(tmp_path, 2, tree)
    # a .tmp dir from a "crashed" save must be invisible
    (tmp_path / "step_3.tmp").mkdir()
    assert C.latest_step(tmp_path) == 2
    step, loaded = C.load_checkpoint(tmp_path, template=tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(loaded["a"]), np.arange(10))


def test_checkpoint_validates_structure(tmp_path):
    tree = {"a": jnp.arange(4)}
    C.save_checkpoint(tmp_path, 1, tree)
    with pytest.raises(ValueError):
        C.load_checkpoint(tmp_path, template={"b": jnp.arange(4)})
    with pytest.raises(ValueError):
        C.load_checkpoint(tmp_path, template={"a": jnp.arange(5)})


def test_prune_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        C.save_checkpoint(tmp_path, s, tree)
    C.prune_checkpoints(tmp_path, keep=2)
    assert C.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == [4, 5]


def test_straggler_detection():
    make_batch, loss_fn = _make_problem()

    class SlowData(SeekableData):
        def batch(self, step):
            if step == 12:
                time.sleep(0.3)     # inject a straggler
            return self.make_batch(step)

    opt = AdamWCfg(lr=0.01)
    _, _, rep = run(loss_fn, {"w": jnp.zeros((5, 1))},
                    SlowData(make_batch), opt,
                    LoopCfg(total_steps=20, straggler_factor=3.0))
    assert 12 in rep.straggler_steps


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_error_feedback_accumulates_dropped_mass():
    g = {"w": jnp.asarray([1.0, 0.01, 0.02, 2.0])}
    cfg = CompressionCfg(kind="topk", topk_frac=0.5)
    ef = ef_init(g)
    sent, ef = compress_tree(g, ef, cfg)
    # top-2 kept, small entries in the residual
    np.testing.assert_allclose(np.asarray(sent["w"]), [1.0, 0, 0, 2.0])
    np.testing.assert_allclose(np.asarray(ef["w"]), [0, 0.01, 0.02, 0])
    # next round the residual is re-injected
    sent2, ef2 = compress_tree(
        {"w": jnp.asarray([0.0, 0.03, 0.0, 0.0])}, ef, cfg)
    np.testing.assert_allclose(np.asarray(sent2["w"]), [0, 0.04, 0.02, 0],
                               atol=1e-7)


@pytest.mark.parametrize("kind", ["q8", "topk"])
def test_compressed_training_still_converges(kind):
    make_batch, loss_fn = _make_problem()
    opt = AdamWCfg(lr=0.05, weight_decay=0.0, warmup_steps=0,
                   total_steps=1000, min_lr_frac=1.0)
    comp = CompressionCfg(kind=kind, topk_frac=0.25)
    _, _, rep = run(loss_fn, {"w": jnp.zeros((5, 1))},
                    SeekableData(make_batch), opt,
                    LoopCfg(total_steps=80, compression=comp))
    assert rep.losses[-1] < 0.02, rep.losses[-5:]


def test_compression_ratio_values():
    assert compression_ratio(CompressionCfg("q8")) < 0.27
    assert compression_ratio(CompressionCfg("topk", 0.01)) == 0.02
    assert compression_ratio(CompressionCfg("none")) == 1.0
