"""Multi-process shard serving: RPC codec roundtrips, process-group
parity with the in-process shard group (all four methods, mixed
batches, per-query alpha — bitwise), worker crash → ``ShardWorkerDied``
→ heal-on-restart, graceful SIGTERM drain with no orphan processes,
the pipelined engine over a process group, and the server's ephemeral
port-0 TCP bind."""

import os
import signal
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import ProcessShardGroup, build_shard_group
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import load_group, split_index_tree
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.launch.mesh import default_shard_transport
from repro.serving.engine import Request, ServeEngine
from repro.serving.rpc import (ShardWorkerDied, ShardWorkerError, decode,
                               encode)
from repro.serving.server import RetrievalServer, tcp_query

METHODS = ("splade", "rerank", "hybrid", "colbert")
PLAID = PlaidParams(nprobe=8, candidate_cap=512, ndocs=128, k=50)
MS = MultiStageParams(first_k=50, k=20)


# ---------------------------------------------------------------------------
# RPC codec
# ---------------------------------------------------------------------------

def _roundtrip_equal(val):
    for force in (False, True):           # msgpack and fallback codecs
        got = decode(encode(val, force_fallback=force))
        _assert_value_equal(val, got)


def _assert_value_equal(a, b):
    if isinstance(a, np.ndarray):
        assert isinstance(b, np.ndarray)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)
    elif isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_value_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_value_equal(x, y)
    elif isinstance(a, float) and a != a:    # NaN
        assert b != b
    else:
        assert a == b and type(b) in (type(a), int, float, bool,
                                      str, bytes, type(None))


def test_rpc_roundtrip_basic():
    _roundtrip_equal({"op": "x", "payload": {
        "none": None, "flag": True, "neg": -(2 ** 40),
        "pi": 3.140625, "s": "héllo", "b": b"\x00\xff",
        "list": [1, [2.5, "three"], {"k": None}],
        "i64": np.arange(7, dtype=np.int64).reshape(1, 7),
        "f32": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        "bool": np.array([[True, False]]),
        "empty": np.zeros((0, 4), np.float32),
    }})


def test_rpc_roundtrip_preserves_dtype_bits():
    """Scores must cross the wire bit-for-bit — the parity contract
    rests on it. Includes -inf/NaN payload bits."""
    a = np.array([np.inf, -np.inf, np.nan, -0.0, 1e-45], np.float32)
    for force in (False, True):
        got = decode(encode({"a": a}, force_fallback=force))["a"]
        np.testing.assert_array_equal(a.view(np.uint32),
                                      got.view(np.uint32))


@settings(max_examples=25, deadline=None)
@given(st.recursive(
    st.one_of(
        st.none(), st.booleans(),
        st.integers(min_value=-2 ** 62, max_value=2 ** 62),
        st.floats(allow_nan=False, width=64), st.text(max_size=20),
        st.binary(max_size=32)),
    lambda leaf: st.one_of(
        st.lists(leaf, max_size=4),
        st.dictionaries(st.text(max_size=8), leaf, max_size=4)),
    max_leaves=12))
def test_rpc_roundtrip_property(value):
    """Property roundtrip over nested scalar containers (both codecs).
    Skips when hypothesis is absent (conftest stub)."""
    _roundtrip_equal(value)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["<i8", "<i4", "<f4", "<f8", "|b1", "<u2"]),
       st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=3),
       st.integers(min_value=0, max_value=2 ** 31))
def test_rpc_roundtrip_ndarray_property(dtype_str, shape, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.integers(-100, 100, size=shape)
           .astype(np.dtype(dtype_str)))
    _roundtrip_equal({"arr": arr, "nested": [arr, {"x": arr}]})


def test_rpc_send_failure_fails_pending_without_deadlock():
    """A send onto a dead peer must raise ShardWorkerDied and fail the
    outstanding pipelined replies — from *inside* the send critical
    section (re-entrant), and without wedging on a full pipe."""
    import socket
    import threading

    from repro.serving.rpc import ShardWorkerClient

    cli = ShardWorkerClient(0, "/tmp/nowhere")
    a, b = socket.socketpair()
    cli.sock = a

    class FakeProc:
        pid = -1

        def poll(self):
            return -9

    cli.proc = FakeProc()
    rep = cli.call_async("ping", {})        # absorbed by the buffer
    b.close()                               # peer 'dies'
    failures = []

    def second_send():
        try:
            # oversized payload forces sendall to hit the closed peer
            cli.call_async("x", {"big": np.zeros(1 << 22, np.uint8)})
        except ShardWorkerDied as e:
            failures.append(e)

    t = threading.Thread(target=second_send, daemon=True)
    t.start()
    t.join(timeout=10)
    a.close()
    assert not t.is_alive(), "sender deadlocked marking the peer dead"
    assert failures
    assert rep.event.is_set() and isinstance(rep.error, ShardWorkerDied)


# ---------------------------------------------------------------------------
# group fixtures (one spawn for the whole module — workers import jax)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, small_corpus):
    base = tmp_path_factory.mktemp("pgroup_base")
    build_colbert_index(base / "colbert", small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    build_splade_index(small_corpus["doc_term_ids"],
                       small_corpus["doc_term_weights"],
                       small_corpus["cfg"].vocab,
                       small_corpus["cfg"].n_docs).save(base / "splade")
    return base


@pytest.fixture(scope="module")
def unsharded(base_dir):
    index = ColBERTIndex(base_dir / "colbert", mode="mmap")
    sidx = SpladeIndex.load(base_dir / "splade", mmap=True)
    return MultiStageRetriever(sidx, PLAIDSearcher(index, PLAID), MS)


@pytest.fixture(scope="module")
def thread_group(base_dir, small_corpus):
    group = split_index_tree(base_dir, 2)
    dirs, bounds = load_group(group)
    return build_shard_group(dirs, bounds, workers="thread",
                             mode="mmap", plaid_params=PLAID,
                             multistage_params=MS)


@pytest.fixture(scope="module")
def process_group(base_dir, thread_group):
    dirs, bounds = load_group(base_dir / "shards")
    g = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                          plaid_params=PLAID, multistage_params=MS)
    yield g
    g.close()
    for cli in g._clients:
        assert cli is None or cli.proc.poll() is not None


@pytest.fixture(scope="module")
def socket_group(base_dir, thread_group):
    """Same shards, stream transport — the cross-transport parity
    reference."""
    dirs, bounds = load_group(base_dir / "shards")
    g = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                          plaid_params=PLAID, multistage_params=MS,
                          transport="socket")
    yield g
    g.close()


def _batch(corpus, lo, hi):
    return dict(q_embs=corpus["q_embs"][lo:hi],
                term_ids=corpus["q_term_ids"][lo:hi],
                term_weights=corpus["q_term_weights"][lo:hi])


def _assert_bitwise(ref, got):
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))


# ---------------------------------------------------------------------------
# parity: process workers == thread workers == shards=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_process_parity_per_method(unsharded, thread_group,
                                   process_group, small_corpus, method):
    kw = _batch(small_corpus, 0, 6)
    ref = unsharded.search_batch(method, k=15, **kw)
    thr = thread_group.search_batch(method, k=15, **kw)
    got = process_group.search_batch(method, k=15, **kw)
    # pid-identical to the single index…
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
    # …and bitwise (pids AND scores) to the in-process shard group
    _assert_bitwise(thr, got)


def test_process_parity_mixed_batch_and_alpha(thread_group,
                                              process_group,
                                              small_corpus):
    methods = [METHODS[i % 4] for i in range(8)]
    alphas = [None, 0.1, 0.9, None, 0.5, 0.3, None, 0.7]
    kw = _batch(small_corpus, 0, 8)
    thr = thread_group.search_batch(methods, alpha=alphas, k=10, **kw)
    got = process_group.search_batch(methods, alpha=alphas, k=10, **kw)
    _assert_bitwise(thr, got)


def test_process_per_query_search(thread_group, process_group,
                                  small_corpus):
    for method in ("hybrid", "colbert"):
        thr = thread_group.search(
            method, q_emb=small_corpus["q_embs"][3],
            term_ids=small_corpus["q_term_ids"][3],
            term_weights=small_corpus["q_term_weights"][3], k=12)
        got = process_group.search(
            method, q_emb=small_corpus["q_embs"][3],
            term_ids=small_corpus["q_term_ids"][3],
            term_weights=small_corpus["q_term_weights"][3], k=12)
        _assert_bitwise(thr, got)


def test_process_group_stage1_api(thread_group, process_group,
                                  small_corpus):
    """``run_splade_batch`` (the benchmark entry point) matches the
    thread group's group-wide stage 1."""
    tids = list(small_corpus["q_term_ids"][:4])
    tw = list(small_corpus["q_term_weights"][:4])
    thr = thread_group.run_splade_batch(tids, tw, 20)
    got = process_group.run_splade_batch(tids, tw, 20)
    _assert_bitwise(thr, got)


def test_process_parity_jax_stage1_backend(thread_group, process_group,
                                           small_corpus):
    """Device stage-1 backend: workers build their own padded-postings
    device caches (warmed via the ``warm`` RPC) and must match the
    in-process group bitwise."""
    kw = _batch(small_corpus, 0, 5)
    try:
        thread_group.set_splade_backend("jax")
        process_group.set_splade_backend("jax")
        process_group.splade_device_cache()      # broadcast warm
        thr = thread_group.search_batch("splade", k=10, **kw)
        got = process_group.search_batch("splade", k=10, **kw)
        _assert_bitwise(thr, got)
    finally:
        thread_group.set_splade_backend("host")
        process_group.set_splade_backend("host")


def test_worker_health_reports_split_pool(unsharded, process_group):
    """Shared-nothing check: each worker maps only its segment — the
    segments sum to the single pool and no worker holds it all."""
    health = process_group.worker_health()
    assert all(w["alive"] for w in health)
    total = unsharded.searcher.index.store.total_bytes()
    seg = [w["pool_bytes"] for w in health]
    assert sum(seg) == total
    assert max(seg) < total
    assert all(w["rss_bytes"] > 0 for w in health)
    assert all(w["rpc_bytes_sent"] > 0 for w in health)


# ---------------------------------------------------------------------------
# lifecycle: crash → ShardWorkerDied → heal; SIGTERM drain; no orphans
# ---------------------------------------------------------------------------

def test_worker_crash_raises_then_heals(process_group, thread_group,
                                        small_corpus):
    kw = _batch(small_corpus, 0, 4)
    victim = process_group._clients[0].proc
    os.kill(victim.pid, signal.SIGKILL)          # hard crash
    victim.wait(timeout=10)
    with pytest.raises(ShardWorkerDied):
        process_group.search_batch("rerank", k=10, **kw)
    # heal-on-restart: the next batch respawns the worker and serves
    got = process_group.search_batch("rerank", k=10, **kw)
    thr = thread_group.search_batch("rerank", k=10, **kw)
    _assert_bitwise(thr, got)
    assert process_group.restarts[0] == 1
    assert all(process_group.heartbeat())


def test_sigterm_drains_gracefully_no_orphans(process_group,
                                              thread_group,
                                              small_corpus):
    """SIGTERM = graceful drain: the worker exits 0 on its own (no
    SIGKILL escalation), leaves no orphan process, and the group heals
    on the next batch."""
    cli = process_group._clients[1]
    pid = cli.proc.pid
    os.kill(pid, signal.SIGTERM)
    assert cli.proc.wait(timeout=15) == 0        # clean exit, reaped
    with pytest.raises(ProcessLookupError):      # no orphan remains
        os.kill(pid, 0)
    kw = _batch(small_corpus, 4, 8)
    with pytest.raises(ShardWorkerDied):
        process_group.search_batch("splade", k=10, **kw)
    got = process_group.search_batch("splade", k=10, **kw)
    thr = thread_group.search_batch("splade", k=10, **kw)
    _assert_bitwise(thr, got)


def test_restart_loop_is_capped(base_dir):
    """A worker that dies again before serving one successful call is
    not respawned (single-restart healing, not a spawn storm)."""
    dirs, bounds = load_group(base_dir / "shards")
    g = ProcessShardGroup(dirs, bounds, mode="mmap", plaid_params=PLAID,
                          multistage_params=MS)
    try:
        os.kill(g._clients[0].proc.pid, signal.SIGKILL)
        g._clients[0].proc.wait(timeout=10)
        with pytest.raises(ShardWorkerDied, match="healing"):
            g._call(0, "ping", {})
        # the heal respawn — kill it again before any successful
        # group-level call can reset the restart budget
        cli = g._ensure_worker(0)
        os.kill(cli.proc.pid, signal.SIGKILL)
        cli.proc.wait(timeout=10)
        with pytest.raises(ShardWorkerDied, match="healing"):
            g._call(0, "ping", {})
        with pytest.raises(ShardWorkerDied, match="not respawning"):
            g._call(0, "ping", {})
        assert g.restarts[0] == 2
    finally:
        g.close()


def test_close_is_idempotent_and_reaps(base_dir):
    dirs, bounds = load_group(base_dir / "shards")
    g = ProcessShardGroup(dirs, bounds, mode="mmap", plaid_params=PLAID,
                          multistage_params=MS)
    pids = [c.proc.pid for c in g._clients]
    g.close()
    g.close()
    for pid in pids:
        with pytest.raises(ProcessLookupError):
            os.kill(pid, 0)
    with pytest.raises(ShardWorkerDied, match="closed"):
        g._call(0, "ping", {})


# ---------------------------------------------------------------------------
# transport: shm == socket parity, zero-copy accounting, coalescing,
# crash-under-shm promptness + fresh-arena healing
# ---------------------------------------------------------------------------

def test_parity_across_transports(unsharded, thread_group,
                                  process_group, socket_group,
                                  small_corpus):
    """shm == socket == thread workers == shards=1, bitwise, on a
    mixed batch with per-query alpha — the transport must be
    invisible to results."""
    assert socket_group.transport == "socket"
    methods = [METHODS[i % 4] for i in range(8)]
    alphas = [None, 0.2, 0.8, None, 0.5, 0.4, None, 0.6]
    kw = _batch(small_corpus, 0, 8)
    ref = unsharded.search_batch(methods, alpha=alphas, k=12, **kw)
    thr = thread_group.search_batch(methods, alpha=alphas, k=12, **kw)
    shm = process_group.search_batch(methods, alpha=alphas, k=12, **kw)
    sock = socket_group.search_batch(methods, alpha=alphas, k=12, **kw)
    np.testing.assert_array_equal(np.asarray(ref[0]),
                                  np.asarray(sock[0]))
    _assert_bitwise(thr, sock)
    _assert_bitwise(shm, sock)
    # per-method singles ride the same contract on both channels
    kw1 = _batch(small_corpus, 8, 11)
    for method in METHODS:
        _assert_bitwise(process_group.search_batch(method, k=9, **kw1),
                        socket_group.search_batch(method, k=9, **kw1))


def test_shm_default_engages_zero_copy(process_group, small_corpus):
    """On a host with writable /dev/shm the default transport is shm:
    tensors under ARENA_MIN_BYTES inline in the control frame (span
    bookkeeping costs more than a small memcpy saves), big tensors
    cross the ring without ever being serialized, and the copy split
    is visible in transport_stats / worker_health / the pipeline
    counters."""
    from repro.serving.transport.shm import ARENA_MIN_BYTES

    if default_shard_transport() != "shm":
        pytest.skip("no writable /dev/shm on this host")
    assert process_group.transport == "shm"
    process_group.search_batch("rerank", k=8,
                               **_batch(small_corpus, 0, 3))
    ts = process_group.transport_stats()
    assert ts["transport"] == "shm"
    assert all(w["transport"] == "shm" for w in ts["per_worker"])
    # the small-corpus rerank stays under the inline threshold: no
    # arena spans, and inlined tensors never count as "copied" either
    assert ts["total"]["bytes_copied"] == 0
    # drive one over-threshold round trip through every worker: the
    # request sel and the reply scores must both cross via the arena
    q = np.asarray(small_corpus["q_embs"][:4])
    q_valid = np.ones(q.shape[:2], bool)
    sel = np.zeros((4, ARENA_MIN_BYTES // 8), np.int64)  # 2x threshold
    for i in range(len(process_group._disp)):
        out = process_group._disp[i].call(
            "score_tokens", {"q": q, "q_valid": q_valid, "sel": sel})
        assert out["scores"].shape == sel.shape
    ts = process_group.transport_stats()
    assert ts["total"]["bytes_zero_copy"] >= sel.nbytes
    assert ts["total"]["bytes_copied"] == 0
    for w in process_group.worker_health():
        # every respawn bumps the arena generation in lockstep with
        # the restart counter — stale locators can't resolve
        assert (w["arena_generation"]
                == process_group.restarts[w["shard"]] + 1)
        assert w["rpc_bytes_zero_copy"] > 0
    counters = process_group.pipeline_stats.snapshot()["counters"]
    assert counters["rpc_dispatches"] > 0
    assert counters["transport_bytes_zero_copy"] > 0


def test_dispatcher_coalesces_on_busy_worker(process_group):
    """Ops enqueued while the worker is busy ride the next flush as
    one ``multi`` frame: one dispatch, per-op demux, FIFO stream
    discipline intact."""
    g = process_group
    d = g._disp[0]
    cli = g._ensure_worker(0)
    before = g.pipeline_stats.snapshot()["counters"].get(
        "rpc_coalesced_ops", 0)
    s1 = d.enqueue("ping", {})          # idle worker → flushed at once
    s2 = d.enqueue("health", {})        # busy → buffered
    s3 = d.enqueue("ping", {})          # busy → buffered with s2
    assert s1.rep is not None and s2.rep is None and s3.rep is None
    assert d.wait(s2)["pid"] == cli.pid  # flush rides one multi frame
    assert s2.rep is s3.rep and (s2.index, s3.index) == (0, 1)
    assert d.wait(s3)["ready"] and d.wait(s1)["ready"]
    after = g.pipeline_stats.snapshot()["counters"]["rpc_coalesced_ops"]
    assert after == before + 1          # two ops saved one dispatch


def test_multi_op_error_isolation(process_group):
    """A bad op inside a coalesced multi fails alone — its co-batched
    neighbours still resolve and the worker stays up."""
    process_group._ensure_worker(1)
    d = process_group._disp[1]
    s1 = d.enqueue("ping", {})
    s2 = d.enqueue("definitely_not_an_op", {})
    s3 = d.enqueue("ping", {})
    with pytest.raises(ShardWorkerError, match="unknown RPC op"):
        d.wait(s2)
    assert d.wait(s3)["ready"]          # neighbour unharmed
    assert d.wait(s1)["ready"]
    assert all(process_group.heartbeat())


def test_shm_crash_surfaces_promptly_and_heals_with_fresh_arena(
        base_dir, unsharded, small_corpus):
    """SIGKILL on the shm transport must surface ``ShardWorkerDied``
    promptly — both while the coordinator is *blocked on a ring slot*
    (worker stopped, request ring full) and while it is *waiting on a
    reply* — and each respawn heals with a fresh arena generation."""
    dirs, bounds = load_group(base_dir / "shards")
    g = ProcessShardGroup(dirs, bounds, mode="mmap", plaid_params=PLAID,
                          multistage_params=MS, transport="shm",
                          arena_bytes=1 << 20)  # 1 MiB ring: fills fast
    killer = None
    try:
        assert g.transport == "shm"

        # -- killed while the producer is blocked on ring space --------
        cli = g._ensure_worker(0)
        assert cli.arena_generation == 1
        os.kill(cli.pid, signal.SIGSTOP)      # worker stops draining
        big = {"q": np.zeros(100_000, np.float32)}     # 400 KB / call
        reps = [cli.call_async("score_tokens", big) for _ in range(2)]
        killer = threading.Timer(0.5, os.kill,
                                 (cli.pid, signal.SIGKILL))
        killer.start()
        t0 = time.monotonic()
        with pytest.raises(ShardWorkerDied):
            cli.call_async("score_tokens", big)  # blocks on ring space
        assert time.monotonic() - t0 < 15, "back-pressure wait hung"
        assert all(r.event.is_set()
                   and isinstance(r.error, ShardWorkerDied)
                   for r in reps)

        # -- killed while the coordinator waits on a reply -------------
        cli1 = g._ensure_worker(1)
        os.kill(cli1.pid, signal.SIGSTOP)     # reply can never finish
        rep = cli1.call_async("splade", {
            "term_ids": [small_corpus["q_term_ids"][0]],
            "term_weights": [small_corpus["q_term_weights"][0]],
            "k": 5})
        os.kill(cli1.pid, signal.SIGKILL)
        t0 = time.monotonic()
        with pytest.raises(ShardWorkerDied):
            cli1.wait(rep, timeout=60)
        assert time.monotonic() - t0 < 15, "reply wait hung"

        # -- heal: fresh arena generation per respawn ------------------
        for i in (0, 1):
            with pytest.raises(ShardWorkerDied, match="healing"):
                g._call(i, "ping", {})
            assert g._call(i, "ping", {})["ready"]
            assert g.restarts[i] == 1
            assert g._clients[i].arena_generation == 2
        kw = _batch(small_corpus, 0, 4)
        got = g.search_batch("hybrid", k=10, **kw)
        ref = unsharded.search_batch("hybrid", k=10, **kw)
        np.testing.assert_array_equal(np.asarray(ref[0]),
                                      np.asarray(got[0]))
    finally:
        if killer is not None:
            killer.cancel()
        g.close()


# ---------------------------------------------------------------------------
# engine / server integration
# ---------------------------------------------------------------------------

def _requests(corpus, n, methods=METHODS, k=10):
    return [Request(qid=i, method=methods[i % len(methods)],
                    q_emb=corpus["q_embs"][i],
                    term_ids=corpus["q_term_ids"][i],
                    term_weights=corpus["q_term_weights"][i], k=k)
            for i in range(n)]


def test_pipelined_engine_over_process_group(thread_group, process_group,
                                             small_corpus):
    reqs = _requests(small_corpus, 16)
    ref = ServeEngine(thread_group).process_batch(reqs)
    eng = ServeEngine(process_group, pipeline_depth=2)
    assert eng.pipelined
    futs = [eng.process_batch_async(reqs[i:i + 4])
            for i in range(0, 16, 4)]
    got = [r for f in futs for r in f.result(timeout=300)]
    eng.stop_pipelines()      # group is module-scoped: do not close it
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.pids, b.pids)
        np.testing.assert_array_equal(a.scores, b.scores)


def test_server_health_includes_shard_workers(process_group,
                                              small_corpus):
    srv = RetrievalServer(ServeEngine(process_group), n_threads=1)
    srv.start()
    try:
        for f in [srv.submit(r) for r in _requests(small_corpus, 4)]:
            f.result(timeout=120)
        h = srv.health()
        assert h["n_shards"] == 2
        workers = h["shard_workers"]
        assert len(workers) == 2
        assert all(w["alive"] for w in workers)
    finally:
        srv.stop()


def test_serve_tcp_port0_ephemeral_and_graceful(unsharded, small_corpus):
    """Port 0 binds an ephemeral port, reports the real one in
    health(), serves over TCP, and shuts down gracefully (idempotent)."""
    import threading

    srv = RetrievalServer(ServeEngine(unsharded), n_threads=1)
    srv.start()
    tcp = srv.serve_tcp("127.0.0.1", 0)
    port = srv.health()["port"]
    assert port and port > 0
    t = threading.Thread(target=tcp.serve_forever, daemon=True)
    t.start()
    try:
        out = tcp_query("127.0.0.1", port, {
            "qid": 1, "method": "splade",
            "term_ids": small_corpus["q_term_ids"][0].tolist(),
            "term_weights": small_corpus["q_term_weights"][0].tolist(),
            "k": 5})
        assert out["qid"] == 1 and len(out["pids"]) == 5
    finally:
        srv.shutdown_gracefully()
        srv.shutdown_gracefully()        # idempotent
        t.join(timeout=10)
    assert not t.is_alive()
    assert srv.health()["workers"] == 0


def test_sigterm_handler_drains_server(unsharded, small_corpus):
    """The installed SIGTERM handler completes queued work before
    stopping — clients get results, not dropped futures."""
    import signal as _signal

    srv = RetrievalServer(ServeEngine(unsharded), n_threads=1)
    srv.start()
    old = srv.install_sigterm_handler()
    try:
        futs = [srv.submit(r) for r in _requests(small_corpus, 6)]
        os.kill(os.getpid(), _signal.SIGTERM)
        for f in futs:
            assert f.result(timeout=120).pids.shape == (10,)
        deadline = time.monotonic() + 10
        while srv.health()["workers"] and time.monotonic() < deadline:
            time.sleep(0.05)
        assert srv.health()["workers"] == 0
    finally:
        _signal.signal(_signal.SIGTERM, old)
        srv.stop()


def test_group_validates_inputs(base_dir):
    with pytest.raises(ValueError, match="empty"):
        ProcessShardGroup([], [0])
    with pytest.raises(ValueError, match="boundaries"):
        ProcessShardGroup([base_dir], [0, 10, 20], autostart=False)
    with pytest.raises(ValueError, match="workers"):
        build_shard_group([base_dir], [0, 10], workers="fibers")
