"""LM substrate: per-arch reduced-config smoke tests (forward/train step
on CPU, shape + finiteness), decode/prefill consistency, attention
variants, MoE routing invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import layers as L
from repro.models import transformer as T

LM_ARCHS = [n for n, a in ARCHS.items() if a.family == "lm"]


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_arch_smoke_train_step(arch_name):
    """Reduced same-family config: one forward + loss + grad step."""
    cfg = ARCHS[arch_name].smoke_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    tokens = jax.random.randint(k, (2, 24), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    loss, metrics = jax.jit(
        lambda p: T.lm_loss(p, cfg, tokens, labels))(params)
    assert jnp.isfinite(loss), metrics
    g = jax.jit(jax.grad(lambda p: T.lm_loss(p, cfg, tokens, labels)[0]))(
        params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)
    assert float(loss) > 0


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_arch_smoke_prefill_shapes(arch_name):
    cfg = ARCHS[arch_name].smoke_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab)
    logits = jax.jit(lambda p, t: T.prefill(p, cfg, t))(params, tokens)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_name", [
    "qwen3-14b",
    pytest.param("deepseek-v3-671b", marks=pytest.mark.xfail(
        reason="known CPU-only numeric flake (MLA decode tolerance) — "
               "see ROADMAP.md 'Known seed flake'", strict=False)),
])
def test_decode_matches_prefill(arch_name):
    """Greedy decode logits at position t must match a full forward over
    the same prefix (KV-cache correctness, GQA and MLA paths)."""
    cfg = dataclasses.replace(ARCHS[arch_name].smoke_cfg(), use_mtp=False,
                              remat="none")
    params = T.init(jax.random.PRNGKey(0), cfg)
    B, Lp = 2, 7
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, Lp), 0,
                                cfg.vocab)
    # full forward logits at every position
    hidden, _ = T.forward(params, cfg, tokens)
    full_logits = T.logits_from_hidden(params, cfg, hidden)
    # incremental decode
    caches = T.init_cache(cfg, B, 16)
    dec = jax.jit(lambda p, t, pos, c: T.decode_step(p, cfg, t, pos, c))
    for t in range(Lp):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, caches = dec(params, tokens[:, t:t + 1], pos, caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_window_attention_masks_past():
    """Chunked-local attention (iRoPE): tokens beyond the window are
    invisible; inside the window results equal full attention."""
    k = jax.random.PRNGKey(0)
    B, Lq, H, h = 1, 12, 2, 8
    q = jax.random.normal(k, (B, Lq, H, h))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, Lq, H, h))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, Lq, H, h))
    pos = jnp.arange(Lq)[None]
    full = L.dense_attention(q, kk, v, causal=True, q_positions=pos,
                             kv_positions=pos)
    w_big = L.dense_attention(q, kk, v, causal=True, q_positions=pos,
                              kv_positions=pos, window=Lq + 5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(w_big),
                               rtol=1e-5, atol=1e-5)
    w4 = L.dense_attention(q, kk, v, causal=True, q_positions=pos,
                           kv_positions=pos, window=4)
    # early positions (< window) agree with full attention
    np.testing.assert_allclose(np.asarray(full[:, :4]),
                               np.asarray(w4[:, :4]), rtol=1e-5, atol=1e-5)
    # late positions must differ (history truncated)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(w4[:, -1]))
    # blockwise agrees with dense under the same window
    bw = L.blockwise_attention(q, kk, v, causal=True, q_positions=pos,
                               kv_positions=pos, chunk=5, window=4)
    np.testing.assert_allclose(np.asarray(bw), np.asarray(w4), rtol=2e-4,
                               atol=2e-4)


def test_moe_routing_top1_selects_argmax():
    cfg = L.MoECfg(d_model=16, d_ff_expert=8, n_experts=4, top_k=1)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 16))
    w, ids, aux = L.moe_route(params, cfg, x)
    logits = x @ params["router"]
    np.testing.assert_array_equal(np.asarray(ids[:, 0]),
                                  np.argmax(np.asarray(logits), -1))
    assert float(aux["aux_loss"]) >= 0.99  # ≥1 at perfect balance


def test_moe_capacity_overflow_drops_not_corrupts():
    """With capacity_factor → tiny, outputs stay finite and dropped
    tokens contribute 0 (not garbage)."""
    cfg = L.MoECfg(d_model=8, d_ff_expert=8, n_experts=2, top_k=1,
                   capacity_factor=0.01)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y, _ = L.moe_apply(params, cfg, x)
    assert bool(jnp.isfinite(y).all())
    # capacity C = max(8, ...) = 8 per expert → ≤16 of 64 tokens served
    nonzero = jnp.sum(jnp.any(y[0] != 0, axis=-1))
    assert int(nonzero) <= 16


def test_moe_matches_dense_expert_loop():
    """Buffer-dispatch MoE equals the naive per-token expert loop."""
    cfg = L.MoECfg(d_model=12, d_ff_expert=16, n_experts=4, top_k=2,
                   capacity_factor=8.0)   # no drops
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 12))
    y, _ = L.moe_apply(params, cfg, x)

    w, ids, _ = L.moe_route(params, cfg, x.reshape(-1, 12))
    expected = np.zeros((9, 12), np.float32)
    for t in range(9):
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            g = np.asarray(x.reshape(-1, 12)[t] @ params["experts_w_gate"][e])
            u = np.asarray(x.reshape(-1, 12)[t] @ params["experts_w_up"][e])
            hsilu = g / (1 + np.exp(-g)) * u
            expected[t] += float(w[t, j]) * (
                hsilu @ np.asarray(params["experts_w_down"][e]))
    np.testing.assert_allclose(np.asarray(y[0]), expected, rtol=2e-4,
                               atol=2e-4)


def test_mtp_loss_increases_total():
    cfg = ARCHS["deepseek-v3-671b"].smoke_cfg()
    assert cfg.use_mtp
    params = T.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    loss, m = T.lm_loss(params, cfg, tokens, labels)
    assert "mtp_loss" in m and float(m["mtp_loss"]) > 0
    assert float(loss) > float(m["ce_loss"])


def test_label_masking():
    cfg = ARCHS["qwen3-14b"].smoke_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    all_masked = jnp.full_like(labels, -100)
    loss_m, _ = T.lm_loss(params, cfg, tokens, all_masked)
    assert float(loss_m) == 0.0
