"""Cross-query batched execution: batched == sequential for every stage
(kernels, PLAID, multi-stage methods, server micro-batcher), stage-3
codes-only access, and shutdown semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams, pad_query_batch
from repro.index.builder import ColBERTIndex
from repro.index.splade_index import build_splade_index
from repro.kernels.decompress_maxsim.ops import (
    decompress_maxsim_scores,
    decompress_maxsim_scores_batch,
)
from repro.kernels.maxsim.ops import maxsim_scores, maxsim_scores_batch
from repro.serving.engine import Request, ServeEngine
from repro.serving.server import RetrievalServer

METHODS = ("colbert", "splade", "rerank", "hybrid")


# ---------------------------------------------------------------------------
# batched kernels == per-query loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl,block_c", [("ref", 16), ("interpret", 4)])
def test_maxsim_batch_equals_loop(impl, block_c):
    B, C, Ld, Lq, d = 3, 20, 12, 8, 32
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, Lq, d))
    docs = jax.random.normal(jax.random.fold_in(k, 1), (B, C, Ld, d))
    dv = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.8, (B, C, Ld))
    qv = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.9, (B, Lq))
    batch = maxsim_scores_batch(q, docs, dv, qv, impl=impl, block_c=block_c)
    loop = jnp.stack([maxsim_scores(q[b], docs[b], dv[b], qv[b], impl="ref")
                      for b in range(B)])
    np.testing.assert_allclose(np.asarray(batch), np.asarray(loop),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl,block_c", [("ref", 16), ("interpret", 4)])
def test_decompress_maxsim_batch_equals_loop(impl, block_c):
    B, C, Ld, Lq, d, nbits, K = 3, 20, 12, 8, 32, 4, 16
    k = jax.random.PRNGKey(1)
    q = jax.random.normal(k, (B, Lq, d))
    packed = jax.random.randint(jax.random.fold_in(k, 1),
                                (B, C, Ld, d * nbits // 8), 0, 256
                                ).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 2), (B, C, Ld), 0, K)
    dv = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.85, (B, C, Ld))
    qv = jax.random.bernoulli(jax.random.fold_in(k, 4), 0.9, (B, Lq))
    cent = jax.random.normal(jax.random.fold_in(k, 5), (K, d))
    bw = jnp.linspace(-0.3, 0.3, 2 ** nbits)
    batch = decompress_maxsim_scores_batch(q, packed, cids, dv, cent, bw,
                                           nbits=nbits, q_valid=qv,
                                           impl=impl, block_c=block_c)
    loop = jnp.stack([decompress_maxsim_scores(q[b], packed[b], cids[b],
                                               dv[b], cent, bw, nbits=nbits,
                                               q_valid=qv[b], impl="ref")
                      for b in range(B)])
    np.testing.assert_allclose(np.asarray(batch), np.asarray(loop),
                               rtol=1e-3, atol=1e-3)


def test_pad_query_batch_ragged():
    qs = [np.ones((4, 8), np.float32), np.ones((2, 8), np.float32)]
    q, valid = pad_query_batch(qs)
    assert q.shape == (2, 4, 8)
    assert np.asarray(valid).tolist() == [[True] * 4,
                                          [True, True, False, False]]
    np.testing.assert_array_equal(np.asarray(q[1, 2:]), 0.0)


# ---------------------------------------------------------------------------
# PLAID / multistage stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stack(built_index, small_corpus):
    index = ColBERTIndex(built_index, mode="mmap")
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                                ndocs=128, k=50))
    sidx = build_splade_index(small_corpus["doc_term_ids"],
                              small_corpus["doc_term_weights"],
                              small_corpus["cfg"].vocab,
                              small_corpus["cfg"].n_docs)
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=50, k=20))
    return index, searcher, retr


def _ragged_queries(small_corpus, n):
    """Per-query embeddings with deliberately ragged lengths."""
    lens = (6, 4, 6, 5, 3, 6, 2, 5)
    return [small_corpus["q_embs"][i][:lens[i % len(lens)]]
            for i in range(n)]


def test_search_batch_equals_sequential_ragged(stack, small_corpus):
    _, searcher, _ = stack
    qs = _ragged_queries(small_corpus, 6)
    bp, bs, aux = searcher.search_batch(qs, k=20)
    for i, q in enumerate(qs):
        sp, ss, a = searcher.search(q, k=20)
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_allclose(bs[i], ss, rtol=1e-4, atol=1e-4)
        assert aux[i]["candidates"] == a["candidates"]


def test_search_batch_device_resident(built_index, small_corpus):
    index = ColBERTIndex(built_index, mode="ram")
    dev = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                           ndocs=128, k=50),
                        device_resident=True)
    qs = _ragged_queries(small_corpus, 4)
    bp, bs, _ = dev.search_batch(qs, k=15)
    for i, q in enumerate(qs):
        sp, ss, _ = dev.search(q, k=15)
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_allclose(bs[i], ss, rtol=1e-4, atol=1e-4)


def test_rerank_batch_equals_sequential(stack, small_corpus):
    _, searcher, _ = stack
    qs = _ragged_queries(small_corpus, 3)
    pids = np.stack([np.arange(30), np.arange(30) + 5,
                     np.concatenate([np.arange(20), np.full(10, -1)])])
    batch = searcher.rerank_batch(qs, pids)
    for i, q in enumerate(qs):
        np.testing.assert_allclose(batch[i], searcher.rerank(q, pids[i]),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("method", METHODS)
def test_multistage_batch_equals_sequential(stack, small_corpus, method):
    _, _, retr = stack
    B = 5
    args = dict(
        q_embs=[small_corpus["q_embs"][i] for i in range(B)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(B)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(B)])
    bp, bs = retr.search_batch(method, k=15, **args)
    for i in range(B):
        sp, ss = retr.search(method, q_emb=args["q_embs"][i],
                             term_ids=args["term_ids"][i],
                             term_weights=args["term_weights"][i], k=15)
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_allclose(bs[i], ss, rtol=1e-3, atol=1e-3)


def test_multistage_batch_mixed_methods(stack, small_corpus):
    _, _, retr = stack
    methods = ["hybrid", "colbert", "rerank", "splade", "hybrid", "rerank"]
    alphas = [0.2, None, None, None, 0.7, None]
    n = len(methods)
    args = dict(
        q_embs=[small_corpus["q_embs"][i] for i in range(n)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(n)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(n)])
    bp, bs = retr.search_batch(methods, alpha=alphas, k=10, **args)
    for i, m in enumerate(methods):
        sp, ss = retr.search(m, q_emb=args["q_embs"][i],
                             term_ids=args["term_ids"][i],
                             term_weights=args["term_weights"][i],
                             alpha=alphas[i], k=10)
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_allclose(bs[i], ss, rtol=1e-3, atol=1e-3)


def test_hybrid_scores_with_neg_inf_padding_stay_finite():
    """-inf at padded slots (rerank scores of -1 pids) must not poison
    the masked normalisation stats with NaN."""
    from repro.core.hybrid import hybrid_scores
    s = jnp.asarray([3.0, 2.0, 0.0])
    c = jnp.asarray([5.0, 4.0, -jnp.inf])
    mask = jnp.asarray([True, True, False])
    out = np.asarray(hybrid_scores(s, c, mask, alpha=0.3))
    assert np.isfinite(out[:2]).all(), out
    assert np.isneginf(out[2])


def test_mixed_batch_k_beyond_first_k(stack, small_corpus):
    """k > first_k in a mixed batch: splade-first groups fill only
    min(k, first_k) columns; the rest is (-1, -inf) padding, and the
    colbert group fills its full k."""
    _, _, retr = stack
    first_k = retr.params.first_k
    k = first_k + 10
    methods = ["colbert", "hybrid"]
    bp, bs = retr.search_batch(
        methods, k=k,
        q_embs=[small_corpus["q_embs"][i] for i in range(2)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(2)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(2)])
    assert bp.shape == (2, k)
    assert (bp[1, first_k:] == -1).all()
    assert np.isneginf(bs[1, first_k:]).all()


def test_k_zero_and_explicit_k_honored(stack, small_corpus):
    """A k=0 request must not silently become k=params.k (regression for
    the falsy ``k or p.k`` default)."""
    _, searcher, retr = stack
    q = small_corpus["q_embs"][0]
    pids, scores, _ = searcher.search(q, k=0)
    assert pids.shape == (0,) and scores.shape == (0,)
    sp, ss = retr.search("hybrid", q_emb=q,
                         term_ids=small_corpus["q_term_ids"][0],
                         term_weights=small_corpus["q_term_weights"][0], k=0)
    assert sp.shape == (0,)
    bp, bs, _ = searcher.search_batch([q, q], k=0)
    assert bp.shape == (2, 0)


# ---------------------------------------------------------------------------
# stage-3 access minimisation (the paper's claim, now enforced)
# ---------------------------------------------------------------------------

def test_codes_only_gather_touches_zero_residual_pages(stack):
    index, _, _ = stack
    index.store.stats.reset()
    index.gather_doc_codes(np.arange(32))
    st = index.store.stats
    assert st.gathers == 1 and st.tokens_read > 0
    assert st.pages_touched == 0
    assert len(st.unique_pages) == 0
    assert st.residual_gathers == 0
    assert st.residual_tokens_read == 0


def test_stage3_touches_zero_residual_pages(stack, small_corpus):
    """Full mmap search faults residual pages in stage 4 ONLY: exactly
    one residual gather, covering the ``ndocs`` survivors — stages 1-3
    stay codes-only."""
    index, searcher, _ = stack
    index.store.stats.reset()
    searcher.search(small_corpus["q_embs"][0], k=10)
    st = index.store.stats
    assert st.residual_gathers == 1
    assert st.residual_tokens_read == \
        searcher.params.ndocs * index.doc_maxlen
    # the codes-only stage-3 gather still happened (and was accounted)
    assert st.gathers == 2
    assert st.tokens_read > st.residual_tokens_read


def test_batched_gathers_share_pages(stack, small_corpus):
    """Duplicate queries co-batched touch the same residual pages once —
    the shared-page-touch benefit the micro-batcher exists for."""
    index, searcher, _ = stack
    q = small_corpus["q_embs"][1]
    index.store.stats.reset()
    searcher.search(q, k=10)
    single = index.store.stats.pages_touched
    index.store.stats.reset()
    searcher.search_batch([q, q, q], k=10)
    batched = index.store.stats.pages_touched
    assert batched == single


# ---------------------------------------------------------------------------
# server-level micro-batching
# ---------------------------------------------------------------------------

def _requests(small_corpus, n, k=10):
    return [Request(qid=i, method=METHODS[i % len(METHODS)],
                    q_emb=small_corpus["q_embs"][i],
                    term_ids=small_corpus["q_term_ids"][i],
                    term_weights=small_corpus["q_term_weights"][i], k=k)
            for i in range(n)]


def test_server_microbatch_equals_sequential(stack, small_corpus):
    _, _, retr = stack
    n = 16
    seq_srv = RetrievalServer(ServeEngine(retr), n_threads=1)
    seq_srv.start()
    seq = [seq_srv.submit(r).result(timeout=60)
           for r in _requests(small_corpus, n)]
    seq_srv.stop()

    bat_srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=8,
                              batch_timeout_ms=25)
    bat_srv.start()
    futs = [bat_srv.submit(r) for r in _requests(small_corpus, n)]
    bat = [f.result(timeout=60) for f in futs]
    assert bat_srv.health()["served"] == n
    bat_srv.stop()

    for r_seq, r_bat in zip(seq, bat):
        assert r_seq.qid == r_bat.qid
        np.testing.assert_array_equal(r_seq.pids, r_bat.pids)
        np.testing.assert_allclose(r_seq.scores, r_bat.scores,
                                   rtol=1e-3, atol=1e-3)


def test_microbatch_respects_per_request_k(stack, small_corpus):
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=4,
                          batch_timeout_ms=25)
    srv.start()
    reqs = _requests(small_corpus, 4, k=10)
    for r, want in zip(reqs, (3, 10, 7, 1)):
        r.k = want
    futs = [srv.submit(r) for r in reqs]
    for r, fut in zip(reqs, futs):
        assert len(fut.result(timeout=60).pids) == r.k
    srv.stop()


def test_stop_fails_queued_futures(stack, small_corpus):
    """stop() must not leave enqueued-but-unserved futures pending."""
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr), n_threads=1)
    # never started: nothing drains the queue
    futs = [srv.submit(r) for r in _requests(small_corpus, 3)]
    srv.stop()
    for fut in futs:
        assert fut.done()
        with pytest.raises(RuntimeError, match="server stopped"):
            fut.result(timeout=1)


def test_cancelled_future_does_not_kill_worker(stack, small_corpus):
    """A client cancelling a queued request must not crash the worker or
    disturb co-batched neighbours (regression: double-resolution raised
    InvalidStateError inside the worker thread)."""
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=4,
                          batch_timeout_ms=25)
    futs = [srv.submit(r) for r in _requests(small_corpus, 4)]
    assert futs[1].cancel()          # cancelled while still queued
    srv.start()
    for i in (0, 2, 3):
        assert len(futs[i].result(timeout=60).pids) > 0
    # worker survived and keeps serving
    extra = srv.submit(_requests(small_corpus, 1)[0])
    assert len(extra.result(timeout=60).pids) > 0
    assert srv.health()["workers"] == 1
    srv.stop()


def test_microbatch_isolates_poisoned_request(stack, small_corpus):
    """One bad request in a coalesced batch fails alone; its co-batched
    neighbours still succeed."""
    _, _, retr = stack
    srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=4,
                          batch_timeout_ms=25)
    srv.start()
    reqs = _requests(small_corpus, 4)
    reqs[2].method = "no-such-method"
    futs = [srv.submit(r) for r in reqs]
    with pytest.raises(ValueError):
        futs[2].result(timeout=60)
    for i in (0, 1, 3):
        assert len(futs[i].result(timeout=60).pids) > 0
    assert srv.health()["failed"] == 1
    srv.stop()
