"""Multi-device integration tests (subprocess with 8 fake CPU devices):
pjit train parity vs single device, elastic checkpoint re-shard,
compressed cross-pod psum, sharding-rule coverage, dry-run micro-cell,
HLO analyzer ground truth."""

import pytest

from conftest import run_subprocess_jax

pytestmark = pytest.mark.slow


def test_pjit_train_matches_single_device():
    """The same train step on a (2,4) mesh and on 1 device produces the
    same loss trajectory — sharding must not change numerics."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.optimizer import AdamWCfg, adamw_init, adamw_update
from repro.common.compat import make_mesh

W = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
def loss_fn(params, batch):
    p = jnp.tanh(batch['x'] @ params['w1']) @ params['w2']
    return jnp.mean((p - batch['y'])**2)

def trajectory(mesh=None):
    params = {'w1': jnp.zeros((16, 16)) + 0.01, 'w2': jnp.zeros((16, 8)) + 0.01}
    cfg = AdamWCfg(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=100, min_lr_frac=1.0)
    state = adamw_init(params, cfg)
    if mesh is not None:
        sh = NamedSharding(mesh, P('data', None))
        rep = NamedSharding(mesh, P())
        params = jax.tree.map(lambda x: jax.device_put(x, rep), params)
    @jax.jit
    def step(params, state, batch):
        g = jax.grad(loss_fn)(params, batch)
        return adamw_update(g, state, params, cfg)[:2]
    losses = []
    for s in range(8):
        k = jax.random.PRNGKey(s)
        x = jax.random.normal(k, (32, 16)); y = jnp.tanh(x @ W[:, :16][:, :16])[:, :8]
        batch = {'x': x, 'y': y}
        if mesh is not None:
            batch = {k2: jax.device_put(v, NamedSharding(mesh, P('data', None))) for k2, v in batch.items()}
        losses.append(float(loss_fn(params, batch)))
        params, state = step(params, state, batch)
    return losses

l1 = trajectory(None)
mesh = make_mesh((4, 2), ('data', 'model'))
with mesh:
    l2 = trajectory(mesh)
np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
print('PARITY OK')
""")
    assert "PARITY OK" in out


def test_elastic_restore_across_meshes():
    """Checkpoint written under a (4,2) mesh restores onto (2,4) and a
    single device — elastic re-shard on restore."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training import checkpoint as C
from repro.common.compat import make_mesh

tree = {'w': jax.random.normal(jax.random.PRNGKey(0), (8, 16)),
        'b': jnp.arange(16.0)}
mesh_a = make_mesh((4, 2), ('data', 'model'))
sh_a = {'w': NamedSharding(mesh_a, P('data', 'model')), 'b': NamedSharding(mesh_a, P('model'))}
placed = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh_a)
with tempfile.TemporaryDirectory() as d:
    C.save_checkpoint(d, 3, placed)
    mesh_b = make_mesh((2, 4), ('data', 'model'))
    sh_b = {'w': NamedSharding(mesh_b, P('model', 'data')), 'b': NamedSharding(mesh_b, P())}
    step, restored = C.load_checkpoint(d, template=tree, shardings=sh_b)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored['w']), np.asarray(tree['w']))
    assert restored['w'].sharding == sh_b['w']
    step, single = C.load_checkpoint(d, template=tree)
    np.testing.assert_array_equal(np.asarray(single['b']), np.asarray(tree['b']))
print('ELASTIC OK')
""")
    assert "ELASTIC OK" in out


def test_q8_psum_across_pod_axis():
    """int8-compressed all-reduce over a real 8-way axis ≈ exact psum."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.training.compression import q8_psum
from repro.common.compat import make_mesh
mesh = make_mesh((8,), ('pod',))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 256))
exact = jnp.sum(x, axis=0)
f = shard_map(lambda v: q8_psum(v[0], 'pod'), mesh=mesh,
              in_specs=P('pod'), out_specs=P())
approx = f(x)
rel = float(jnp.max(jnp.abs(approx - exact)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel
print('Q8PSUM OK', rel)
""")
    assert "Q8PSUM OK" in out


def test_dryrun_micro_cell_compiles_multipod():
    """A miniature multi-pod mesh (2,2,2) lowers + compiles an LM smoke
    train cell with the production sharding rules and shows the
    expected collectives."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import ARCHS
from repro.configs.cells import build_cell
from repro.launch import hlo_analysis

from repro.common.compat import make_mesh
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
arch = ARCHS['qwen3-14b']
with mesh:
    cell = build_cell(arch, 'train_4k', mesh, cfg=arch.smoke_cfg(),
                      dims={'global_batch': 8, 'seq': 32})
    compiled = jax.jit(cell.fn, donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
costs = hlo_analysis.analyze(compiled.as_text(), n_devices=8)
assert costs.flops > 0
assert costs.coll_bytes > 0, 'expected gradient all-reduce traffic'
print('MICROCELL OK', costs.flops, costs.coll_by_kind)
""")
    assert "MICROCELL OK" in out


def test_hlo_analyzer_scan_ground_truth():
    """Analyzer reproduces the analytic FLOPs of a scanned matmul
    (trip-count × per-layer dot) exactly."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze
from repro.common.compat import make_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
def f(ws, x):
    y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
    return y
ws = jax.ShapeDtypeStruct((12, 512, 512), jnp.float32, sharding=NamedSharding(mesh, P(None, None, 'model')))
x = jax.ShapeDtypeStruct((256, 512), jnp.float32, sharding=NamedSharding(mesh, P('data', None)))
with mesh:
    compiled = jax.jit(f).lower(ws, x).compile()
c = analyze(compiled.as_text(), n_devices=8)
expected = 12 * 2 * 128 * 512 * 128     # per-device
assert abs(c.flops - expected) / expected < 1e-6, (c.flops, expected)
assert c.coll_by_kind.get('all-gather', 0) > 0
print('ANALYZER OK')
""")
    assert "ANALYZER OK" in out


def test_recsys_sharded_lookup_matches_replicated():
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.recsys import embedding as EB
from repro.common.compat import make_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
table = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
ids = jax.random.randint(jax.random.PRNGKey(1), (16, 3), 0, 64)
with mesh:
    t_sh = jax.device_put(table, NamedSharding(mesh, P('model', None)))
    i_sh = jax.device_put(ids, NamedSharding(mesh, P('data', None)))
    out_sh = jax.jit(lambda t, i: EB.lookup(t, i, shard_axis='model'))(t_sh, i_sh)
np.testing.assert_allclose(np.asarray(out_sh), np.asarray(table)[np.asarray(ids)], rtol=1e-6)
print('LOOKUP OK')
""")
    assert "LOOKUP OK" in out


def test_pipeline_parallel_matches_sequential():
    """GPipe fill-drain over a 4-stage 'pipe' axis == applying the 4
    stages sequentially."""
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline_parallel import (bubble_fraction,
                                                 make_pipelined_fn)
from repro.common.compat import make_mesh
S, M, mb, d = 4, 8, 2, 16
mesh = make_mesh((S,), ('pipe',))
ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.3
bs = jax.random.normal(jax.random.PRNGKey(1), (S, d)) * 0.1
params = {'w': ws, 'b': bs}
xs = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))

def stage_fn(p, x):
    return jnp.tanh(x @ p['w'] + p['b'])

with mesh:
    piped = jax.jit(make_pipelined_fn(stage_fn, mesh, n_stages=S))
    got = piped(params, xs)

ref = xs
for s in range(S):
    ref = jnp.tanh(ref @ ws[s] + bs[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
assert abs(bubble_fraction(S, M) - 3/11) < 1e-9
print('PIPELINE OK')
""", n_devices=4)
    assert "PIPELINE OK" in out
