"""Live (mutable) index: delta-segment parity, tombstone filtering,
compaction-vs-rebuild bitwise equality, cache invalidation, and the
mutation-RPC safety guards.

The central invariant (checked against a from-scratch rebuild oracle
with the serve index's geometry pinned): at any quiesce point, an
interleaved upsert/delete/query trace returns bitwise-identical top-k
to rebuilding the surviving corpus, under the monotone pid map
``sorted(survivors) <-> 0..n-1`` — across shard counts and worker
backends, before and after compaction.
"""

import threading

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import (
    MUTATION_OPS,
    ProcessShardGroup,
    build_shard_group,
)
from repro.data.synth import SynthCfg, make_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.live import build_reference_indexes, map_global_to_ref
from repro.index.sharding import shard_boundaries, split_index_tree
from repro.index.splade_index import SpladeIndex, build_splade_index

# candidate_cap must not bind: the oracle rebuild changes stage-2
# candidate *sets* near the cap, so parity is only guaranteed when both
# sides keep every candidate
PLAID = PlaidParams(nprobe=4, candidate_cap=4096, ndocs=128, k=10)
MS = MultiStageParams(first_k=64, k=10)
METHODS = ("splade", "colbert", "rerank", "hybrid")
HOLD = 8          # held-out docs, upserted during the tests
DELETED = (5, 17, 100, 201)   # base pids tombstoned by mutate()


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(SynthCfg(n_docs=240, n_queries=16, vocab=512,
                                dim=32, n_topics=12, doc_maxlen=20,
                                query_maxlen=6, seed=3))


def _base_n(corpus):
    return corpus["cfg"].n_docs - HOLD


@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, corpus):
    base = tmp_path_factory.mktemp("live_base")
    n = _base_n(corpus)
    build_colbert_index(base / "colbert", corpus["doc_embs"][:n],
                        corpus["doc_lens"][:n], nbits=4, n_centroids=64,
                        kmeans_iters=4)
    build_splade_index(corpus["doc_term_ids"][:n],
                       corpus["doc_term_weights"][:n],
                       corpus["cfg"].vocab, n).save(base / "splade")
    return base


def _queries(corpus):
    return dict(q_embs=list(corpus["q_embs"]),
                term_ids=list(corpus["q_term_ids"]),
                term_weights=list(corpus["q_term_weights"]))


def _make_unsharded(base_dir):
    return MultiStageRetriever(
        SpladeIndex.load(base_dir / "splade", mmap=True),
        PLAIDSearcher(ColBERTIndex(base_dir / "colbert"), PLAID), MS)


def _mutate(retr, corpus):
    """The canonical trace: upsert the held-out docs, tombstone a few
    base docs and one delta doc. Returns the full deleted set."""
    n = _base_n(corpus)
    new_pids = [retr.live_upsert(corpus["doc_embs"][j],
                                 corpus["doc_term_ids"][j],
                                 corpus["doc_term_weights"][j],
                                 corpus["doc_lens"][j])
                for j in range(n, corpus["cfg"].n_docs)]
    assert new_pids == list(range(n, n + HOLD))   # append-only global pids
    deleted = list(DELETED) + [new_pids[2]]
    for g in deleted:
        assert retr.live_delete(g)
    return deleted


@pytest.fixture(scope="module")
def oracle(tmp_path_factory, corpus, base_dir):
    """From-scratch rebuild of the canonical trace's surviving corpus,
    with the base index's frozen geometry pinned."""
    deleted = set(DELETED) | {_base_n(corpus) + 2}
    survivors = np.array([g for g in range(corpus["cfg"].n_docs)
                          if g not in deleted], np.int64)
    idx = ColBERTIndex(base_dir / "colbert")
    rd = tmp_path_factory.mktemp("live_oracle")
    build_reference_indexes(
        rd / "colbert", rd / "splade",
        corpus["doc_embs"][survivors], corpus["doc_lens"][survivors],
        corpus["doc_term_ids"][survivors],
        corpus["doc_term_weights"][survivors], corpus["cfg"].vocab,
        centroids=idx.centroids, bucket_cutoffs=idx.bucket_cutoffs,
        bucket_weights=idx.bucket_weights, nbits=idx.nbits,
        quantum=SpladeIndex.load(base_dir / "splade").quantum)
    ref = _make_unsharded(rd)
    q = _queries(corpus)
    expected = {m: ref.search_batch(m, **q, k=10) for m in METHODS}
    return survivors, expected


def _assert_parity(retr, corpus, oracle, tag=""):
    survivors, expected = oracle
    q = _queries(corpus)
    for m in METHODS:
        lp, ls = retr.search_batch(m, **q, k=10)
        rp, rs = expected[m]
        np.testing.assert_array_equal(map_global_to_ref(lp, survivors),
                                      rp, err_msg=f"{tag} {m} pids")
        np.testing.assert_array_equal(ls, rs, err_msg=f"{tag} {m} scores")


# ---------------------------------------------------------------------------
# delta codec
# ---------------------------------------------------------------------------

def test_delta_encode_matches_builder(corpus, base_dir):
    """encode_doc quantises a document bitwise as the from-scratch
    builder does (per-row deterministic assign + encode)."""
    retr = _make_unsharded(base_dir)
    live = retr.enable_live()
    idx = retr.searcher.index
    for pid in (0, 7, 100):
        cids, packed, L = live.encode_doc(corpus["doc_embs"][pid],
                                          corpus["doc_lens"][pid])
        lo, hi = idx.doc_offsets[pid], idx.doc_offsets[pid + 1]
        assert L == idx.doclens[pid] == hi - lo
        np.testing.assert_array_equal(cids,
                                      np.asarray(idx.store.codes[lo:hi]))
        np.testing.assert_array_equal(
            packed, np.asarray(idx.store.residuals[lo:hi]))
    with pytest.raises(ValueError):
        live.encode_doc(corpus["doc_embs"][0][:, :-1])   # wrong dim
    with pytest.raises(ValueError):
        live.encode_doc(corpus["doc_embs"][0], 0)        # empty doc


def test_clean_live_serves_frozen_results(corpus, base_dir):
    retr = _make_unsharded(base_dir)
    q = _queries(corpus)
    before = {m: retr.search_batch(m, **q, k=10) for m in METHODS}
    retr.enable_live()
    assert not retr.live.dirty and retr.index_generation == 0
    for m in METHODS:
        p, s = retr.search_batch(m, **q, k=10)
        np.testing.assert_array_equal(before[m][0], p)
        np.testing.assert_array_equal(before[m][1], s)


# ---------------------------------------------------------------------------
# tombstones
# ---------------------------------------------------------------------------

def test_tombstone_filtered_and_backfilled(corpus, base_dir):
    """A deleted doc vanishes from every method's top-k, and the slot is
    backfilled (k stays full — shard-side pre-top-k exclusion, so the
    (k+1)-th doc takes its place rather than leaving a hole)."""
    retr = _make_unsharded(base_dir)
    retr.enable_live()
    q = _queries(corpus)
    p0, _ = retr.search_batch("splade", **q, k=10)
    victim = int(p0[0, 0])
    assert retr.live_delete(victim)
    assert not retr.live_delete(victim)          # double delete: no-op
    assert not retr.live_delete(10 ** 9)         # unknown pid
    for m in METHODS:
        p, s = retr.search_batch(m, **q, k=10)
        assert victim not in p
        assert (p >= 0).all() and np.isfinite(np.asarray(s)).all()


def test_deleted_doc_cached_stage1_is_not_served(corpus, base_dir):
    """Generation-salted caches: a doc cached by the stage-1/exact
    caches must not survive its own deletion (the mutation bumps the
    index generation, which invalidates every cache key)."""
    from repro.serving.context import CacheHierarchy
    from repro.serving.engine import Request, ServeEngine

    retr = _make_unsharded(base_dir)
    retr.enable_live()
    engine = ServeEngine(retr, caches=CacheHierarchy(exact_entries=64,
                                                     stage1_entries=64))
    req = lambda qid: Request(qid=qid, method="hybrid",
                              q_emb=corpus["q_embs"][0],
                              term_ids=corpus["q_term_ids"][0],
                              term_weights=corpus["q_term_weights"][0],
                              k=5)
    r0 = engine.process(req(0))
    r1 = engine.process(req(1))                  # warm: exact-cache hit
    assert r1.cache_hit and list(r1.pids) == list(r0.pids)
    victim = int(r0.pids[0])
    assert engine.live_delete(victim)
    r2 = engine.process(req(2))
    assert not r2.cache_hit                      # generation bump missed
    assert victim not in list(r2.pids)
    assert len(r2.pids) == 5


# ---------------------------------------------------------------------------
# rebuild parity (the correctness bar)
# ---------------------------------------------------------------------------

def test_unsharded_parity_and_compaction(corpus, base_dir, oracle):
    retr = _make_unsharded(base_dir)
    retr.enable_live()
    _mutate(retr, corpus)
    _assert_parity(retr, corpus, oracle, "dirty")

    gen = retr.index_generation
    out = retr.compact_live()
    assert out["compacted"] == HOLD
    assert retr.compact_live() is None           # nothing left to merge
    assert retr.index_generation > gen           # caches invalidated
    assert retr.live.n_delta == 0
    st = retr.live_stats()
    assert st["compactions"] == 1 and st["docs_compacted"] == HOLD
    assert st["tombstones"] == len(DELETED) + 1
    _assert_parity(retr, corpus, oracle, "compacted")
    # the swapped-in layout grew by the delta, pids unchanged
    assert retr.searcher.index.n_docs == corpus["cfg"].n_docs


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_thread_group_parity_and_compaction(corpus, base_dir, oracle,
                                            n_shards):
    group_dir = split_index_tree(base_dir, n_shards,
                                 group_dir=base_dir / f"sh{n_shards}")
    g = build_shard_group(
        [group_dir / str(i) for i in range(n_shards)],
        shard_boundaries(_base_n(corpus), n_shards), workers="thread",
        plaid_params=PLAID, multistage_params=MS)
    g.enable_live()
    _mutate(g, corpus)
    _assert_parity(g, corpus, oracle, f"thread x{n_shards} dirty")
    assert g.compact_live()["compacted"] == HOLD
    assert g.live.n_delta == 0 and g.index_generation > 0
    _assert_parity(g, corpus, oracle, f"thread x{n_shards} compacted")
    assert g.n_docs == corpus["cfg"].n_docs      # boundary grew


def test_process_group_parity_and_compaction(corpus, base_dir, oracle):
    group_dir = split_index_tree(base_dir, 2, group_dir=base_dir / "sh2")
    g = build_shard_group([group_dir / str(i) for i in range(2)],
                          shard_boundaries(_base_n(corpus), 2),
                          workers="process", plaid_params=PLAID,
                          multistage_params=MS)
    try:
        g.enable_live()
        _mutate(g, corpus)
        _assert_parity(g, corpus, oracle, "process x2 dirty")
        assert g.compact_live()["compacted"] == HOLD
        _assert_parity(g, corpus, oracle, "process x2 compacted")
        # mutations are replicated as writes, never as hedged/failover
        # retries
        counters = g.pipeline_stats.snapshot().get("counters", {})
        assert counters.get("hedges", 0) == 0
        assert counters.get("failover_retries", 0) == 0
        h = g.worker_health()
        assert any("live" in w for w in h)
    finally:
        g.close()


def test_query_during_compaction(corpus, base_dir, oracle):
    """Readers and the compaction swap interleave safely: queries keep
    returning the (identical) answer while the generation swap happens
    under the write gate."""
    group_dir = split_index_tree(base_dir, 2, group_dir=base_dir / "sh2")
    g = build_shard_group([group_dir / str(i) for i in range(2)],
                          shard_boundaries(_base_n(corpus), 2),
                          workers="thread", plaid_params=PLAID,
                          multistage_params=MS)
    g.enable_live()
    _mutate(g, corpus)
    q = _queries(corpus)
    expect_p, expect_s = g.search_batch("hybrid", **q, k=10)
    errors, stop = [], threading.Event()

    def reader():
        while not stop.is_set():
            try:
                p, s = g.search_batch("hybrid", **q, k=10)
                np.testing.assert_array_equal(p, expect_p)
                np.testing.assert_array_equal(s, expect_s)
            except Exception as e:   # pragma: no cover - surfaced below
                errors.append(e)
                return

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        assert g.compact_live()["compacted"] == HOLD
        p, s = g.search_batch("hybrid", **q, k=10)
        np.testing.assert_array_equal(p, expect_p)
        np.testing.assert_array_equal(s, expect_s)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert not errors, errors[0]
    _assert_parity(g, corpus, oracle, "compacted under readers")


# ---------------------------------------------------------------------------
# mutation RPCs are not hedged / not retried on siblings
# ---------------------------------------------------------------------------

class _FakeRep:
    class event:
        @staticmethod
        def is_set():
            return False


class _FakeCli:
    def __init__(self):
        self.wait_kwargs = None

    def wait(self, rep, **kw):
        self.wait_kwargs = kw
        return {"ok": True}


class _FakeReplicaSet:
    total = 2

    def __init__(self):
        self.budget_calls = 0

    def hedge_budget_ms(self, r):
        self.budget_calls += 1
        return 1.0       # would hedge almost immediately if armed

    def record_success(self, r, ms):
        pass

    def acquire(self, exclude=None):   # pragma: no cover - must not run
        raise AssertionError("mutation op acquired a sibling replica")


def _fake_group():
    g = ProcessShardGroup.__new__(ProcessShardGroup)
    g._replica_sets = [_FakeReplicaSet()]
    return g


def test_mutation_ops_never_arm_hedge_budget():
    from repro.core.sharded import _Slot

    g = _fake_group()
    for op in sorted(MUTATION_OPS):
        slot = _Slot(op, {})
        slot.cli, slot.rep, slot.replica = _FakeCli(), _FakeRep(), 0
        out = g._wait_replica(0, slot)
        assert out == {"ok": True}
        # waited without a hedge timeout: the budget was never consulted
        assert g._replica_sets[0].budget_calls == 0
        assert slot.cli.wait_kwargs == {}
    # a pure op on the same group DOES arm the budget
    slot = _Slot("splade", {})
    slot.cli, slot.rep, slot.replica = _FakeCli(), _FakeRep(), 0
    g._wait_replica(0, slot)
    assert g._replica_sets[0].budget_calls == 1
    assert slot.cli.wait_kwargs.get("timeout") is not None


def test_mutation_ops_never_resent_on_siblings():
    from repro.serving.transport import ShardWorkerDied

    from repro.core.sharded import _Slot

    g = _fake_group()
    for op in sorted(MUTATION_OPS):
        slot = _Slot(op, {})
        with pytest.raises(ShardWorkerDied, match="not retryable"):
            g._resend_slot(0, slot)
        boom = RuntimeError("original failure")
        with pytest.raises(RuntimeError, match="original failure"):
            g._resend_slot(0, slot, last_error=boom)
