"""Per-kernel validation: interpret-mode Pallas body vs pure-jnp oracle
across shape/dtype sweeps, plus hypothesis property tests on the
oracles themselves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.index.residual import unpack_codes
from repro.kernels.decompress_maxsim.ops import decompress_maxsim_scores
from repro.kernels.maxsim.ops import maxsim_scores
from repro.kernels.maxsim.ref import maxsim_scores_ref
from repro.kernels.splade_score.ops import (splade_block_scores,
                                            splade_block_scores_batch,
                                            splade_block_topk_batch)


# ---------------------------------------------------------------------------
# maxsim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C,Ld,Lq,d,block_c", [
    (16, 24, 32, 128, 16),
    (20, 8, 8, 64, 8),        # C not multiple of block (pads)
    (1, 180, 32, 128, 16),    # single candidate
    (64, 17, 5, 32, 32),      # odd doc length
])
def test_maxsim_interpret_matches_ref(C, Ld, Lq, d, block_c):
    k = jax.random.PRNGKey(C * 101 + Ld)
    q = jax.random.normal(k, (Lq, d), jnp.float32)
    docs = jax.random.normal(jax.random.fold_in(k, 1), (C, Ld, d))
    valid = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.8, (C, Ld))
    qv = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.9, (Lq,))
    a = maxsim_scores(q, docs, valid, qv, impl="interpret", block_c=block_c)
    b = maxsim_scores(q, docs, valid, qv, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxsim_dtypes(dtype):
    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (8, 64), dtype)
    docs = jax.random.normal(jax.random.fold_in(k, 1), (16, 12, 64), dtype)
    valid = jnp.ones((16, 12), bool)
    a = maxsim_scores(q, docs, valid, impl="interpret")
    b = maxsim_scores(q, docs, valid, impl="ref")
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol,
                               atol=tol)


def test_maxsim_all_invalid_doc_scores_zero():
    q = jnp.ones((4, 16))
    docs = jnp.ones((3, 5, 16))
    valid = jnp.array([[True] * 5, [False] * 5, [True] * 5])
    s = maxsim_scores(q, docs, valid, impl="ref")
    assert float(s[1]) == 0.0


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(2, 12), st.integers(2, 8),
       st.integers(0, 2 ** 31 - 1))
def test_maxsim_doc_token_permutation_invariant(C, Ld, Lq, seed):
    """MaxSim is a max over doc tokens — permuting them is a no-op."""
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (Lq, 16))
    docs = jax.random.normal(jax.random.fold_in(k, 1), (C, Ld, 16))
    valid = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.7, (C, Ld))
    perm = jax.random.permutation(jax.random.fold_in(k, 3), Ld)
    a = maxsim_scores_ref(q, docs, valid)
    b = maxsim_scores_ref(q, docs[:, perm], valid[:, perm])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_maxsim_padding_tokens_never_change_scores(C, Ld, seed):
    """Appending invalid tokens must not move any score."""
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (4, 8))
    docs = jax.random.normal(jax.random.fold_in(k, 1), (C, Ld, 8))
    valid = jnp.ones((C, Ld), bool)
    pad = 100.0 * jax.random.normal(jax.random.fold_in(k, 2), (C, 3, 8))
    docs2 = jnp.concatenate([docs, pad], axis=1)
    valid2 = jnp.concatenate([valid, jnp.zeros((C, 3), bool)], axis=1)
    np.testing.assert_allclose(np.asarray(maxsim_scores_ref(q, docs, valid)),
                               np.asarray(maxsim_scores_ref(q, docs2, valid2)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decompress_maxsim (fused)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits,gather,C,Ld,K", [
    (4, "take", 16, 24, 64),
    (4, "onehot", 16, 24, 64),
    (2, "take", 8, 12, 32),
    (2, "onehot", 24, 8, 16),
])
def test_decompress_maxsim_interpret_matches_ref(nbits, gather, C, Ld, K):
    d = 64
    k = jax.random.PRNGKey(nbits * 7 + C)
    q = jax.random.normal(k, (16, d))
    packed = jax.random.randint(jax.random.fold_in(k, 1),
                                (C, Ld, d * nbits // 8), 0, 256, jnp.int32
                                ).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 2), (C, Ld), 0, K)
    valid = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.85, (C, Ld))
    cent = jax.random.normal(jax.random.fold_in(k, 4), (K, d))
    bw = jnp.linspace(-0.3, 0.3, 2 ** nbits)
    a = decompress_maxsim_scores(q, packed, cids, valid, cent, bw,
                                 nbits=nbits, impl="interpret",
                                 gather=gather, block_c=8)
    b = decompress_maxsim_scores(q, packed, cids, valid, cent, bw,
                                 nbits=nbits, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-3)


def test_fused_equals_decompress_then_maxsim():
    """The fusion is exact: same numbers as the two-step pipeline."""
    nbits, C, Ld, K, d = 4, 12, 10, 32, 64
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (8, d))
    packed = jax.random.randint(jax.random.fold_in(k, 1),
                                (C, Ld, d // 2), 0, 256).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 2), (C, Ld), 0, K)
    valid = jnp.ones((C, Ld), bool)
    cent = jax.random.normal(jax.random.fold_in(k, 4), (K, d))
    bw = jnp.linspace(-0.2, 0.2, 16)
    codes = unpack_codes(packed, nbits)
    emb = cent[cids] + bw[codes.astype(jnp.int32)]
    two_step = maxsim_scores(q, emb, valid, impl="ref")
    fused = decompress_maxsim_scores(q, packed, cids, valid, cent, bw,
                                     nbits=nbits, impl="ref")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_step),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# splade_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Qt,max_df,n_docs,block_d,chunk", [
    (8, 128, 500, 256, 128),
    (4, 64, 1000, 512, 256),
    (16, 32, 300, 128, 512),   # E not multiple of chunk (pads)
])
def test_splade_interpret_matches_ref(Qt, max_df, n_docs, block_d, chunk):
    k = jax.random.PRNGKey(Qt + max_df)
    pids = jax.random.randint(k, (Qt, max_df), -1, n_docs, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 1), (Qt, max_df))
    w = jax.random.uniform(jax.random.fold_in(k, 2), (Qt,))
    a = splade_block_scores(pids, imps, w, n_docs=n_docs,
                            impl="interpret", block_d=block_d, chunk=chunk)
    b = splade_block_scores(pids, imps, w, n_docs=n_docs, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("B,Qt,max_df,n_docs,block_d,chunk", [
    (3, 8, 64, 500, 256, 128),
    (1, 4, 32, 300, 128, 256),    # B=1 degenerate; E pads to chunk
    (5, 16, 16, 700, 512, 64),
])
def test_splade_batch_interpret_matches_ref(B, Qt, max_df, n_docs,
                                            block_d, chunk):
    k = jax.random.PRNGKey(B * 31 + Qt)
    pids = jax.random.randint(k, (B, Qt, max_df), -1, n_docs, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 1), (B, Qt, max_df))
    w = jax.random.uniform(jax.random.fold_in(k, 2), (B, Qt))
    a = splade_block_scores_batch(pids, imps, w, n_docs=n_docs,
                                  impl="interpret", block_d=block_d,
                                  chunk=chunk)
    b = splade_block_scores_batch(pids, imps, w, n_docs=n_docs, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


def test_splade_batch_ref_equals_per_query_loop():
    B, Qt, max_df, n_docs = 4, 6, 48, 400
    k = jax.random.PRNGKey(9)
    pids = jax.random.randint(k, (B, Qt, max_df), -1, n_docs, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 1), (B, Qt, max_df))
    w = jax.random.uniform(jax.random.fold_in(k, 2), (B, Qt))
    batch = splade_block_scores_batch(pids, imps, w, n_docs=n_docs,
                                      impl="ref")
    loop = jnp.stack([splade_block_scores(pids[b], imps[b], w[b],
                                          n_docs=n_docs, impl="ref")
                      for b in range(B)])
    np.testing.assert_allclose(np.asarray(batch), np.asarray(loop),
                               rtol=1e-5, atol=1e-6)


def test_splade_fused_topk_matches_scores_then_topk():
    B, Qt, max_df, n_docs, k_top = 3, 5, 40, 250, 17
    k = jax.random.PRNGKey(21)
    pids = jax.random.randint(k, (B, Qt, max_df), -1, n_docs, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 1), (B, Qt, max_df))
    w = jax.random.uniform(jax.random.fold_in(k, 2), (B, Qt))
    top_pids, top_scores = splade_block_topk_batch(pids, imps, w,
                                                   n_docs=n_docs, k=k_top,
                                                   impl="ref")
    scores = np.asarray(splade_block_scores_batch(pids, imps, w,
                                                  n_docs=n_docs, impl="ref"))
    for b in range(B):
        want = np.sort(scores[b])[::-1][:k_top]
        np.testing.assert_allclose(np.asarray(top_scores[b]), want,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(scores[b][np.asarray(top_pids[b])],
                                   np.asarray(top_scores[b]), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 32), st.integers(0, 2 ** 31 - 1))
def test_splade_ref_is_exact_posting_sum(Qt, max_df, seed):
    """Oracle equals a literal python loop over postings."""
    rng = np.random.default_rng(seed)
    n_docs = 50
    pids = rng.integers(-1, n_docs, (Qt, max_df)).astype(np.int32)
    imps = rng.random((Qt, max_df)).astype(np.float32)
    w = rng.random(Qt).astype(np.float32)
    expected = np.zeros(n_docs, np.float32)
    for t in range(Qt):
        for j in range(max_df):
            if pids[t, j] >= 0:
                expected[pids[t, j]] += w[t] * imps[t, j]
    got = np.asarray(splade_block_scores(
        jnp.asarray(pids), jnp.asarray(imps), jnp.asarray(w),
        n_docs=n_docs, impl="ref"))
    np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused_rerank (decompress + MaxSim + top-k in one dispatch)
# ---------------------------------------------------------------------------

from repro.kernels.decompress_maxsim.ops import decompress_maxsim_scores_batch
from repro.kernels.fused_rerank.ops import (fused_rerank_topk,
                                            fused_rerank_topk_batch)


def _rerank_case(seed, C, Ld, nbits, K=32, d=64, Lq=8, B=None,
                 mask_p=0.85):
    """Random compressed candidate set (+ optional leading batch dim)."""
    k = jax.random.PRNGKey(seed)
    lead = () if B is None else (B,)
    q = jax.random.normal(k, lead + (Lq, d), jnp.float32)
    packed = jax.random.randint(jax.random.fold_in(k, 1),
                                lead + (C, Ld, d * nbits // 8), 0, 256,
                                jnp.int32).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 2), lead + (C, Ld),
                              0, K, jnp.int32)
    valid = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.8,
                                 lead + (C, Ld))
    cmask = jax.random.bernoulli(jax.random.fold_in(k, 4), mask_p,
                                 lead + (C,))
    qv = jax.random.bernoulli(jax.random.fold_in(k, 5), 0.9, lead + (Lq,))
    cent = jax.random.normal(jax.random.fold_in(k, 6), (K, d), jnp.float32)
    bw = jnp.linspace(-0.3, 0.3, 2 ** nbits, dtype=jnp.float32)
    return q, packed, cids, valid, cmask, cent, bw, qv


@pytest.mark.parametrize("nbits,C,Ld,k_top,block_c", [
    (4, 32, 12, 10, 16),
    (4, 33, 12, 10, 8),      # ragged C (pads to block multiple)
    (2, 16, 1, 16, 8),       # single-token docs, k == C
    (4, 24, 6, 40, 8),       # k > C (pads tail with (-inf, -1))
    (2, 8, 5, 1, 8),         # k == 1
])
def test_fused_rerank_interpret_bitwise_matches_ref(nbits, C, Ld, k_top,
                                                    block_c):
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        nbits * 101 + C, C, Ld, nbits)
    a = fused_rerank_topk(q, packed, cids, valid, cmask, cent, bw,
                          nbits=nbits, k=k_top, q_valid=qv,
                          impl="interpret", block_c=block_c)
    b = fused_rerank_topk(q, packed, cids, valid, cmask, cent, bw,
                          nbits=nbits, k=k_top, q_valid=qv, impl="ref")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("nbits,B,C,Ld,k_top,block_c", [
    (4, 3, 32, 10, 12, 16),
    (2, 1, 24, 4, 24, 8),     # B=1 degenerate
    (4, 5, 40, 8, 64, 8),     # k > C
])
def test_fused_rerank_batch_interpret_bitwise_matches_ref(nbits, B, C, Ld,
                                                          k_top, block_c):
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        nbits * 7 + B, C, Ld, nbits, B=B)
    a = fused_rerank_topk_batch(q, packed, cids, valid, cmask, cent, bw,
                                nbits=nbits, k=k_top, q_valid=qv,
                                impl="interpret", block_c=block_c)
    b = fused_rerank_topk_batch(q, packed, cids, valid, cmask, cent, bw,
                                nbits=nbits, k=k_top, q_valid=qv,
                                impl="ref")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_rerank_bitwise_matches_split_pipeline(impl):
    """The fused tail == split dispatches + stable host argsort, bitwise
    — scores AND indices, ties broken toward the lower candidate index."""
    nbits, B, C, Ld, k_top = 4, 4, 32, 8, 12
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        17, C, Ld, nbits, B=B)
    scores = np.asarray(decompress_maxsim_scores_batch(
        q, packed, cids, valid, cent, bw, nbits=nbits, q_valid=qv,
        impl="ref"))
    final = np.where(np.asarray(cmask), scores, -np.inf)
    order = np.argsort(-final, axis=1, kind="stable")[:, :k_top]
    vals, idx = fused_rerank_topk_batch(
        q, packed, cids, valid, cmask, cent, bw, nbits=nbits, k=k_top,
        q_valid=qv, impl=impl, block_c=8)
    np.testing.assert_array_equal(np.asarray(idx), order.astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(vals), np.take_along_axis(final, order, axis=1)
        .astype(np.float32))


def test_fused_rerank_duplicate_scores_break_ties_by_index():
    """Identical candidates produce identical scores — selection must
    order them by ascending candidate index (lax.top_k semantics)."""
    nbits, C, Ld, k_top = 4, 16, 6, 8
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        5, C, Ld, nbits, mask_p=1.0)
    # every candidate is a copy of candidate 0 → C-way score tie
    packed = jnp.broadcast_to(packed[:1], packed.shape)
    cids = jnp.broadcast_to(cids[:1], cids.shape)
    valid = jnp.broadcast_to(valid[:1], valid.shape)
    for impl in ("ref", "interpret"):
        _, idx = fused_rerank_topk(q, packed, cids, valid, cmask, cent,
                                   bw, nbits=nbits, k=k_top, q_valid=qv,
                                   impl=impl, block_c=8)
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.arange(k_top, dtype=np.int32))


def test_fused_rerank_all_masked_and_empty_edges():
    nbits, C, Ld, k_top = 2, 16, 4, 6
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        11, C, Ld, nbits)
    # all-masked candidate row: every score -inf, indices still the
    # stable prefix (lax.top_k returns ascending indices on full ties)
    none = jnp.zeros_like(cmask)
    for impl in ("ref", "interpret"):
        vals, idx = fused_rerank_topk(q, packed, cids, valid, none, cent,
                                      bw, nbits=nbits, k=k_top,
                                      q_valid=qv, impl=impl, block_c=8)
        assert np.all(np.asarray(vals) == -np.inf)
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.arange(k_top, dtype=np.int32))
    # empty candidate set: fully padded output
    vals, idx = fused_rerank_topk(q, packed[:0], cids[:0], valid[:0],
                                  cmask[:0], cent, bw, nbits=nbits,
                                  k=k_top, impl="ref")
    assert np.all(np.asarray(vals) == -np.inf)
    assert np.all(np.asarray(idx) == -1)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 24), st.integers(1, 6), st.integers(1, 30),
       st.integers(0, 2 ** 31 - 1))
def test_fused_rerank_topk_roundtrip_property(C, Ld, k_top, seed):
    """Returned (score, index) pairs must be exactly the k best masked
    scores in (desc, index-asc) order, and indices must map back to the
    scores the split pipeline computes for them."""
    nbits = 4
    q, packed, cids, valid, cmask, cent, bw, qv = _rerank_case(
        seed, C, Ld, nbits, mask_p=0.7)
    vals, idx = fused_rerank_topk(q, packed, cids, valid, cmask, cent,
                                  bw, nbits=nbits, k=k_top, q_valid=qv,
                                  impl="ref")
    vals, idx = np.asarray(vals), np.asarray(idx)
    scores = np.asarray(decompress_maxsim_scores_batch(
        q[None], packed[None], cids[None], valid[None], cent, bw,
        nbits=nbits, q_valid=qv[None], impl="ref"))[0]
    final = np.where(np.asarray(cmask), scores, -np.inf)
    kk = min(k_top, C)
    order = np.argsort(-final, kind="stable")[:kk]
    np.testing.assert_array_equal(idx[:kk], order.astype(np.int32))
    np.testing.assert_array_equal(vals[:kk],
                                  final[order].astype(np.float32))
    assert np.all(vals[kk:] == -np.inf) and np.all(idx[kk:] == -1)
