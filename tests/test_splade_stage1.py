"""Batched device-resident SPLADE stage 1: backend parity
(host CSR == vectorised batch host == JAX segment-sum == batched Pallas
kernel in interpret mode), padded-postings truncation semantics, edge
cases (zero-weight queries, k > n_docs), the no-per-query-loop
guarantee for jax/pallas `search_batch`, and adaptive micro-batch
sizing in the server."""

import time

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.index.builder import ColBERTIndex
from repro.index.splade_device import SpladeDeviceCache
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.serving.engine import Request, Result, ServeEngine
from repro.serving.server import RetrievalServer


@pytest.fixture(scope="module")
def sidx(small_corpus):
    return build_splade_index(small_corpus["doc_term_ids"],
                              small_corpus["doc_term_weights"],
                              small_corpus["cfg"].vocab,
                              small_corpus["cfg"].n_docs)


@pytest.fixture(scope="module")
def queries(small_corpus):
    rng = np.random.default_rng(5)
    tids, tw = [], []
    for i in range(6):
        n = int(rng.integers(2, 8))
        tids.append(small_corpus["q_term_ids"][i][:n])
        tw.append(small_corpus["q_term_weights"][i][:n])
    return tids, tw


def test_topk_rows_tie_break_matches_stable_argsort():
    """The O(n) partition+refine selection must be indistinguishable
    from a stable full argsort (score desc, pid asc) — the order
    ``lax.top_k`` uses, and what shard-merge parity relies on. Heavy
    integer ties exercise both the boundary fill and the final sort."""
    from repro.index.splade_index import _topk_rows
    rng = np.random.default_rng(9)
    scores = rng.integers(0, 6, (5, 97)).astype(np.float32)
    scores[1] = 0.0                              # all-tied row
    for k in (1, 7, 50, 97, 120):
        got_p, got_s = _topk_rows(scores, k)
        ref = np.argsort(-scores, axis=1, kind="stable")[:, :min(k, 97)]
        np.testing.assert_array_equal(got_p[:, :ref.shape[1]], ref)
        np.testing.assert_array_equal(
            got_s[:, :ref.shape[1]],
            np.take_along_axis(scores, ref, axis=1))
        assert (got_p[:, ref.shape[1]:] == -1).all()
        assert (got_s[:, ref.shape[1]:] == 0).all()


# ---------------------------------------------------------------------------
# host scoring: the np.add.at regression + vectorised batch parity
# ---------------------------------------------------------------------------

def test_score_host_accumulates_duplicate_pids():
    """A doc listing the same term twice yields two postings with the
    same pid; fancy-index += silently dropped one of them."""
    ids = np.array([[7, 7, 3]], np.int32)
    w = np.array([[1.0, 1.0, 2.0]], np.float32)
    idx = build_splade_index(ids, w, vocab=10, n_docs=1)
    s, e = idx.term_offsets[7], idx.term_offsets[8]
    assert e - s == 2 and (idx.pids[s:e] == 0).all()   # duplicate-pid term
    pids, scores = idx.score_host(np.array([7], np.int32),
                                  np.array([1.0], np.float32), k=1)
    expected = (idx.impacts[s:e].astype(np.float32) * idx.quantum).sum()
    np.testing.assert_allclose(scores[0], expected, rtol=1e-5)


def test_score_batch_host_matches_score_host(sidx, queries):
    tids, tw = queries
    bp, bs = sidx.score_batch_host(tids, tw, k=25)
    for i in range(len(tids)):
        sp, ss = sidx.score_host(tids[i], tw[i], k=25)
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_array_equal(bs[i], ss)


def test_score_batch_host_shares_union_gathers(sidx, queries):
    """Duplicate queries co-batched score identically to one copy (the
    union-of-terms pass must not double-count shared terms)."""
    tids, tw = queries
    dup_p, dup_s = sidx.score_batch_host([tids[0], tids[0]],
                                         [tw[0], tw[0]], k=10)
    np.testing.assert_array_equal(dup_p[0], dup_p[1])
    np.testing.assert_array_equal(dup_s[0], dup_s[1])


# ---------------------------------------------------------------------------
# backend parity: host == jax segment-sum == batched pallas (interpret)
# ---------------------------------------------------------------------------

def test_backend_parity_host_jax_pallas_interpret(sidx, queries):
    tids, tw = queries
    hp, hs = sidx.score_batch_host(tids, tw, k=30)
    cache = SpladeDeviceCache(sidx)          # max_df=None → exact
    assert cache.truncated_terms == 0
    jp, js = cache.score_topk(tids, tw, k=30, impl="ref")
    pp, ps = cache.score_topk(tids, tw, k=30, impl="interpret")
    np.testing.assert_allclose(js, hs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ps, js, rtol=1e-4, atol=1e-4)
    # same candidate sets at every rank with distinct scores
    np.testing.assert_array_equal(jp, pp)


def test_padded_truncation_keeps_top_impacts():
    """df > max_df: the device tier keeps the top-impact postings, so
    truncated scores lower-bound exact scores and match a manual
    top-max_df recomputation."""
    n_docs, term = 12, 0
    ids = np.zeros((n_docs, 1), np.int32)          # every doc has term 0
    w = (np.arange(1, n_docs + 1, dtype=np.float32)
         .reshape(n_docs, 1))                      # distinct impacts
    idx = build_splade_index(ids, w, vocab=4, n_docs=n_docs)
    cache = SpladeDeviceCache(idx, max_df=4)
    assert cache.max_df == 4 and cache.truncated_terms == 1
    q = [np.array([term], np.int32)], [np.array([1.0], np.float32)]
    tp, ts = cache.score_topk(q[0], q[1], k=n_docs, impl="ref")
    ep, es = idx.score_batch_host(q[0], q[1], k=n_docs)
    # kept: the 4 highest-impact docs, scored exactly as the host tier
    np.testing.assert_array_equal(np.sort(tp[0, :4]), np.sort(ep[0, :4]))
    np.testing.assert_allclose(ts[0, :4], es[0, :4], rtol=1e-4)
    # dropped postings score 0, never inflated
    assert (ts[0, 4:] == 0).all()
    assert (es[0, 4:] > 0).all()


def test_all_zero_weight_query(sidx):
    tids = [np.array([1, 2, 3], np.int32)]
    tw = [np.zeros(3, np.float32)]
    hp, hs = sidx.score_batch_host(tids, tw, k=5)
    assert (hs == 0).all()
    cache = SpladeDeviceCache(sidx)
    for impl in ("ref", "interpret"):
        dp, ds = cache.score_topk(tids, tw, k=5, impl=impl)
        assert (ds == 0).all(), impl
        assert np.isfinite(ds).all()


def test_out_of_vocab_term_rejected(sidx):
    """The device tier must fail loudly like the host CSR path — a
    clamped gather would silently return the last term's postings."""
    cache = SpladeDeviceCache(sidx)
    bad = [np.array([sidx.vocab + 3], np.int32)]
    w = [np.array([1.0], np.float32)]
    with pytest.raises(IndexError, match="out of range"):
        cache.score_topk(bad, w, k=5, impl="ref")
    with pytest.raises(IndexError):
        sidx.score_host(bad[0], w[0], k=5)


def test_k_gt_n_docs(sidx, queries):
    tids, tw = queries
    k = sidx.n_docs + 13
    hp, hs = sidx.score_batch_host(tids[:2], tw[:2], k=k)
    assert hp.shape == (2, k)
    assert (hp[:, sidx.n_docs:] == -1).all()
    assert (hs[:, sidx.n_docs:] == 0).all()
    cache = SpladeDeviceCache(sidx)
    dp, ds = cache.score_topk(tids[:2], tw[:2], k=k, impl="ref")
    assert dp.shape == (2, k)
    assert (dp[:, sidx.n_docs:] == -1).all()
    np.testing.assert_allclose(ds[:, :sidx.n_docs], hs[:, :sidx.n_docs],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# retriever integration: single dispatch, no per-query host loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def retr(built_index, small_corpus, sidx):
    index = ColBERTIndex(built_index, mode="mmap")
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=8, candidate_cap=512,
                                                ndocs=128, k=50))
    return MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=50, k=20))


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("method", ["splade", "rerank", "hybrid"])
def test_search_batch_device_backend_matches_search(retr, small_corpus,
                                                    backend, method,
                                                    monkeypatch):
    B = 5
    args = dict(
        q_embs=[small_corpus["q_embs"][i] for i in range(B)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(B)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(B)])
    retr.set_splade_backend(backend)
    try:
        sequential = [retr.search(method, q_emb=args["q_embs"][i],
                                  term_ids=args["term_ids"][i],
                                  term_weights=args["term_weights"][i],
                                  k=15)
                      for i in range(B)]
        # the batched path must never fall back to the per-query host CSR
        # loop, and must issue exactly ONE stage-1 dispatch
        monkeypatch.setattr(
            SpladeIndex, "score_host",
            lambda *a, **k: pytest.fail("per-query score_host called "
                                        "on a device backend"))
        retr.reset_stage_stats()
        bp, bs = retr.search_batch(method, k=15, **args)
        assert retr.stage_stats["stage1_dispatches"] == 1
        assert retr.stage_stats["stage1_queries"] == B
    finally:
        retr.set_splade_backend("host")
    for i, (sp, ss) in enumerate(sequential):
        np.testing.assert_array_equal(bp[i], sp)
        np.testing.assert_allclose(bs[i], ss, rtol=1e-3, atol=1e-3)


def test_search_batch_host_backend_is_single_pass(retr, small_corpus):
    """The host backend also batches: one vectorised dispatch, no
    per-query loop in search_batch."""
    B = 4
    retr.reset_stage_stats()
    retr.search_batch(
        "splade", k=10,
        q_embs=[small_corpus["q_embs"][i] for i in range(B)],
        term_ids=[small_corpus["q_term_ids"][i] for i in range(B)],
        term_weights=[small_corpus["q_term_weights"][i] for i in range(B)])
    assert retr.stage_stats["stage1_dispatches"] == 1
    assert retr.stage_stats["stage1_queries"] == B


def test_engine_backend_override(retr):
    assert retr.splade_backend == "host"
    ServeEngine(retr, splade_backend="jax")
    try:
        assert retr.splade_backend == "jax"
        assert retr._splade_device is not None    # cache pre-materialised
    finally:
        retr.set_splade_backend("host")


def test_unknown_backend_rejected(retr):
    with pytest.raises(ValueError, match="backend"):
        retr.set_splade_backend("cuda")
    with pytest.raises(ValueError, match="backend"):
        retr.run_splade_batch([np.array([1])], [np.array([1.0])],
                              backend="cuda")


# ---------------------------------------------------------------------------
# adaptive micro-batch sizing (latency SLO)
# ---------------------------------------------------------------------------

class _PacedEngine:
    """Engine stub whose service time is settable at runtime."""

    def __init__(self):
        self.served = 0
        self.delay_s = 0.0

    def _result(self, req):
        now = time.perf_counter()
        return Result(qid=req.qid, pids=np.array([0]),
                      scores=np.array([1.0]), t_arrival=req.t_arrival,
                      t_start=now, t_done=now + self.delay_s)

    def process(self, req):
        time.sleep(self.delay_s)
        self.served += 1
        return self._result(req)

    def process_batch(self, reqs):
        time.sleep(self.delay_s)
        self.served += len(reqs)
        return [self._result(r) for r in reqs]


def _drain(srv, n):
    futs = [srv.submit(Request(qid=i, method="splade")) for i in range(n)]
    for f in futs:
        f.result(timeout=30)


def test_adaptive_batch_cap_shrinks_then_recovers():
    eng = _PacedEngine()
    srv = RetrievalServer(eng, n_threads=1, max_batch=8,
                          batch_timeout_ms=1.0, latency_slo_ms=20.0,
                          slo_ewma_alpha=1.0)   # react instantly
    srv.start()
    try:
        assert srv.batch_cap == 8
        eng.delay_s = 0.06                      # 60ms ≫ 20ms SLO
        _drain(srv, 12)
        assert srv.batch_cap < 8
        assert srv.health()["ewma_latency_ms"] > 20.0
        shrunk = srv.batch_cap
        eng.delay_s = 0.0                       # latency collapses
        _drain(srv, 40)
        assert srv.batch_cap > shrunk
    finally:
        srv.stop()


def test_fixed_cap_without_slo():
    eng = _PacedEngine()
    eng.delay_s = 0.03
    srv = RetrievalServer(eng, n_threads=1, max_batch=4,
                          batch_timeout_ms=1.0)   # no latency_slo_ms
    srv.start()
    try:
        _drain(srv, 8)
        assert srv.batch_cap == 4                 # never adapted
        assert srv.health()["ewma_latency_ms"] is None
    finally:
        srv.stop()
