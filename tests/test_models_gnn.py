"""MACE: E(3)-equivariance property tests (the model's defining
invariant), spherical-harmonic identities, per-shape smoke steps,
neighbour sampler correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import ARCHS
from repro.models.gnn import mace as M
from repro.models.gnn import sampler as SP
from repro.models.gnn import spherical as sph


def _random_rotation(rng):
    """Haar-ish random rotation from QR of a Gaussian."""
    A = rng.normal(size=(3, 3))
    Q, R = np.linalg.qr(A)
    Q *= np.sign(np.diag(R))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q.astype(np.float32)


@pytest.fixture()
def tiny_graph(rng):
    N, E = 20, 60
    return {
        "feats": rng.normal(size=(N, 8)).astype(np.float32),
        "pos": rng.normal(size=(N, 3)).astype(np.float32),
        "senders": rng.integers(0, N, E).astype(np.int32),
        "receivers": rng.integers(0, N, E).astype(np.int32),
    }


def test_gaunt_tensor_identities():
    G = sph.gaunt_tensor()
    # G[0,b,c] = Y_0 ∫ Y_b Y_c = (1/2√π)·δ_bc  (orthonormality)
    c0 = 0.5 / np.sqrt(np.pi)
    np.testing.assert_allclose(G[0], c0 * np.eye(9), atol=1e-10)
    # total symmetry in all three indices
    np.testing.assert_allclose(G, np.transpose(G, (1, 0, 2)), atol=1e-10)
    np.testing.assert_allclose(G, np.transpose(G, (0, 2, 1)), atol=1e-10)


def test_sh_orthonormality():
    """Quadrature check: ∫ Y_a Y_b = δ_ab over the sphere."""
    n_t, n_p = 32, 64
    nodes, wts = np.polynomial.legendre.leggauss(n_t)
    phi = (np.arange(n_p) + 0.5) * (2 * np.pi / n_p)
    ct = nodes[:, None]
    sth = np.sqrt(1 - ct ** 2)
    xyz = np.stack([sth * np.cos(phi), sth * np.sin(phi),
                    np.broadcast_to(ct, (n_t, n_p))], axis=-1)
    Y = sph.real_sh_l2_np(xyz)
    w = wts[:, None] * (2 * np.pi / n_p)
    gram = np.einsum("tp,tpa,tpb->ab", w, Y, Y)
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_mace_rotation_invariant_readout(seed):
    """Rotating all positions leaves the (invariant) node outputs
    unchanged — the defining E(3) property."""
    rng = np.random.default_rng(seed)
    cfg = M.MACECfg(n_layers=2, d_hidden=8, n_rbf=4, d_in=4, n_out=3)
    params = M.init(jax.random.PRNGKey(seed % 997), cfg)
    N, E = 12, 40
    feats = rng.normal(size=(N, 4)).astype(np.float32)
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    snd = rng.integers(0, N, E).astype(np.int32)
    rcv = rng.integers(0, N, E).astype(np.int32)
    out1 = M.forward(params, cfg, feats, pos, snd, rcv)
    Q = _random_rotation(rng)
    out2 = M.forward(params, cfg, feats, pos @ Q.T, snd, rcv)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=5e-4, atol=5e-4)


def test_mace_translation_invariant(tiny_graph):
    cfg = M.MACECfg(n_layers=2, d_hidden=8, n_rbf=4, d_in=8, n_out=2)
    params = M.init(jax.random.PRNGKey(0), cfg)
    g = tiny_graph
    out1 = M.forward(params, cfg, g["feats"], g["pos"], g["senders"],
                     g["receivers"])
    out2 = M.forward(params, cfg, g["feats"], g["pos"] + 5.0,
                     g["senders"], g["receivers"])
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_mace_node_permutation_equivariant(tiny_graph, rng):
    cfg = M.MACECfg(n_layers=2, d_hidden=8, n_rbf=4, d_in=8, n_out=2)
    params = M.init(jax.random.PRNGKey(0), cfg)
    g = tiny_graph
    N = g["feats"].shape[0]
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    out1 = M.forward(params, cfg, g["feats"], g["pos"], g["senders"],
                     g["receivers"])
    out2 = M.forward(params, cfg, g["feats"][perm], g["pos"][perm],
                     inv[g["senders"]].astype(np.int32),
                     inv[g["receivers"]].astype(np.int32))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2)[inv],
                               rtol=5e-4, atol=5e-4)


def test_padding_self_loops_are_noops(tiny_graph):
    """0→0 zero-length pad edges (the fixed-shape padding convention)
    must not change any output."""
    cfg = M.MACECfg(n_layers=2, d_hidden=8, n_rbf=4, d_in=8, n_out=2)
    params = M.init(jax.random.PRNGKey(0), cfg)
    g = tiny_graph
    out1 = M.forward(params, cfg, g["feats"], g["pos"], g["senders"],
                     g["receivers"])
    snd = np.concatenate([g["senders"], np.zeros(16, np.int32)])
    rcv = np.concatenate([g["receivers"], np.zeros(16, np.int32)])
    out2 = M.forward(params, cfg, g["feats"], g["pos"], snd, rcv)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape_name", list(ARCHS["mace"].shapes))
def test_mace_shape_smoke(shape_name, rng):
    """Reduced-size train step per assigned shape: loss + grads finite."""
    from repro.training.optimizer import AdamWCfg, adamw_init, adamw_update
    sd = ARCHS["mace"].shapes[shape_name]
    cfg = dataclasses.replace(
        ARCHS["mace"].smoke_cfg(), d_in=8,
        n_out=sd.dims.get("n_classes", 1) if sd.dims["readout"] == "node"
        else 1, readout=sd.dims["readout"])
    params = M.init(jax.random.PRNGKey(0), cfg)
    N, E = 64, 200
    batch = {
        "feats": jnp.asarray(rng.normal(size=(N, 8)), jnp.float32),
        "pos": jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, N, E), jnp.int32),
    }
    if sd.dims["readout"] == "graph":
        batch["graph_ids"] = jnp.asarray(rng.integers(0, 4, N), jnp.int32)
        batch["targets"] = jnp.asarray(rng.normal(size=4), jnp.float32)
        batch["n_graphs"] = 4
    else:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.n_out, N), jnp.int32)
        batch["label_mask"] = jnp.ones(N, jnp.float32)
    (loss, m), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    opt_cfg = AdamWCfg()
    state = adamw_init(params, opt_cfg)
    new_params, _, _ = adamw_update(grads, state, params, opt_cfg)
    assert all(bool(jnp.isfinite(x).all())
               for x in jax.tree_util.tree_leaves(new_params))


# ---------------------------------------------------------------------------
# neighbour sampler
# ---------------------------------------------------------------------------

def test_sampler_edges_exist_and_fanout_bounded(rng):
    g = SP.random_graph(rng, n_nodes=500, avg_degree=8)
    seeds = rng.choice(500, 32, replace=False)
    sub = SP.sample_subgraph(g, seeds, (5, 3), rng, max_nodes=1024,
                             max_edges=4096)
    assert sub["node_ids"].shape == (1024,)
    assert sub["senders"].shape == (4096,)
    node_ids = sub["node_ids"]
    for i in range(sub["n_edges"]):
        s, r = sub["senders"][i], sub["receivers"][i]
        u, v = node_ids[s], node_ids[r]   # edge v←u means u ∈ N(v)
        assert u in g.neighbors(int(v))
    # first hop bounded: each seed contributes ≤5 edges in hop 1
    assert sub["n_edges"] <= 32 * 5 + 32 * 5 * 3


def test_sampler_fixed_shapes_across_draws(rng):
    g = SP.random_graph(rng, n_nodes=300, avg_degree=6)
    shapes = set()
    for i in range(3):
        seeds = rng.choice(300, 16, replace=False)
        sub = SP.sample_subgraph(g, seeds, (4, 2), rng, max_nodes=256,
                                 max_edges=512)
        shapes.add((sub["node_ids"].shape, sub["senders"].shape))
    assert len(shapes) == 1   # jit-stable shapes
