"""Scatter-gather sharded serving: index splitting, merge-parity with
the single-index path (all four methods, mixed batches, per-query
alpha), global doc-id remapping at shard boundaries, k > docs-in-shard,
failure isolation, and the pipelined engine over a shard group."""

import json

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import (
    CombinedAccessStats,
    ShardedRetriever,
    build_sharded_retriever,
    merge_topk,
)
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import (
    shard_boundaries,
    split_index_tree,
    split_splade_index,
)
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.launch.mesh import shard_device_map
from repro.serving.engine import Request, ServeEngine
from repro.serving.server import RetrievalServer

METHODS = ("splade", "rerank", "hybrid", "colbert")
PLAID = PlaidParams(nprobe=8, candidate_cap=512, ndocs=128, k=50)
MS = MultiStageParams(first_k=50, k=20)


@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, small_corpus):
    """Serve-layout index (<base>/{colbert,splade}) over small_corpus."""
    base = tmp_path_factory.mktemp("shard_base")
    build_colbert_index(base / "colbert", small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    build_splade_index(small_corpus["doc_term_ids"],
                       small_corpus["doc_term_weights"],
                       small_corpus["cfg"].vocab,
                       small_corpus["cfg"].n_docs).save(base / "splade")
    return base


@pytest.fixture(scope="module")
def unsharded(base_dir):
    index = ColBERTIndex(base_dir / "colbert", mode="mmap")
    sidx = SpladeIndex.load(base_dir / "splade", mmap=True)
    return MultiStageRetriever(sidx, PLAIDSearcher(index, PLAID), MS)


@pytest.fixture(scope="module")
def groups(base_dir, small_corpus):
    """{n_shards: ShardedRetriever} for 2 and 4 shards."""
    n_docs = small_corpus["cfg"].n_docs
    out = {}
    for s in (2, 4):
        group = split_index_tree(base_dir, s,
                                 group_dir=base_dir / f"shards{s}")
        out[s] = build_sharded_retriever(
            [group / str(i) for i in range(s)],
            shard_boundaries(n_docs, s), mode="mmap",
            plaid_params=PLAID, multistage_params=MS)
    return out


def _batch(corpus, lo, hi):
    return dict(q_embs=corpus["q_embs"][lo:hi],
                term_ids=corpus["q_term_ids"][lo:hi],
                term_weights=corpus["q_term_weights"][lo:hi])


def _assert_same(ref, got):
    np.testing.assert_array_equal(ref[0], got[0])
    r, g = np.asarray(ref[1]), np.asarray(got[1])
    finite = np.isfinite(r)
    assert (finite == np.isfinite(g)).all()
    np.testing.assert_allclose(r[finite], g[finite], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# splitting
# ---------------------------------------------------------------------------

def test_shard_boundaries_contiguous_and_balanced():
    b = shard_boundaries(401, 4)
    assert b[0] == 0 and b[-1] == 401
    sizes = np.diff(b)
    assert sizes.min() >= 100 and sizes.max() <= 101
    with pytest.raises(ValueError):
        shard_boundaries(3, 5)
    with pytest.raises(ValueError):
        shard_boundaries(10, 0)


def test_split_splade_preserves_postings_and_quantum(base_dir):
    sidx = SpladeIndex.load(base_dir / "splade")
    bounds = shard_boundaries(sidx.n_docs, 3)
    parts = split_splade_index(sidx, bounds)
    assert sum(len(p.pids) for p in parts) == len(sidx.pids)
    for p, lo, hi in zip(parts, bounds[:-1], bounds[1:]):
        assert p.quantum == sidx.quantum      # global scale kept
        assert p.n_docs == hi - lo
        if len(p.pids):
            assert p.pids.min() >= 0 and p.pids.max() < p.n_docs
    # per-term postings re-assemble to the original (global pid order)
    t = int(np.argmax(np.diff(sidx.term_offsets)))   # densest term
    orig = sidx.pids[sidx.term_offsets[t]:sidx.term_offsets[t + 1]]
    glued = np.concatenate([
        p.pids[p.term_offsets[t]:p.term_offsets[t + 1]] + lo
        for p, lo in zip(parts, bounds[:-1])])
    np.testing.assert_array_equal(np.sort(orig), np.sort(glued))


def test_split_colbert_segments_cover_pool(base_dir, groups):
    meta = json.loads((base_dir / "colbert" / "meta.json").read_text())
    shard_metas = [json.loads(
        (base_dir / "shards4" / str(i) / "colbert" / "meta.json")
        .read_text()) for i in range(4)]
    assert sum(m["n_tokens"] for m in shard_metas) == meta["n_tokens"]
    assert sum(m["n_docs"] for m in shard_metas) == meta["n_docs"]
    for m in shard_metas:
        assert m["nbits"] == meta["nbits"]
        assert m["n_centroids"] == meta["n_centroids"]


# ---------------------------------------------------------------------------
# merge_topk
# ---------------------------------------------------------------------------

def test_merge_topk_orders_and_pads():
    pids = np.array([[3, 9, -1, 5, 7, -1]])
    scores = np.array([[1.0, 3.0, 99.0, 2.0, 3.0, 99.0]], np.float32)
    p, s = merge_topk(pids, scores, 4)
    # ties (9 vs 7 at 3.0) break by ascending global pid; -1 never wins
    np.testing.assert_array_equal(p, [[7, 9, 5, 3]])
    np.testing.assert_allclose(s, [[3.0, 3.0, 2.0, 1.0]])
    p, s = merge_topk(pids, scores, 8, pad_score=0.0)
    np.testing.assert_array_equal(p[0, 4:], [-1] * 4)
    assert (s[0, 4:] == 0.0).all()


def test_merge_topk_matches_single_list_topk(rng):
    """Partition a scored corpus arbitrarily: merged per-part top-k must
    equal the unpartitioned top-k (the parity contract's core lemma)."""
    n = 200
    scores = rng.integers(0, 50, n).astype(np.float32)  # heavy ties
    bounds = [0, 57, 130, n]
    parts_p, parts_s = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        local = scores[lo:hi]
        order = np.argsort(-local, kind="stable")[:20]
        parts_p.append(order + lo)
        parts_s.append(local[order])
    mp, ms_ = merge_topk(np.concatenate(parts_p)[None],
                         np.concatenate(parts_s)[None], 20)
    ref = np.argsort(-scores, kind="stable")[:20]
    np.testing.assert_array_equal(mp[0], ref)
    np.testing.assert_allclose(ms_[0], scores[ref])


# ---------------------------------------------------------------------------
# parity: shards=k vs shards=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("method", METHODS)
def test_method_parity(unsharded, groups, small_corpus, method, n_shards):
    kw = _batch(small_corpus, 0, 6)
    ref = unsharded.search_batch(method, k=15, **kw)
    got = groups[n_shards].search_batch(method, k=15, **kw)
    _assert_same(ref, got)


def test_mixed_batch_and_per_query_alpha_parity(unsharded, groups,
                                                small_corpus):
    methods = [METHODS[i % 4] for i in range(8)]
    alphas = [None, 0.1, 0.9, None, 0.5, 0.3, None, 0.7]
    kw = _batch(small_corpus, 0, 8)
    ref = unsharded.search_batch(methods, alpha=alphas, k=10, **kw)
    got = groups[4].search_batch(methods, alpha=alphas, k=10, **kw)
    _assert_same(ref, got)


def test_per_query_search_parity(unsharded, groups, small_corpus):
    for method in METHODS:
        ref = unsharded.search(
            method, q_emb=small_corpus["q_embs"][3],
            term_ids=small_corpus["q_term_ids"][3],
            term_weights=small_corpus["q_term_weights"][3], k=12)
        got = groups[2].search(
            method, q_emb=small_corpus["q_embs"][3],
            term_ids=small_corpus["q_term_ids"][3],
            term_weights=small_corpus["q_term_weights"][3], k=12)
        _assert_same(ref, got)


def test_single_shard_group_is_bitwise_unsharded(unsharded, base_dir,
                                                 small_corpus):
    """n_shards=1 delegates wholesale — same arrays, same plan object."""
    index = ColBERTIndex(base_dir / "colbert", mode="mmap")
    sidx = SpladeIndex.load(base_dir / "splade", mmap=True)
    solo = MultiStageRetriever(sidx, PLAIDSearcher(index, PLAID), MS)
    group = ShardedRetriever([solo], [0, small_corpus["cfg"].n_docs])
    assert group.compile_plan("hybrid") is solo.compile_plan("hybrid")
    kw = _batch(small_corpus, 0, 4)
    for method in METHODS:
        ref = solo.search_batch(method, k=10, **kw)
        got = group.search_batch(method, k=10, **kw)
        np.testing.assert_array_equal(ref[0], got[0])
        np.testing.assert_array_equal(ref[1], got[1])


def test_results_span_shard_boundaries(groups, small_corpus):
    """Global remapping: merged results carry valid *global* pids drawn
    from more than one shard's range (a remapping bug would either
    collapse everything into shard-local ids < n_docs/S or produce
    out-of-range ids)."""
    retr = groups[4]
    n_docs = small_corpus["cfg"].n_docs
    kw = _batch(small_corpus, 0, 12)
    pids, _ = retr.search_batch("splade", k=20, **kw)
    real = pids[pids >= 0]
    assert real.max() < n_docs
    owners = np.searchsorted(retr.offsets, real, side="right") - 1
    assert len(np.unique(owners)) >= 2


def test_k_exceeds_docs_in_shard(unsharded, groups, small_corpus):
    """k larger than any single shard's corpus slice: the merge must
    fill from every shard and pad (-1) only past the global corpus."""
    n_docs = small_corpus["cfg"].n_docs
    per_shard = n_docs // 4
    k = per_shard + 37
    big = MultiStageParams(first_k=n_docs + 50, k=k)
    kw = _batch(small_corpus, 0, 3)
    retr4 = groups[4]
    old_params = [sh.params for sh in retr4.shards]
    try:
        for sh in retr4.shards:
            sh.params = big
        retr4.params = big
        retr4._plans.clear()
        ref_retr = MultiStageRetriever(unsharded.splade,
                                       unsharded.searcher, big)
        ref = ref_retr.search_batch("splade", k=k, **kw)
        got = retr4.search_batch("splade", k=k, **kw)
        _assert_same(ref, got)
        assert got[0].shape == (3, k)
        assert (got[0] >= 0).sum(axis=1).max() <= n_docs
    finally:
        for sh, p in zip(retr4.shards, old_params):
            sh.params = p
        retr4.params = old_params[0]
        retr4._plans.clear()


# ---------------------------------------------------------------------------
# engine / server integration
# ---------------------------------------------------------------------------

def _requests(corpus, n, methods=METHODS, k=10):
    return [Request(qid=i, method=methods[i % len(methods)],
                    q_emb=corpus["q_embs"][i],
                    term_ids=corpus["q_term_ids"][i],
                    term_weights=corpus["q_term_weights"][i], k=k)
            for i in range(n)]


def test_pipelined_engine_over_shard_group(unsharded, groups,
                                           small_corpus):
    reqs = _requests(small_corpus, 16)
    ref = ServeEngine(unsharded).process_batch(reqs)
    eng = ServeEngine(groups[2], pipeline_depth=2)
    assert eng.pipelined
    futs = [eng.process_batch_async(reqs[i:i + 4])
            for i in range(0, 16, 4)]
    got = [r for f in futs for r in f.result(timeout=300)]
    eng.close()
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.pids, b.pids)


def test_one_shard_failure_isolated(groups, small_corpus):
    """A raising shard fails its own batch's requests cleanly; requests
    that never touch the poisoned path keep serving, and the server
    survives to serve healthy traffic afterwards."""
    retr = groups[2]
    poisoned = retr.shards[1]
    orig = poisoned.run_splade_batch

    def boom(*a, **k):
        raise RuntimeError("shard 1 down")

    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2),
                          n_threads=1, max_batch=4, batch_timeout_ms=5.0)
    srv.start()
    try:
        poisoned.run_splade_batch = boom
        retr._plans.clear()        # recompile over the poisoned fn
        bad = [srv.submit(r) for r in
               _requests(small_corpus, 4, methods=("rerank",))]
        for f in bad:
            with pytest.raises(RuntimeError, match="shard 1 down"):
                f.result(timeout=60)
        # colbert never touches SPLADE stage 1 → unaffected
        ok = [srv.submit(r) for r in
              _requests(small_corpus, 4, methods=("colbert",))]
        assert all(f.result(timeout=60).pids.shape == (10,) for f in ok)
        poisoned.run_splade_batch = orig
        retr._plans.clear()
        healed = [srv.submit(r) for r in
                  _requests(small_corpus, 4, methods=("rerank",))]
        assert all(len(f.result(timeout=60).pids) == 10 for f in healed)
        assert srv.health()["failed"] == 4
        assert srv.health()["n_shards"] == 2
    finally:
        poisoned.run_splade_batch = orig
        retr._plans.clear()
        srv.stop()


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------

def test_sharded_plan_shapes(groups):
    plan = groups[2].compile_plan("hybrid")
    names = plan.stage_names()
    assert names == ("splade_stage1", "merge_topk:stage1",
                     "host_gather:residuals", "device_score:maxsim",
                     "fuse_topk")
    fanouts = {s.name: s.fanout for s in plan.stages}
    # stage 1 is a group stage (dispatch-all-then-sync-all across the
    # shard devices), not a fanout; the mmap gather is the pooled fanout
    assert fanouts["splade_stage1"] == 0
    assert fanouts["host_gather:residuals"] == 2
    assert plan.stages[2].pooled
    assert fanouts["merge_topk:stage1"] == 0
    cplan = groups[2].compile_plan("colbert")
    assert "merge_topk:approx" in cplan.stage_names()
    assert cplan.stage_names()[-1] == "merge_topk"


def test_combined_access_stats_sums_segments(groups, small_corpus):
    retr = groups[2]
    stats = [sh.searcher.index.store.stats for sh in retr.shards]
    combined = CombinedAccessStats(stats)
    combined.reset()
    retr.search_batch("rerank", k=10, **_batch(small_corpus, 0, 4))
    snap = combined.snapshot()
    per = [s.snapshot() for s in stats]
    assert snap["pages_touched"] == sum(p["pages_touched"] for p in per)
    assert snap["pages_touched"] > 0
    # both segments actually gathered (parallel page-fault streams)
    assert all(p["gathers"] > 0 for p in per)


def test_shard_device_map_round_robin():
    devs = ["d0", "d1", "d2"]
    assert shard_device_map(5, devices=devs) == \
        ["d0", "d1", "d2", "d0", "d1"]
    assert len(shard_device_map(4)) == 4       # real backend: 1 CPU dev


def test_group_validates_inputs(unsharded):
    with pytest.raises(ValueError, match="empty"):
        ShardedRetriever([], [0])
    with pytest.raises(ValueError, match="boundaries"):
        ShardedRetriever([unsharded], [0, 10, 20])
