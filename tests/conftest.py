"""Test fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benchmarks must see the single real CPU device; only the
dry-run (and the subprocess-based multi-device tests) use placeholder
devices."""

import os
import sys
import types

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def _install_hypothesis_stub():
    """Let the suite collect without ``hypothesis`` (an optional test
    extra — see pyproject.toml). Six modules import it at module scope;
    this shim makes those imports succeed and turns each ``@given`` test
    into a skip, so every non-property test still runs."""
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg on purpose: pytest must not mistake the property
            # arguments for fixtures
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install '.[test]' for property tests)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def _strategy(*_args, **_kwargs):
        return None

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _strategy

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data.synth import SynthCfg, make_corpus
    return make_corpus(SynthCfg(n_docs=400, n_queries=60, vocab=1024,
                                dim=32, n_topics=24, doc_maxlen=20,
                                query_maxlen=6, seed=1))


@pytest.fixture(scope="session")
def built_index(tmp_path_factory, small_corpus):
    from repro.index.builder import ColBERTIndex, build_colbert_index
    path = tmp_path_factory.mktemp("index")
    build_colbert_index(path, small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    return path


def run_subprocess_jax(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a JAX snippet in a subprocess with fake devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    res = subprocess.run([sys.executable, "-c", code], timeout=timeout,
                         capture_output=True, text=True, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n"
            f"{res.stderr[-3000:]}")
    return res.stdout
