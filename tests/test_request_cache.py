"""Request-lifecycle refactor: typed per-request contexts, the
coordinator cache hierarchy (exact result cache + stage-1/candidate
cache), SLO-aware admission/degradation, and the loadgen realism knobs.

The load-bearing contracts:

* an exact-cache hit is **bitwise** the cold answer (all four methods,
  mixed batches, per-query k/alpha keying);
* the LRU evicts at capacity and invalidates on index-generation bump;
* cache-on answers stay bitwise-parity across 1/2/4 thread shards and
  process workers;
* admission degrades hybrid/rerank to the splade-only plan (with a
  reason code) before it sheds, and sheds are never counted as
  failures by the load generators.
"""

import numpy as np
import pytest

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import build_sharded_retriever, build_shard_group
from repro.eval.metrics import ndcg_at_k
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import (
    load_group,
    shard_boundaries,
    split_index_tree,
)
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.serving.admission import AdmissionController, RequestShed
from repro.serving.context import (
    ADMIT_DEGRADED,
    ADMIT_FULL,
    ADMIT_SHED,
    CacheHierarchy,
    LRUCache,
    query_digest,
)
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import (
    load_trace,
    run_poisson_load,
    zipf_trace,
)
from repro.serving.pipeline import DEVICE, HOST
from repro.serving.server import RetrievalServer

METHODS = ("splade", "rerank", "hybrid", "colbert")
PLAID = PlaidParams(nprobe=8, candidate_cap=512, ndocs=128, k=50)
MS = MultiStageParams(first_k=50, k=20)


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_dir(tmp_path_factory, small_corpus):
    base = tmp_path_factory.mktemp("reqcache_base")
    build_colbert_index(base / "colbert", small_corpus["doc_embs"],
                        small_corpus["doc_lens"], nbits=4,
                        n_centroids=128, kmeans_iters=4)
    build_splade_index(small_corpus["doc_term_ids"],
                       small_corpus["doc_term_weights"],
                       small_corpus["cfg"].vocab,
                       small_corpus["cfg"].n_docs).save(base / "splade")
    return base


def _fresh_retr(base_dir):
    index = ColBERTIndex(base_dir / "colbert", mode="mmap")
    sidx = SpladeIndex.load(base_dir / "splade", mmap=True)
    return MultiStageRetriever(sidx, PLAIDSearcher(index, PLAID), MS)


@pytest.fixture(scope="module")
def reference(base_dir, small_corpus):
    """Cache-free engine: the cold-answer oracle."""
    return ServeEngine(_fresh_retr(base_dir))


def _reqs(corpus, method, idxs, k=20, alpha=None, qid0=0):
    return [Request(qid=qid0 + j, method=method,
                    q_emb=corpus["q_embs"][i],
                    term_ids=corpus["q_term_ids"][i],
                    term_weights=corpus["q_term_weights"][i],
                    k=k, alpha=alpha)
            for j, i in enumerate(idxs)]


def _assert_bitwise(ref, got):
    np.testing.assert_array_equal(np.asarray(ref.pids),
                                  np.asarray(got.pids))
    r = np.asarray(ref.scores).view(np.uint32)
    g = np.asarray(got.scores).view(np.uint32)
    np.testing.assert_array_equal(r, g)


# ---------------------------------------------------------------------------
# LRU + context primitives
# ---------------------------------------------------------------------------

def test_lru_counters_eviction_and_generation_purge():
    c = LRUCache(2, name="t")
    assert c.get("a") is None and c.misses == 1
    c.put("a", 1, generation=0)
    c.put("b", 2, generation=0)
    assert c.get("a") == 1 and c.hits == 1
    c.put("c", 3, generation=1)          # evicts LRU ("b")
    assert c.evictions == 1
    assert c.get("b") is None
    assert c.purge_below(1) == 1         # "a" was generation 0
    assert c.invalidations == 1
    assert c.get("a") is None and c.get("c") == 3
    # advisory probe: a count_miss=False miss is free
    m = c.misses
    assert c.get("zzz", count_miss=False) is None
    assert c.misses == m


def test_lru_capacity_zero_disables():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None
    assert len(c) == 0 and c.hits == c.misses == 0


def test_query_digest_is_byte_exact():
    a = np.arange(6, dtype=np.float32)
    b = a.copy()
    assert query_digest(a, None, None) == query_digest(b, None, None)
    b[0] = np.float32(-0.0)              # 0.0 vs -0.0: different bytes
    assert query_digest(a, None, None) != query_digest(b, None, None)
    assert (query_digest(a, None, None)
            != query_digest(a.astype(np.float64), None, None))
    assert (query_digest(None, a.astype(np.int32), None)
            != query_digest(a.astype(np.int32), None, None))


# ---------------------------------------------------------------------------
# exact result cache: bitwise hits
# ---------------------------------------------------------------------------

def test_exact_cache_hit_is_bitwise_all_methods(base_dir, small_corpus):
    caches = CacheHierarchy(exact_entries=256)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    for m in METHODS:
        cold = eng.process_batch(_reqs(small_corpus, m, range(4)))
        assert not any(r.cache_hit for r in cold)
        warm = eng.process_batch(_reqs(small_corpus, m, range(4)))
        assert all(r.cache_hit for r in warm)
        for c, w in zip(cold, warm):
            _assert_bitwise(c, w)
    assert caches.exact.hits >= 16


def test_exact_cache_respects_per_query_k_and_alpha(base_dir,
                                                    small_corpus):
    caches = CacheHierarchy(exact_entries=256)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    cold = eng.process_batch(_reqs(small_corpus, "hybrid", [0],
                                   alpha=0.3))
    # same query, different k or alpha: different key, no hit
    r_k = eng.process_batch(_reqs(small_corpus, "hybrid", [0], k=10,
                                  alpha=0.3))
    assert not r_k[0].cache_hit and len(r_k[0].pids) == 10
    r_a = eng.process_batch(_reqs(small_corpus, "hybrid", [0],
                                  alpha=0.7))
    assert not r_a[0].cache_hit
    # exact same request shape hits
    warm = eng.process_batch(_reqs(small_corpus, "hybrid", [0],
                                   alpha=0.3))
    assert warm[0].cache_hit
    _assert_bitwise(cold[0], warm[0])


def test_mixed_batch_partial_hits_bitwise(base_dir, small_corpus,
                                          reference):
    """A mixed-method batch with some queries warm and some cold: hits
    come from the cache, misses run the retriever, and every answer is
    bitwise the cache-free engine's answer."""
    caches = CacheHierarchy(exact_entries=256)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    # warm two of the four (one hybrid, one splade)
    eng.process_batch(_reqs(small_corpus, "hybrid", [0]))
    eng.process_batch(_reqs(small_corpus, "splade", [1]))

    reqs = (_reqs(small_corpus, "hybrid", [0, 2])
            + _reqs(small_corpus, "splade", [1, 3], qid0=2))
    got = eng.process_batch(reqs)
    assert got[0].cache_hit and got[2].cache_hit
    assert not got[1].cache_hit and not got[3].cache_hit

    ref = reference.process_batch(
        _reqs(small_corpus, "hybrid", [0, 2])
        + _reqs(small_corpus, "splade", [1, 3], qid0=2))
    for r, g in zip(ref, got):
        _assert_bitwise(r, g)


def test_exact_cache_eviction_at_capacity(base_dir, small_corpus):
    caches = CacheHierarchy(exact_entries=2)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    for i in range(3):
        eng.process_batch(_reqs(small_corpus, "splade", [i]))
    assert caches.exact.evictions >= 1
    # query 0 was evicted: runs cold again
    again = eng.process_batch(_reqs(small_corpus, "splade", [0]))
    assert not again[0].cache_hit


def test_generation_bump_invalidates_everything(base_dir, small_corpus):
    caches = CacheHierarchy(exact_entries=64, stage1_entries=64)
    retr = _fresh_retr(base_dir)
    eng = ServeEngine(retr, caches=caches)
    eng.process_batch(_reqs(small_corpus, "hybrid", range(3)))
    assert len(caches.exact) > 0 and len(caches.stage1) > 0
    gen = retr.bump_index_generation()
    assert gen == 1
    assert len(caches.exact) == 0 and len(caches.stage1) == 0
    assert caches.exact.invalidations > 0
    # post-bump runs miss, recompute, and re-fill under the new salt
    cold = eng.process_batch(_reqs(small_corpus, "hybrid", range(3)))
    assert not any(r.cache_hit for r in cold)
    warm = eng.process_batch(_reqs(small_corpus, "hybrid", range(3)))
    assert all(r.cache_hit for r in warm)


# ---------------------------------------------------------------------------
# stage-1 / candidate cache
# ---------------------------------------------------------------------------

def test_stage1_cache_splade_warms_hybrid(base_dir, small_corpus,
                                          reference):
    """Stage-1 entries are method-independent for splade-first plans: a
    splade batch warms the rows a later hybrid batch reuses — and the
    hybrid answer built from cached rows is bitwise the cold one."""
    caches = CacheHierarchy(stage1_entries=256)   # exact cache OFF
    retr = _fresh_retr(base_dir)
    eng = ServeEngine(retr, caches=caches)
    eng.process_batch(_reqs(small_corpus, "splade", range(4)))
    assert len(caches.stage1) == 4
    before = caches.stage1.hits
    got = eng.process_batch(_reqs(small_corpus, "hybrid", range(4)))
    assert caches.stage1.hits >= before + 4
    assert not any(r.cache_hit for r in got)      # full plan still ran
    ref = reference.process_batch(_reqs(small_corpus, "hybrid",
                                        range(4)))
    for r, g in zip(ref, got):
        _assert_bitwise(r, g)
    counters = retr.pipeline_stats.snapshot()["counters"]
    assert counters.get("cache_stage1_hits", 0) >= 4


def test_stage1_cache_colbert_candidates(base_dir, small_corpus,
                                         reference):
    caches = CacheHierarchy(stage1_entries=256)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    cold = eng.process_batch(_reqs(small_corpus, "colbert", range(4)))
    before = caches.stage1.hits
    warm = eng.process_batch(_reqs(small_corpus, "colbert", range(4)))
    assert caches.stage1.hits > before
    ref = reference.process_batch(_reqs(small_corpus, "colbert",
                                        range(4)))
    for a, b, c in zip(ref, cold, warm):
        _assert_bitwise(a, b)
        _assert_bitwise(a, c)


# ---------------------------------------------------------------------------
# sharded parity (thread 1/2/4 shards + process workers)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shard_groups(base_dir, small_corpus):
    n_docs = small_corpus["cfg"].n_docs
    out = {}
    for s in (1, 2, 4):
        group = split_index_tree(base_dir, s,
                                 group_dir=base_dir / f"shards{s}")
        out[s] = build_sharded_retriever(
            [group / str(i) for i in range(s)],
            shard_boundaries(n_docs, s), mode="mmap",
            plaid_params=PLAID, multistage_params=MS)
    return out


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("method", ["splade", "rerank", "hybrid"])
def test_sharded_cache_parity(base_dir, small_corpus, reference,
                              shard_groups, n_shards, method):
    caches = CacheHierarchy(exact_entries=128, stage1_entries=128)
    eng = ServeEngine(shard_groups[n_shards], caches=caches)
    ref = reference.process_batch(_reqs(small_corpus, method, range(4)))
    cold = eng.process_batch(_reqs(small_corpus, method, range(4)))
    warm = eng.process_batch(_reqs(small_corpus, method, range(4)))
    assert all(r.cache_hit for r in warm)
    for a, b, c in zip(ref, cold, warm):
        np.testing.assert_array_equal(np.asarray(a.pids),
                                      np.asarray(b.pids))
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores),
                                   rtol=1e-5, atol=1e-5)
        _assert_bitwise(b, c)            # hit vs cold: bitwise


def test_sharded_stage1_group_cache(base_dir, small_corpus,
                                    shard_groups):
    """Group-level stage-1 cache (2 shards, exact cache off): the
    second identical batch skips the per-shard stage-1 fanout and
    still produces bitwise the same answer."""
    caches = CacheHierarchy(stage1_entries=128)
    retr = shard_groups[2]
    eng = ServeEngine(retr, caches=caches)
    try:
        cold = eng.process_batch(_reqs(small_corpus, "hybrid",
                                       range(4)))
        assert len(caches.stage1) == 4
        before = caches.stage1.hits
        warm = eng.process_batch(_reqs(small_corpus, "hybrid",
                                       range(4)))
        assert caches.stage1.hits >= before + 4
        for c, w in zip(cold, warm):
            _assert_bitwise(c, w)
    finally:
        retr.attach_caches(None)


def test_process_group_cache_parity(base_dir, small_corpus,
                                    shard_groups):
    dirs, bounds = load_group(base_dir / "shards2")
    g = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                          plaid_params=PLAID, multistage_params=MS)
    try:
        caches = CacheHierarchy(exact_entries=64, stage1_entries=64)
        eng = ServeEngine(g, caches=caches)
        cold = eng.process_batch(_reqs(small_corpus, "hybrid",
                                       range(4)))
        warm = eng.process_batch(_reqs(small_corpus, "hybrid",
                                       range(4)))
        assert all(r.cache_hit for r in warm)
        for c, w in zip(cold, warm):
            _assert_bitwise(c, w)
        # stage-1 rows were stored at the group (merged-row) level
        assert len(caches.stage1) == 4
    finally:
        g.close()


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------

def _snap(stage1_ms, tail_ms, dispatches=10):
    return {"splade_stage1": {"ewma_ms": stage1_ms,
                              "dispatches": dispatches},
            "device_score:maxsim": {"ewma_ms": tail_ms,
                                    "dispatches": dispatches}}


def test_admission_ladder_unit():
    ac = AdmissionController(latency_slo_ms=100.0, shed_factor=3.0)
    # cold start admits full
    assert ac.decide("hybrid", True, {}).admission == ADMIT_FULL
    assert ac.decide("hybrid", True, {}).reason == "cold_start"
    # comfortably inside SLO
    assert ac.decide("hybrid", True,
                     _snap(10, 20)).admission == ADMIT_FULL
    # tail blows SLO, stage-1 fits → degrade with reason
    d = ac.decide("hybrid", True, _snap(10, 500))
    assert d.admission == ADMIT_DEGRADED and d.reason == "slo_tail"
    # both over, cheap within shed_factor× → still degrade
    d = ac.decide("hybrid", True, _snap(150, 500))
    assert d.admission == ADMIT_DEGRADED and d.reason == "slo_overload"
    # not degradable, full within shed_factor× → best-effort full
    d = ac.decide("colbert", False, _snap(10, 200))
    assert d.admission == ADMIT_FULL and d.reason == "slo_best_effort"
    # hopeless → shed
    d = ac.decide("hybrid", True, _snap(5000, 5000))
    assert d.admission == ADMIT_SHED and d.reason == "overload"
    # splade requests are costed at stage-1 only
    assert ac.decide("splade", False,
                     _snap(50, 9000)).admission == ADMIT_FULL
    # a tight per-request deadline sheds with reason "deadline"
    d = ac.decide("hybrid", True, _snap(50, 60), deadline_ms=1.0)
    assert d.admission == ADMIT_SHED and d.reason == "deadline"
    s = ac.stats()
    assert s["full_admits"] + s["degraded_admits"] + s["sheds"] == 9


def _poison(retr, stage1_s, tail_s):
    for _ in range(4):                   # drive the EWMA, not one sample
        retr.pipeline_stats.record("splade_stage1", HOST,
                                   wall_s=stage1_s)
        retr.pipeline_stats.record("device_score:maxsim", DEVICE,
                                   wall_s=tail_s)


def test_admission_degrades_hybrid_to_splade(base_dir, small_corpus,
                                             reference):
    """A stalled rerank tail (poisoned EWMA) degrades hybrid requests
    to the splade-only plan: the answer matches splade bitwise and
    carries degraded=True with the SLO reason code."""
    retr = _fresh_retr(base_dir)
    eng = ServeEngine(retr)
    srv = RetrievalServer(eng, n_threads=1,
                          admission=AdmissionController(50.0))
    srv.start()
    try:
        _poison(retr, stage1_s=0.001, tail_s=10.0)
        res = srv.submit(_reqs(small_corpus, "hybrid", [7])[0]) \
                 .result(timeout=60)
        assert res.degraded and res.degrade_reason == "slo_tail"
        ref = reference.process(_reqs(small_corpus, "splade", [7])[0])
        _assert_bitwise(ref, res)
        h = srv.health()
        assert h["admission"]["degraded_admits"] == 1
        assert h["counters"].get("admission_degraded", 0) == 1
    finally:
        srv.stop()


def test_admission_sheds_before_queueing(base_dir, small_corpus):
    retr = _fresh_retr(base_dir)
    eng = ServeEngine(retr)
    srv = RetrievalServer(eng, n_threads=1,
                          admission=AdmissionController(50.0))
    srv.start()
    try:
        _poison(retr, stage1_s=10.0, tail_s=10.0)   # even splade hopeless
        fut = srv.submit(_reqs(small_corpus, "hybrid", [3])[0])
        with pytest.raises(RequestShed) as ei:
            fut.result(timeout=10)
        assert ei.value.reason == "overload"
        h = srv.health()
        assert h["sheds"] == 1 and h["served"] == 0
        assert h["admission"]["sheds"] == 1
    finally:
        srv.stop()


def test_shed_counted_separately_by_loadgen(base_dir, small_corpus):
    retr = _fresh_retr(base_dir)
    srv = RetrievalServer(ServeEngine(retr), n_threads=1,
                          admission=AdmissionController(50.0))
    srv.start()
    try:
        _poison(retr, stage1_s=10.0, tail_s=10.0)
        reqs = _reqs(small_corpus, "colbert", range(6))
        res = run_poisson_load(srv, reqs, qps=500.0, seed=0)
        assert res.shed == 6 and res.failed == 0
        assert len(res.latencies) == 0
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# loadgen realism: Zipf skew, trace replay, outcome counters
# ---------------------------------------------------------------------------

def test_zipf_trace_skews_and_uniform_degenerates():
    t = zipf_trace(4000, 50, skew=1.3, seed=7)
    assert t.min() >= 0 and t.max() < 50
    counts = np.bincount(t, minlength=50)
    # heavy head: the most popular query dwarfs the uniform share
    assert counts.max() > 4 * (4000 / 50)
    u = zipf_trace(4000, 50, skew=0.0, seed=7)
    uc = np.bincount(u, minlength=50)
    assert uc.max() < 3 * (4000 / 50)
    # determinism
    np.testing.assert_array_equal(t, zipf_trace(4000, 50, skew=1.3,
                                                seed=7))


def test_load_trace_parses_and_rejects_empty(tmp_path):
    p = tmp_path / "trace.txt"
    p.write_text("# comment\n3\n1\n\n2  # inline\n")
    np.testing.assert_array_equal(load_trace(p), [3, 1, 2])
    empty = tmp_path / "empty.txt"
    empty.write_text("# nothing\n")
    with pytest.raises(ValueError):
        load_trace(empty)


def test_loadgen_counts_cache_hits_and_trace_mix(base_dir,
                                                 small_corpus):
    caches = CacheHierarchy(exact_entries=64)
    eng = ServeEngine(_fresh_retr(base_dir), caches=caches)
    srv = RetrievalServer(eng, n_threads=1)
    srv.start()
    try:
        eng.process_batch(_reqs(small_corpus, "splade", [0, 1, 2]))
        trace = [0, 1, 0, 1, 0, 2]       # 3 unique, 3 repeats
        reqs = []
        for j, q in enumerate(trace):
            r = _reqs(small_corpus, "splade", [q], qid0=j)[0]
            r.trace_id = q
            reqs.append(r)
        res = run_poisson_load(srv, reqs, qps=2000.0, seed=0)
        assert res.unique_queries == 3 and res.repeat_queries == 3
        assert res.cache_hits == 6       # cache pre-warmed: every hit
        s = res.summary()
        assert s["cache_hits"] == res.cache_hits
        assert s["shed"] == 0 and s["degraded"] == 0
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# graded-relevance metric
# ---------------------------------------------------------------------------

def test_ndcg_at_k():
    ranked = np.array([[5, 3, 9], [1, 2, 3]])
    # binary: perfect first hit vs miss
    assert ndcg_at_k(ranked, [{5}, {7}], k=3) == pytest.approx(0.5)
    # graded: putting the high-gain doc first scores higher
    good = ndcg_at_k(np.array([[5, 3]]), [{5: 3.0, 3: 1.0}], k=2)
    bad = ndcg_at_k(np.array([[3, 5]]), [{5: 3.0, 3: 1.0}], k=2)
    assert good == pytest.approx(1.0) and bad < good
    # empty relevance contributes zero, not NaN
    assert ndcg_at_k(ranked, [set(), {1}], k=3) == pytest.approx(0.5)
