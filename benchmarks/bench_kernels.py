"""Kernel-level benchmark: wall time of the jitted scoring paths on
this host (CPU; TPU numbers come from the dry-run roofline) plus the
analytic HBM-traffic comparison fused-vs-unfused that motivates the
decompress_maxsim kernel (the TPU adaptation of "don't materialise the
index")."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.kernels.decompress_maxsim.ops import (
    decompress_maxsim_scores,
    decompress_maxsim_scores_batch,
)
from repro.kernels.fused_rerank.ops import fused_rerank_topk_batch
from repro.kernels.maxsim.ops import maxsim_scores
from repro.kernels.splade_score.ops import splade_block_scores


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def hbm_model(C, Ld, d, nbits, Lq):
    """Per-candidate HBM bytes: fused reads packed codes + cid; the
    unfused pipeline additionally writes+reads the fp32 embeddings."""
    packed = Ld * d * nbits // 8 + Ld * 4
    fp32 = Ld * d * 4
    return {"fused_bytes": C * packed,
            "unfused_bytes": C * (packed + 2 * fp32),
            "traffic_ratio": (packed + 2 * fp32) / packed}


def main(quick: bool = False):
    out = {}
    k = jax.random.PRNGKey(0)
    C, Ld, Lq, d, nbits = (256 if quick else 1024), 96, 32, 128, 4

    q = jax.random.normal(k, (Lq, d))
    docs = jax.random.normal(jax.random.fold_in(k, 1), (C, Ld, d))
    valid = jnp.ones((C, Ld), bool)
    t_maxsim = _time(lambda a, b, c: maxsim_scores(a, b, c, impl="ref"),
                     q, docs, valid)

    packed = jax.random.randint(jax.random.fold_in(k, 2),
                                (C, Ld, d * nbits // 8), 0, 256
                                ).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 3), (C, Ld), 0, 4096)
    cent = jax.random.normal(jax.random.fold_in(k, 4), (4096, d))
    bw = jnp.linspace(-0.2, 0.2, 16)
    t_fused = _time(lambda *a: decompress_maxsim_scores(
        *a, nbits=nbits, impl="ref"), q, packed, cids, valid, cent, bw)

    pids = jax.random.randint(jax.random.fold_in(k, 5), (32, 512), -1,
                              100_000, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 6), (32, 512))
    w = jax.random.uniform(jax.random.fold_in(k, 7), (32,))
    t_splade = _time(lambda *a: splade_block_scores(
        *a, n_docs=100_000, impl="ref"), pids, imps, w)

    # fused rerank tail (decompress + MaxSim + top-k, one dispatch) vs
    # the split serving tail (score dispatch, eager mask, eager top-k):
    # identical results, so the comparison is pure wall + the peak
    # intermediate-tensor footprint between dispatches
    B, Ct, Ldt, k_top = (2, 128, 24, 50) if quick else (8, 256, 32, 100)
    qb = jax.random.normal(jax.random.fold_in(k, 8), (B, Lq, d))
    packed_b = jax.random.randint(
        jax.random.fold_in(k, 9), (B, Ct, Ldt, d * nbits // 8), 0, 256
        ).astype(jnp.uint8)
    cids_b = jax.random.randint(jax.random.fold_in(k, 10), (B, Ct, Ldt),
                                0, 4096)
    valid_b = jnp.ones((B, Ct, Ldt), bool)
    cmask_b = jnp.ones((B, Ct), bool)

    def split_tail(q_, p_, c_, v_, m_):
        s = decompress_maxsim_scores_batch(q_, p_, c_, v_, cent, bw,
                                           nbits=nbits, impl="ref")
        s = jnp.where(m_, s, -jnp.inf)
        return jax.lax.top_k(s, k_top)

    t_split = _time(split_tail, qb, packed_b, cids_b, valid_b, cmask_b)
    t_ftail = _time(lambda *a: fused_rerank_topk_batch(
        *a, cent, bw, nbits=nbits, k=k_top, impl="ref"),
        qb, packed_b, cids_b, valid_b, cmask_b)
    model = hbm_model(C, Ld, d, nbits, Lq)
    kp = min(-(-min(k_top, Ct) // 8) * 8, Ct)
    rerank_model = {
        # split: the full (B, C) fp32 score tensor round-trips HBM
        # twice (raw + masked copy) before selection reads it back
        "rerank_split_scores_bytes": 2 * B * Ct * 4,
        # fused kernel: only the running (kp,) top-k state per query
        "rerank_fused_scores_bytes": B * kp * (4 + 4),
    }
    out.update({
        "maxsim_ms": t_maxsim * 1e3,
        "decompress_maxsim_ms": t_fused * 1e3,
        "splade_score_ms": t_splade * 1e3,
        "rerank_split_tail_ms": t_split * 1e3,
        "rerank_fused_tail_ms": t_ftail * 1e3,
        "rerank_tail_batch": B, "rerank_tail_candidates": Ct,
        "rerank_tail_k": k_top,
        "candidates": C, "doc_maxlen": Ld,
        **model, **rerank_model,
    })
    print(f"maxsim({C}x{Ld})           {t_maxsim * 1e3:8.2f} ms")
    print(f"decompress_maxsim({C}x{Ld}) {t_fused * 1e3:8.2f} ms")
    print(f"splade_score(32x512)      {t_splade * 1e3:8.2f} ms")
    print(f"rerank tail ({B}x{Ct}, k={k_top}): split "
          f"{t_split * 1e3:.2f} ms / fused {t_ftail * 1e3:.2f} ms; "
          f"peak scores bytes {rerank_model['rerank_split_scores_bytes']}"
          f" -> {rerank_model['rerank_fused_scores_bytes']}")
    print(f"fused vs unfused HBM traffic: {model['traffic_ratio']:.1f}x "
          f"less for the fused kernel")
    assert model["traffic_ratio"] > 10
    assert (rerank_model["rerank_fused_scores_bytes"]
            < rerank_model["rerank_split_scores_bytes"])
    save("kernels", out)
    return out


if __name__ == "__main__":
    main()
