"""Kernel-level benchmark: wall time of the jitted scoring paths on
this host (CPU; TPU numbers come from the dry-run roofline) plus the
analytic HBM-traffic comparison fused-vs-unfused that motivates the
decompress_maxsim kernel (the TPU adaptation of "don't materialise the
index")."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.kernels.decompress_maxsim.ops import decompress_maxsim_scores
from repro.kernels.maxsim.ops import maxsim_scores
from repro.kernels.splade_score.ops import splade_block_scores


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def hbm_model(C, Ld, d, nbits, Lq):
    """Per-candidate HBM bytes: fused reads packed codes + cid; the
    unfused pipeline additionally writes+reads the fp32 embeddings."""
    packed = Ld * d * nbits // 8 + Ld * 4
    fp32 = Ld * d * 4
    return {"fused_bytes": C * packed,
            "unfused_bytes": C * (packed + 2 * fp32),
            "traffic_ratio": (packed + 2 * fp32) / packed}


def main(quick: bool = False):
    out = {}
    k = jax.random.PRNGKey(0)
    C, Ld, Lq, d, nbits = (256 if quick else 1024), 96, 32, 128, 4

    q = jax.random.normal(k, (Lq, d))
    docs = jax.random.normal(jax.random.fold_in(k, 1), (C, Ld, d))
    valid = jnp.ones((C, Ld), bool)
    t_maxsim = _time(lambda a, b, c: maxsim_scores(a, b, c, impl="ref"),
                     q, docs, valid)

    packed = jax.random.randint(jax.random.fold_in(k, 2),
                                (C, Ld, d * nbits // 8), 0, 256
                                ).astype(jnp.uint8)
    cids = jax.random.randint(jax.random.fold_in(k, 3), (C, Ld), 0, 4096)
    cent = jax.random.normal(jax.random.fold_in(k, 4), (4096, d))
    bw = jnp.linspace(-0.2, 0.2, 16)
    t_fused = _time(lambda *a: decompress_maxsim_scores(
        *a, nbits=nbits, impl="ref"), q, packed, cids, valid, cent, bw)

    pids = jax.random.randint(jax.random.fold_in(k, 5), (32, 512), -1,
                              100_000, jnp.int32)
    imps = jax.random.uniform(jax.random.fold_in(k, 6), (32, 512))
    w = jax.random.uniform(jax.random.fold_in(k, 7), (32,))
    t_splade = _time(lambda *a: splade_block_scores(
        *a, n_docs=100_000, impl="ref"), pids, imps, w)

    model = hbm_model(C, Ld, d, nbits, Lq)
    out.update({
        "maxsim_ms": t_maxsim * 1e3,
        "decompress_maxsim_ms": t_fused * 1e3,
        "splade_score_ms": t_splade * 1e3,
        "candidates": C, "doc_maxlen": Ld,
        **model,
    })
    print(f"maxsim({C}x{Ld})           {t_maxsim * 1e3:8.2f} ms")
    print(f"decompress_maxsim({C}x{Ld}) {t_fused * 1e3:8.2f} ms")
    print(f"splade_score(32x512)      {t_splade * 1e3:8.2f} ms")
    print(f"fused vs unfused HBM traffic: {model['traffic_ratio']:.1f}x "
          f"less for the fused kernel")
    assert model["traffic_ratio"] > 10
    save("kernels", out)
    return out


if __name__ == "__main__":
    main()
