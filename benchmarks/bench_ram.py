"""Table 1: RAM usage and machine cost — in-memory vs memory-mapped
index load, with the paper's RSS-delta methodology, plus working-set
(resident-fraction) accounting under rerank traffic.

Scale note: the pool/metadata ratio grows with corpus size (pool ∝
tokens, metadata ∝ √tokens via the centroid heuristic), so this bench
builds a corpus large enough that the pool dominates — the regime the
paper's 90 % claim lives in (MS MARCO: 23.4 GB pool vs 2.3 GB resident).
"""

from __future__ import annotations

import gc
import pathlib
import tempfile

import numpy as np

from benchmarks.common import save
from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.store import PagedStore, rss_bytes
from repro.data.synth import SynthCfg, make_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import build_splade_index

# AWS-style $/GB-month of RAM (r6a family effective rate); the paper's
# Table 1 machine costs scale ~linearly in RAM.
USD_PER_GB_MONTH = 3.42

CFGS = {
    # larger corpus: pool ≫ metadata, the paper's regime
    "wiki_like": dict(synth=SynthCfg(n_docs=12000, n_queries=60,
                                     n_topics=128, doc_maxlen=48,
                                     doc_minlen=32, seed=5),
                      n_centroids=1024, n_queries_ws=25),
    "marco_like": dict(synth=SynthCfg(n_docs=6000, n_queries=60,
                                      n_topics=96, doc_maxlen=40,
                                      doc_minlen=24, seed=6),
                       n_centroids=1024, n_queries_ws=25),
}


def measure(name: str):
    spec = CFGS[name]
    cfg = spec["synth"]
    corpus = make_corpus(cfg)
    d = pathlib.Path(tempfile.mkdtemp(prefix=f"ram_{name}_"))
    build_colbert_index(d, corpus["doc_embs"], corpus["doc_lens"],
                        nbits=4, n_centroids=spec["n_centroids"],
                        kmeans_iters=4)
    index = ColBERTIndex(d, mode="mmap")
    pool_bytes = index.store.total_bytes()
    meta_bytes = (index.centroids.nbytes + index.bucket_weights.nbytes
                  + index.doclens.nbytes + index.doc_offsets.nbytes
                  + index.ivf.pids.nbytes)

    gc.collect()
    r0 = rss_bytes()
    ram_store = PagedStore(d, mode="ram")
    ram_rss = rss_bytes() - r0
    del ram_store
    gc.collect()
    r0 = rss_bytes()
    mmap_store = PagedStore(d, mode="mmap")
    mmap_rss = max(rss_bytes() - r0, 0)
    del mmap_store

    in_mem_total = pool_bytes + meta_bytes
    mmap_total = meta_bytes
    reduction = 1.0 - mmap_total / in_mem_total

    # working set under rerank traffic
    sidx = build_splade_index(corpus["doc_term_ids"],
                              corpus["doc_term_weights"], cfg.vocab,
                              cfg.n_docs)
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=4,
                                                candidate_cap=1024,
                                                ndocs=128, k=50))
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=100, k=50))
    index.store.stats.reset()
    for qi in range(spec["n_queries_ws"]):
        retr.search("rerank", q_emb=corpus["q_embs"][qi],
                    term_ids=corpus["q_term_ids"][qi],
                    term_weights=corpus["q_term_weights"][qi])
    resident_frac = index.store.resident_fraction_estimate()

    gb = 2 ** 30
    cost = lambda b: b / gb * USD_PER_GB_MONTH
    out = {
        "pool_bytes": pool_bytes, "metadata_bytes": meta_bytes,
        "load_bytes_in_memory": in_mem_total,
        "load_bytes_mmap": mmap_total,
        "ram_reduction": reduction,
        "rss_delta_ram_load": int(ram_rss),
        "rss_delta_mmap_load": int(mmap_rss),
        "rerank_working_set_fraction": resident_frac,
        "cost_month_in_memory_usd": cost(in_mem_total),
        "cost_month_mmap_usd": cost(mmap_total
                                    + resident_frac * pool_bytes),
    }
    print(f"== RAM ({name}) ==")
    print(f"pool {pool_bytes / 1e6:.1f} MB, metadata {meta_bytes / 1e6:.1f} MB")
    print(f"load: in-memory {in_mem_total / 1e6:.1f} MB vs mmap "
          f"{mmap_total / 1e6:.1f} MB  (−{100 * reduction:.0f}%)")
    print(f"RSS delta: ram-load {ram_rss / 1e6:.1f} MB vs mmap-open "
          f"{mmap_rss / 1e6:.1f} MB")
    print(f"rerank working set: {100 * resident_frac:.1f}% of pool")
    print(f"RAM cost model: ${out['cost_month_in_memory_usd']:.4f} vs "
          f"${out['cost_month_mmap_usd']:.4f} /month")
    assert reduction > 0.80, f"expected ≥80% load-RAM reduction, got {reduction}"
    assert mmap_rss < 0.2 * ram_rss + 2e6
    return out


def main(quick: bool = False):
    out = {"wiki_like": measure("wiki_like")}
    if not quick:
        out["marco_like"] = measure("marco_like")
    save("ram_table1", out)
    return out


if __name__ == "__main__":
    main()
