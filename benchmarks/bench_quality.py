"""Table 2: retrieval quality of ColBERTv2 / SPLADEv2 / Rerank / Hybrid
on the in-domain set (α tuned there) and two OOD sets, reporting
MRR@10, nDCG@10, R@5, R@50, S@5 and Δ% vs full ColBERTv2 — plus the
degraded-mode guardrail: what SLO-driven degradation to the splade-only
plan costs against the full hybrid answer."""

from __future__ import annotations

from benchmarks.common import DATASETS, dataset, run_all_queries, save
from repro.eval import metrics

METHODS = ["colbert", "splade", "rerank", "hybrid"]


def evaluate(name: str, alpha: float = 0.3):
    corpus, _, _, retr = dataset(name)
    qrels = corpus["qrels"]
    out = {}
    for m in METHODS:
        ranked, _ = run_all_queries(retr, corpus, m, alpha=alpha)
        out[m] = {
            "MRR@10": metrics.mrr_at_k(ranked, qrels, 10),
            "nDCG@10": metrics.ndcg_at_k(ranked, qrels, 10),
            "R@5": metrics.recall_at_k(ranked, qrels, 5),
            "R@50": metrics.recall_at_k(ranked, qrels, 50),
            "S@5": metrics.success_at_k(ranked, qrels, 5),
        }
    return out


def degraded_delta(res: dict) -> dict:
    """Quality cost of the admission ladder's degraded rung: the
    splade-only plan (what a degraded hybrid/rerank request is served)
    vs the full hybrid answer."""
    return {
        "MRR@10_full": res["hybrid"]["MRR@10"],
        "MRR@10_degraded": res["splade"]["MRR@10"],
        "MRR@10_delta": res["splade"]["MRR@10"] - res["hybrid"]["MRR@10"],
        "nDCG@10_full": res["hybrid"]["nDCG@10"],
        "nDCG@10_degraded": res["splade"]["nDCG@10"],
        "nDCG@10_delta": res["splade"]["nDCG@10"]
        - res["hybrid"]["nDCG@10"],
    }


def main(quick: bool = False):
    names = ["marco"] if quick else list(DATASETS)
    table = {}
    for name in names:
        res = evaluate(name)
        table[name] = res
        base = res["colbert"]["S@5"]
        print(f"\n== {name} ==")
        print(f"{'method':10s} MRR@10  nDCG@10 R@5    R@50   S@5    ΔS@5")
        for m in METHODS:
            r = res[m]
            delta = 100 * (r["S@5"] - base) / max(base, 1e-9)
            print(f"{m:10s} {r['MRR@10']:.4f} {r['nDCG@10']:.4f}  "
                  f"{r['R@5']:.4f} {r['R@50']:.4f} {r['S@5']:.4f} "
                  f"{delta:+.1f}%")
        dd = degraded_delta(res)
        table[name]["degraded_mode"] = dd
        print(f"degraded (splade-only) vs full hybrid: "
              f"ΔMRR@10={dd['MRR@10_delta']:+.4f} "
              f"ΔnDCG@10={dd['nDCG@10_delta']:+.4f}")
        # paper-shape assertions (trend checks, not absolute numbers)
        assert res["hybrid"]["MRR@10"] >= res["rerank"]["MRR@10"] - 0.01
        assert res["hybrid"]["MRR@10"] > res["splade"]["MRR@10"]
        assert res["colbert"]["MRR@10"] > res["splade"]["MRR@10"]
        # degraded answers trade quality for latency, but must stay
        # answers: the cheap plan keeps a usable fraction of hybrid's
        # graded relevance
        assert dd["nDCG@10_degraded"] > 0.5 * dd["nDCG@10_full"]
    save("quality_table2", table)
    return table


if __name__ == "__main__":
    main()
