"""Benchmark driver: one module per paper table/figure + kernel and
roofline summaries. ``python -m benchmarks.run [--quick]``."""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single dataset, fewer queries")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_alpha, bench_kernels, bench_latency,
                            bench_quality, bench_ram)
    suites = [
        ("quality_table2", bench_quality.main),
        ("alpha_table3", bench_alpha.main),
        ("ram_table1", bench_ram.main),
        ("latency_fig12", bench_latency.main),
        ("kernels", bench_kernels.main),
    ]
    failures = []
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        print(f"\n########## {name} ##########")
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"[{name}] OK in {time.time() - t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAILED after {time.time() - t0:.1f}s")

    # roofline summary from the dry-run artefacts, if present
    try:
        import pathlib

        from repro.launch.roofline import table
        d = pathlib.Path("results/dryrun")
        if any(d.glob("*.json")):
            print("\n########## roofline (single-pod, from dry-run) ##########")
            print(table(d, "single"))
    except Exception:
        traceback.print_exc()

    print(f"\nbenchmarks done; failures: {failures or 'none'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
