"""Fig 1/2: p95 latency vs offered QPS per method under Poisson
arrivals through the concurrent server — the paper's serving
methodology (client-observed latency includes queueing; saturation
knee at the service-rate reciprocal) — plus a throughput-vs-batch-size
sweep for the cross-query micro-batcher, a per-stage latency breakdown
(stage 1 vs stages 2–4), a stage-1 backend sweep (host / jax / pallas,
batched vs per-query), a stage-graph pipeline sweep
(``--pipeline-sweep``: QPS + measured host/device overlap fraction at
depths 1/2/4), a scatter-gather shard sweep (``--shard-sweep``:
QPS + gather-stage wall time at shard counts 1/2/4 — per-shard mmap
segments fault independent page streams, so the gather stage shrinks
as the shard count grows), and a shard-worker backend sweep
(``--worker-sweep``: in-process thread workers vs shared-nothing
process workers at shards 1/2/4, the latter on both the zero-copy shm
arena transport and the socket stream — QPS/p99 plus per-worker RSS,
mmap-segment bytes, the transport's copied vs zero-copy byte split and
RPC dispatch/coalescing counts, showing the aggregate pool is split
across worker processes, not replicated, and that tensor bytes cross
the shm path without serialization), and a cache/admission sweep
(``--cache-sweep``: Zipf-skewed open-loop load at 10x the uniform
cache-off capacity through the coordinator cache hierarchy — hit rate,
hit-path vs miss-path p99, bitwise parity of cached answers against
the cache-off engine, and the SLO admission ladder under overload:
degraded/shed counts plus the p99 the shed-bounded queue keeps vs the
unbounded no-admission queue)."""

from __future__ import annotations

import argparse
import os
import sys
import time

if "--pipeline-sweep" in sys.argv and "XLA_FLAGS" not in os.environ:
    # CPU stand-in for the TPU serve path: pin XLA's CPU compute to one
    # thread so device-bound stages model a resource distinct from the
    # host-gather cores (on TPU the device queue is separate hardware
    # and takes no host cores). Must happen before jax initialises.
    os.environ["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                               "intra_op_parallelism_threads=1")

import numpy as np

from benchmarks.common import dataset, save
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import run_poisson_load
from repro.serving.server import RetrievalServer

METHODS = ["splade", "rerank", "hybrid", "colbert"]
BATCH_SIZES = (1, 4, 16)
STAGE1_BACKENDS = ("host", "jax")     # pallas rides on TPU runs only
PIPELINE_DEPTHS = (1, 2, 4)
SHARD_COUNTS = (1, 2, 4)


def _requests(corpus, method, n):
    reqs = []
    for qi in range(n):
        reqs.append(Request(
            qid=qi, method=method, q_emb=corpus["q_embs"][qi],
            term_ids=corpus["q_term_ids"][qi],
            term_weights=corpus["q_term_weights"][qi], k=20))
    return reqs


def measure(name: str = "marco", n_queries: int = 60,
            n_threads: int = 1):
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for method in METHODS:
        engine = ServeEngine(retr)
        # measure service rate first (sequential warm run)
        warm = _requests(corpus, method, 10)
        srv = RetrievalServer(engine, n_threads=n_threads)
        srv.start()
        for r in warm:
            srv.submit(r).result(timeout=120)
        t = [srv.submit(r).result(timeout=120).service_time
             for r in _requests(corpus, method, 10)]
        service = float(np.mean(t))
        rate = 1.0 / service
        # offered loads relative to capacity: the paper sweeps QPS and
        # finds the knee at ~1/service_time
        out[method] = {"service_time": service, "capacity_qps": rate,
                       "points": []}
        for frac in (0.25, 0.5, 0.8, 1.5):
            qps = rate * frac
            res = run_poisson_load(srv, _requests(corpus, method,
                                                  n_queries), qps, seed=7)
            out[method]["points"].append(
                {"offered_qps": qps, "rel_load": frac,
                 **res.summary()})
        srv.stop()
        pts = out[method]["points"]
        print(f"{method:8s} svc={service * 1e3:6.1f}ms cap={rate:6.1f}qps  "
              + "  ".join(f"{p['rel_load']:.2f}x:p95={p['p95'] * 1e3:6.1f}ms"
                          for p in pts))
    return out


def measure_batch_sweep(name: str = "marco", method: str = "hybrid",
                        n_queries: int = 96,
                        batch_sizes=BATCH_SIZES):
    """Offline throughput (QPS) of the micro-batched server at several
    ``max_batch`` settings, all requests offered up-front so the batcher
    coalesces maximally. max_batch=1 is the sequential baseline."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for bs in batch_sizes:
        srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=bs,
                              batch_timeout_ms=4.0)
        srv.start()
        for r in _requests(corpus, method, 8):      # warm single-query path
            srv.submit(r).result(timeout=300)
        # warm the batched bucket: a burst deep enough to coalesce fully
        for f in [srv.submit(r) for r in _requests(corpus, method, 2 * bs)]:
            f.result(timeout=600)
        t0 = time.perf_counter()
        futs = [srv.submit(r) for r in _requests(corpus, method, n_queries)]
        for f in futs:
            f.result(timeout=600)
        wall = time.perf_counter() - t0
        srv.stop()
        out[bs] = {"qps": n_queries / wall, "wall_s": wall}
        print(f"batch={bs:3d}  qps={out[bs]['qps']:7.1f}  "
              f"wall={wall * 1e3:7.1f}ms")
    return out


def measure_stage_breakdown(name: str = "marco", method: str = "hybrid",
                            n_queries: int = 32, backend: str = "host"):
    """Per-stage latency split for one backend: stage-1 (SPLADE) wall
    time vs stages 2–4 (rerank + fusion), averaged per query."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    retr.set_splade_backend(backend)
    try:
        for qi in range(4):               # warm compile caches
            retr.search(method, q_emb=corpus["q_embs"][qi],
                        term_ids=corpus["q_term_ids"][qi],
                        term_weights=corpus["q_term_weights"][qi], k=20)
        retr.reset_stage_stats()
        t0 = time.perf_counter()
        for qi in range(n_queries):
            retr.search(method, q_emb=corpus["q_embs"][qi],
                        term_ids=corpus["q_term_ids"][qi],
                        term_weights=corpus["q_term_weights"][qi], k=20)
        wall = time.perf_counter() - t0
        st = retr.stage_stats
        out = {"backend": backend, "method": method,
               "stage1_ms_per_q": st["stage1_s"] / n_queries * 1e3,
               "rest_ms_per_q": st["rest_s"] / n_queries * 1e3,
               "total_ms_per_q": wall / n_queries * 1e3,
               "stage1_fraction": st["stage1_s"] / max(wall, 1e-12)}
    finally:
        retr.set_splade_backend("host")
    print(f"breakdown[{backend:6s}] stage1={out['stage1_ms_per_q']:6.2f}ms "
          f"rest={out['rest_ms_per_q']:6.2f}ms "
          f"({100 * out['stage1_fraction']:4.1f}% stage1)")
    return out


def measure_stage1_backends(name: str = "marco", B: int = 16,
                            rounds: int = 4,
                            backends=STAGE1_BACKENDS):
    """Stage-1 throughput per backend: one batched B-query dispatch vs
    B per-query dispatches on the same backend (the batching win the
    tentpole claims — batched must beat the loop)."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    tids = [corpus["q_term_ids"][i % len(corpus["q_term_ids"])]
            for i in range(B)]
    tw = [corpus["q_term_weights"][i % len(corpus["q_term_weights"])]
          for i in range(B)]
    out = {}
    for be in backends:
        retr.set_splade_backend(be)
        try:
            retr.run_splade_batch(tids, tw)           # warm batched shape
            for i in range(min(4, B)):                # warm B=1 shape
                retr.run_splade(tids[i], tw[i])
            t0 = time.perf_counter()
            for _ in range(rounds):
                retr.run_splade_batch(tids, tw)
            t_batch = (time.perf_counter() - t0) / rounds
            t0 = time.perf_counter()
            for _ in range(rounds):
                for i in range(B):
                    retr.run_splade(tids[i], tw[i])
            t_loop = (time.perf_counter() - t0) / rounds
        finally:
            retr.set_splade_backend("host")
        out[be] = {"batch_ms": t_batch * 1e3, "loop_ms": t_loop * 1e3,
                   "speedup": t_loop / max(t_batch, 1e-12),
                   "batch_qps": B / t_batch, "loop_qps": B / t_loop}
        print(f"stage1[{be:6s}] B={B:3d}: batched {t_batch * 1e3:7.2f}ms "
              f"vs {B}x1 {t_loop * 1e3:7.2f}ms "
              f"→ {out[be]['speedup']:.2f}x")
    return out


# stage-4 tail stage names under each rerank backend — the fused tail
# is one dispatch + a free sync stage; the split tail is the legacy
# multi-dispatch scorer followed by an eager mask/top-k fuse
_STAGE4_NAMES = ("fused_rerank", "fused_rerank:sync",
                 "device_score:exact", "device_score:maxsim", "fuse_topk")


def _stage4_wall(snap):
    return sum(r["wall_s"] for n, r in snap["stages"].items()
               if n in _STAGE4_NAMES)


def measure_pipeline_sweep(name: str = "marco", method: str = "hybrid",
                           n_queries: int = 384, max_batch: int = 16,
                           depths=PIPELINE_DEPTHS, trials: int = 5):
    """Engine-level pipeline throughput + measured host/device overlap
    fraction at several depths.

    depth=1 runs each micro-batch synchronously through
    ``ServeEngine.process_batch`` (one batch owned end-to-end); >= 2
    feeds ``process_batch_async`` so batch N's device scoring (async
    dispatch, lazy sync) executes while batch N+1's host mmap gather
    runs. Measuring at the engine isolates the executor's effect from
    server/client future machinery, whose jitter on small shared hosts
    is larger than the overlap win itself. Depths are interleaved
    across ``trials`` rounds; per-request results are checked identical
    across depths.

    The reported ``qps`` is the **median** across trials — ambient noise
    on shared hosts is bursty and multiplicative, so a max would reward
    whichever depth happened to catch the machine's fastest moment,
    while the median tracks the typical rate. ``qps_best`` keeps the
    fastest round for reference.

    Run via ``python benchmarks/bench_latency.py --pipeline-sweep`` to
    also pin XLA CPU compute to one thread (see module header) — the
    configuration whose depth-2 >= depth-1 throughput claim the bench
    asserts.

    The sweep runs under the default ``fused`` stage-4 tail, then
    re-measures depth 1 under ``rerank_backend="split"``: the recorded
    ``stage4_depth1`` block compares the two tails' stage-4 wall (the
    cost the fusion erases — score dispatch + mask + top-k collapsing
    into one launch) and checks the fused path never executes a
    ``fuse_topk`` stage. Results across backends are asserted
    identical (the bitwise-parity contract)."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    n_q = len(corpus["q_embs"])
    request_batches = [
        [Request(qid=i, method=method, q_emb=corpus["q_embs"][i % n_q],
                 term_ids=corpus["q_term_ids"][i % n_q],
                 term_weights=corpus["q_term_weights"][i % n_q], k=20)
         for i in range(lo, lo + max_batch)]
        for lo in range(0, n_queries, max_batch)]

    def one_round(depth):
        eng = ServeEngine(retr, pipeline_depth=depth)
        retr.reset_stage_stats()
        t0 = time.perf_counter()
        if depth == 1:
            results = [eng.process_batch(b) for b in request_batches]
        else:
            futs = [eng.process_batch_async(b) for b in request_batches]
            results = [f.result(timeout=600) for f in futs]
        wall = time.perf_counter() - t0
        snap = retr.pipeline_stats.snapshot()
        eng.close()
        return n_queries / wall, snap, results

    for depth in depths:
        one_round(depth)     # warm compile caches + executor code paths

    old_si = sys.getswitchinterval()
    sys.setswitchinterval(5e-4)    # cut GIL handoff latency between the
    out = {str(d): {"qps_trials": []} for d in depths}  # worker threads
    baseline = None
    fused_s4, split_s4, split_qps = [], [], []
    split_snap = None
    try:
        for _ in range(trials):
            for depth in depths:
                qps, snap, results = one_round(depth)
                rec = out[str(depth)]
                rec["qps_trials"].append(qps)
                if qps >= max(rec["qps_trials"]):
                    rec["overlap_fraction"] = snap["overlap_fraction"]
                    rec["stage_wall_s"] = {
                        n_: r["wall_s"]
                        for n_, r in snap["stages"].items()}
                    rec["stage_dispatches"] = {
                        n_: {"dispatches": r["dispatches"],
                             "device_dispatches": r["device_dispatches"]}
                        for n_, r in snap["stages"].items()}
                if depth == 1:
                    fused_s4.append(_stage4_wall(snap))
                    assert "fuse_topk" not in snap["stages"], (
                        "fused path ran a fuse_topk stage")
                flat = [r for group in results for r in group]
                if baseline is None:
                    baseline = flat
                else:               # pipelined must be method-faithful
                    for a, b in zip(baseline, flat):
                        np.testing.assert_array_equal(a.pids, b.pids)
        # split-tail baseline at depth 1: same workload, legacy
        # multi-dispatch stage 4 — the wall the fusion is meant to beat
        retr.set_rerank_backend("split")
        try:
            one_round(1)                     # warm the split plans
            for _ in range(trials):
                qps, snap, results = one_round(1)
                split_qps.append(qps)
                split_s4.append(_stage4_wall(snap))
                flat = [r for group in results for r in group]
                for a, b in zip(baseline, flat):   # bitwise parity
                    np.testing.assert_array_equal(a.pids, b.pids)
            split_snap = snap
        finally:
            retr.set_rerank_backend(retr.params.rerank_backend)
    finally:
        sys.setswitchinterval(old_si)
    for depth in depths:
        rec = out[str(depth)]
        rec["qps"] = float(np.median(rec["qps_trials"]))
        rec["qps_best"] = max(rec["qps_trials"])
        print(f"pipeline[depth={depth}] qps={rec['qps']:7.1f} "
              f"(best {rec['qps_best']:7.1f})  "
              f"overlap={100 * rec['overlap_fraction']:5.1f}%")
    # min across trials, not median: ambient noise on shared hosts is
    # strictly additive on a stage wall, so the min is the cleanest
    # estimate of each tail's true cost (the medians sit within noise
    # of each other while the mins separate)
    out["stage4_depth1"] = {
        "fused_wall_s": float(np.min(fused_s4)),
        "split_wall_s": float(np.min(split_s4)),
        "speedup": float(np.min(split_s4) / max(np.min(fused_s4), 1e-12)),
        "fused_wall_trials_s": [float(x) for x in fused_s4],
        "split_wall_trials_s": [float(x) for x in split_s4],
        "split_qps": float(np.median(split_qps)),
        "fuse_topk_dispatches_split": int(
            split_snap["stages"]["fuse_topk"]["dispatches"]),
        "fuse_topk_dispatches_fused": 0,     # asserted absent above
    }
    s4 = out["stage4_depth1"]
    print(f"stage-4 tail [depth=1] fused {s4['fused_wall_s'] * 1e3:7.1f}ms"
          f" vs split {s4['split_wall_s'] * 1e3:7.1f}ms "
          f"→ {s4['speedup']:.2f}x")
    return out


def measure_shard_sweep(name: str = "marco", method: str = "hybrid",
                        n_queries: int = 256, max_batch: int = 16,
                        shard_counts=SHARD_COUNTS, trials: int = 3,
                        depth: int = 2):
    """Scatter-gather serving throughput + gather-stage wall time at
    several shard counts.

    Each shard count serves the same micro-batched workload through the
    pipelined engine (depth 2). Per-request results are checked
    identical across shard counts (the merge-parity contract), and the
    recorded ``gather_wall_s`` — end-to-end wall of the
    ``host_gather:residuals`` stage — is the quantity sharding is meant
    to shrink: per-shard mmap segments fault independent page streams
    concurrently, so the gather stage approaches the slowest shard's
    1/S-sized slice instead of one store's full serial gather."""
    from benchmarks.common import sharded_dataset

    corpus, _ = sharded_dataset(name, 1)
    n_q = len(corpus["q_embs"])
    request_batches = [
        [Request(qid=i, method=method, q_emb=corpus["q_embs"][i % n_q],
                 term_ids=corpus["q_term_ids"][i % n_q],
                 term_weights=corpus["q_term_weights"][i % n_q], k=20)
         for i in range(lo, lo + max_batch)]
        for lo in range(0, n_queries, max_batch)]

    def stores_of(retr):
        if hasattr(retr, "shards"):
            return [sh.searcher.index.store for sh in retr.shards]
        return [retr.searcher.index.store]

    def one_round(retr):
        eng = ServeEngine(retr, pipeline_depth=depth)
        retr.reset_stage_stats()
        before = [st.stats.snapshot() for st in stores_of(retr)]
        t0 = time.perf_counter()
        futs = [eng.process_batch_async(b) for b in request_batches]
        results = [f.result(timeout=600) for f in futs]
        wall = time.perf_counter() - t0
        snap = retr.pipeline_stats.snapshot()
        tokens = [a["residual_tokens_read"] - b["residual_tokens_read"]
                  for a, b in zip((st.stats.snapshot()
                                   for st in stores_of(retr)), before)]
        eng.close()
        return n_queries / wall, snap, results, tokens

    out = {}
    baseline = None
    for s in shard_counts:
        _, retr = sharded_dataset(name, s)
        one_round(retr)                        # warm compiles + caches
        qps_trials, gather_trials, tok_trials = [], [], []
        for _ in range(trials):
            qps, snap, results, tokens = one_round(retr)
            qps_trials.append(qps)
            gather_trials.append(
                sum(r["wall_s"] for n_, r in snap["stages"].items()
                    if n_.startswith("host_gather")))
            tok_trials.append(tokens)
            flat = [r for group in results for r in group]
            if baseline is None:
                baseline = flat
            else:                # sharded must merge to the same top-k
                for a, b in zip(baseline, flat):
                    np.testing.assert_array_equal(a.pids, b.pids)
        tokens = tok_trials[-1]
        out[str(s)] = {
            "qps": float(np.median(qps_trials)),
            "qps_trials": qps_trials,
            "gather_wall_s": float(np.median(gather_trials)),
            "gather_wall_trials": gather_trials,
            # the per-segment fault stream: the widest single mmap
            # segment's residual-token reads (what one file's page-in
            # queue has to serve)
            "gather_tokens_total": int(sum(tokens)),
            "gather_tokens_max_segment": int(max(tokens))}
        print(f"shards={s}  qps={out[str(s)]['qps']:7.1f}  "
              f"gather={out[str(s)]['gather_wall_s'] * 1e3:7.1f}ms  "
              f"max-segment tokens={max(tokens)}/{sum(tokens)}")
    return out


def measure_worker_sweep(name: str = "marco", method: str = "hybrid",
                         n_queries: int = 128, max_batch: int = 8,
                         shard_counts=SHARD_COUNTS, concurrency: int = 4,
                         depth: int = 2):
    """In-process vs process shard workers at several shard counts —
    the process backend measured on both transports (``shm`` ring
    arenas at every count, the ``socket`` stream at the widest count as
    the copy-path reference): QPS + p50/p99 through the pipelined
    server, plus — for process configs — per-worker RSS, mmap-segment
    bytes, transport byte split (copied vs zero-copy) and the RPC
    dispatch/coalescing counters, so the transport win is visible in
    the JSON, not just QPS.

    The memory record is the tentpole's deployment claim: the aggregate
    token pool is **split** across the worker processes (each maps
    ~1/S of the bytes, so each worker's page-cache working set is its
    own shard's), not replicated into every process. Segment bytes and
    the copy-split invariant (tensors under ARENA_MIN_BYTES inline,
    bigger ones cross the shm arena unserialized — demonstrated by an
    explicit over-threshold probe per process run) are
    deterministic and asserted; RSS and QPS are recorded for the
    machine-dependent picture (on a big multi-core host the process
    backend's independent GILs pay off; on a busy 1–2 core CI box the
    RPC hop can still cost more than it buys).

    Every configuration must return identical top-k pids for the probe
    queries (the shm==socket==thread==shards-1 parity contract under
    the full server stack)."""
    from benchmarks.common import process_sharded_dataset, sharded_dataset
    from repro.core.store import rss_bytes
    from repro.serving.loadgen import run_closed_loop

    widest = max(shard_counts)
    configs = [("thread", None, s) for s in shard_counts]
    configs += [("process", "shm", s) for s in shard_counts]
    configs += [("process", "socket", widest)]
    out = {}
    probe_ref = None
    for backend, transport, s in configs:
        if backend == "thread":
            corpus, retr = sharded_dataset(name, s)
            key = f"thread_{s}"
        else:
            corpus, retr = process_sharded_dataset(name, s,
                                                   transport=transport)
            key = f"process_{retr.transport}_{s}"
        srv = RetrievalServer(ServeEngine(retr, pipeline_depth=depth),
                              n_threads=1, max_batch=max_batch,
                              batch_timeout_ms=4.0)
        srv.start()
        try:
            warm = [srv.submit(r) for r in
                    _requests(corpus, method, 2 * max_batch)]
            for f in warm:
                f.result(timeout=600)
            # concurrency-shaped warm pass: closed-loop traffic hits
            # micro-batch sizes the sequential warm never does, and the
            # first topology measured in this process must not pay
            # those jit compiles inside its measured window (that skew
            # is what made transports look 2x apart on a cold start)
            run_closed_loop(srv, _requests(corpus, method, 24),
                            concurrency=concurrency)
            res = run_closed_loop(
                srv, _requests(corpus, method, n_queries),
                concurrency=concurrency)
            probe = [srv.submit(r).result(timeout=300).pids
                     for r in _requests(corpus, method, 8)]
            if probe_ref is None:
                probe_ref = probe
            else:   # parity across backends, transports, shard counts
                for a, b in zip(probe_ref, probe):
                    np.testing.assert_array_equal(a, b)
            rec = {"qps": res.achieved_qps,
                   "p50_ms": res.p50 * 1e3, "p99_ms": res.p99 * 1e3}
            if backend == "process":
                wh = retr.worker_health()
                rec["workers"] = [
                    {"pid": w["pid"], "rss_bytes": w["rss_bytes"],
                     "pool_bytes": w["pool_bytes"],
                     "served": w["served"]} for w in wh]
                rec["coordinator_rss_bytes"] = rss_bytes()
                segs = [w["pool_bytes"] for w in wh]
                rec["pool_total_bytes"] = int(sum(segs))
                rec["pool_max_segment_bytes"] = int(max(segs))
                ts = retr.transport_stats()
                rec["transport"] = ts["transport"]
                rec["transport_bytes"] = ts["total"]
                counters = retr.pipeline_stats.snapshot()["counters"]
                rec["rpc"] = {k: v for k, v in sorted(counters.items())
                              if k.startswith("rpc_")}
                # serving tensors on this synth corpus sit under
                # ARENA_MIN_BYTES (they inline: a ring span's fixed
                # bookkeeping costs more than a small memcpy), so
                # drive one over-threshold op per run and record that
                # big tensors cross the arena, not the serializer
                from repro.serving.transport.shm import ARENA_MIN_BYTES
                q = np.asarray(corpus["q_embs"][:4])
                sel = np.zeros((4, (2 * ARENA_MIN_BYTES) // 8),
                               np.int64)
                t0 = time.perf_counter()
                scores = retr._disp[0].call("score_tokens", {
                    "q": q, "q_valid": np.ones(q.shape[:2], bool),
                    "sel": sel})["scores"]
                dt = time.perf_counter() - t0
                ts2 = retr.transport_stats()["total"]
                rec["big_tensor_probe"] = {
                    "sel_bytes": int(sel.nbytes),
                    "reply_bytes": int(scores.nbytes),
                    "ms": dt * 1e3,
                    "zero_copy_delta": (ts2["bytes_zero_copy"]
                                        - ts["total"]["bytes_zero_copy"]),
                    "copied_delta": (ts2["bytes_copied"]
                                     - ts["total"]["bytes_copied"])}
        finally:
            srv.stop()
            if backend == "process":
                retr.close()
        out[key] = rec
        extra = ""
        if backend == "process":
            tb = rec["transport_bytes"]
            extra = (f"  max-segment={rec['pool_max_segment_bytes']}"
                     f"/{rec['pool_total_bytes']}B"
                     f"  zero-copy={tb['bytes_zero_copy']}B"
                     f" copied={tb['bytes_copied']}B"
                     f" dispatches={rec['rpc'].get('rpc_dispatches', 0)}"
                     f" coalesced="
                     f"{rec['rpc'].get('rpc_coalesced_ops', 0)}"
                     f" probe[{rec['big_tensor_probe']['ms']:.1f}ms"
                     f" zc={rec['big_tensor_probe']['zero_copy_delta']}B]")
        label = backend if transport is None else f"{backend}-{transport}"
        print(f"workers[{label:14s} x{s}] "
              f"qps={rec['qps']:7.1f}  p99={rec['p99_ms']:7.1f}ms"
              + extra)
    # the shared-nothing memory claim is deterministic: at S shards no
    # worker maps more than ~1/S of the pool (+1 doc of slack)…
    for s in shard_counts:
        if s >= 2 and f"process_shm_{s}" in out:
            rec = out[f"process_shm_{s}"]
            assert rec["pool_max_segment_bytes"] < \
                0.75 * rec["pool_total_bytes"], out
    # …and so is the copy-split invariant: small tensors inline in the
    # control frame on shm (never counted as copied), big tensors
    # cross the arena — never the serializer — while the socket
    # channel shows the inverse split on the same probe
    for key, rec in out.items():
        tb = rec.get("transport_bytes")
        if tb is None:
            continue
        probe = rec["big_tensor_probe"]
        if rec["transport"] == "shm":
            assert tb["bytes_copied"] == 0, (key, tb)
            assert probe["copied_delta"] == 0, (key, probe)
            assert probe["zero_copy_delta"] >= \
                probe["sel_bytes"] + probe["reply_bytes"], (key, probe)
        else:
            assert tb["bytes_copied"] > 0 and tb["bytes_zero_copy"] == 0, \
                (key, tb)
            assert probe["zero_copy_delta"] == 0, (key, probe)
            assert probe["copied_delta"] >= probe["reply_bytes"], \
                (key, probe)
    return out


def measure_cache_sweep(name: str = "marco", method: str = "hybrid",
                        n_requests: int = 320, n_unique: int = 16,
                        skew: float = 1.2, overload: float = 4.0,
                        quick: bool = False):
    """Coordinator cache hierarchy + SLO admission under realistic
    (skewed) traffic.

    Three passes over the same mmap'd retriever:

    1. **Uniform cache-off baseline** — sequential service time fixes
       the capacity ``base_qps``; the cache-off answers for every
       unique query become the bitwise oracle.
    2. **Zipf open-loop at 10x capacity, caches on** — a skew-``skew``
       trace over ``n_unique`` queries. Cold answers must be bitwise
       the cache-off oracle (caches must not perturb the cold path),
       warm answers must be bitwise the cold ones served off the exact
       cache, and under load the hit path's p99 must sit far under the
       miss path's (hits resolve at the front door without queueing —
       that is why 10x the cache-off capacity is servable at all).
    3. **Overload with vs without admission** — ``overload``x capacity
       through an :class:`AdmissionController`: the ladder degrades
       hybrid requests to the splade-only plan and sheds the hopeless
       tail, bounding the queue; the same offered load without
       admission grows its queue without bound. The admission run's
       p99 must beat the unbounded run's.

    The quality cost of the degraded rung (splade-only vs full hybrid,
    MRR@10/nDCG@10) is pulled from the graded-relevance eval so the
    latency JSON carries the quality delta next to the shed counts."""
    from benchmarks.bench_quality import degraded_delta, evaluate
    from repro.serving.admission import AdmissionController
    from repro.serving.context import CacheHierarchy
    from repro.serving.loadgen import zipf_trace

    corpus, index, sidx, retr = dataset(name, mode="mmap")
    n_unique = min(n_unique, len(corpus["q_embs"]))
    if quick:
        n_requests = n_requests // 2

    def _trace_reqs(trace, qid0=0):
        return [Request(
            qid=qid0 + j, method=method,
            q_emb=corpus["q_embs"][int(q)],
            term_ids=corpus["q_term_ids"][int(q)],
            term_weights=corpus["q_term_weights"][int(q)], k=20,
            trace_id=int(q)) for j, q in enumerate(trace)]

    def _bitwise(a, b):
        np.testing.assert_array_equal(np.asarray(a.pids),
                                      np.asarray(b.pids))
        assert (np.asarray(a.scores).tobytes()
                == np.asarray(b.scores).tobytes())

    # -- pass 1: uniform cache-off baseline + bitwise oracle ---------
    retr.attach_caches(None)
    srv = RetrievalServer(ServeEngine(retr), n_threads=1)
    srv.start()
    uniq = _trace_reqs(range(n_unique))
    for r in uniq:                                   # warm compiles
        srv.submit(r).result(timeout=300)
    t = [srv.submit(r).result(timeout=300).service_time
         for r in _trace_reqs(range(n_unique), qid0=1000)]
    service = float(np.mean(t))
    base_qps = 1.0 / service
    oracle = [srv.submit(r).result(timeout=300)
              for r in _trace_reqs(range(n_unique), qid0=2000)]
    uni = run_poisson_load(
        srv, _trace_reqs(np.arange(n_requests) % n_unique, qid0=3000),
        qps=0.5 * base_qps, seed=11)
    srv.stop()

    # -- pass 2: Zipf at 10x capacity through the caches -------------
    caches = CacheHierarchy(exact_entries=1024, stage1_entries=1024)
    srv = RetrievalServer(ServeEngine(retr, caches=caches), n_threads=1,
                          max_batch=8, batch_timeout_ms=2.0)
    srv.start()
    cold = [srv.submit(r).result(timeout=300)
            for r in _trace_reqs(range(n_unique), qid0=4000)]
    warm = [srv.submit(r).result(timeout=300)
            for r in _trace_reqs(range(n_unique), qid0=5000)]
    for o, c, w in zip(oracle, cold, warm):
        _bitwise(o, c)               # caches don't perturb cold path
        _bitwise(c, w)               # a hit IS the cold answer
    assert all(w.cache_hit for w in warm)
    caches.clear()                   # the sweep measures cold+warm mix
    trace = zipf_trace(n_requests, n_unique, skew=skew, seed=3)
    hit_lat, miss_lat = [], []
    zipf = run_poisson_load(
        srv, _trace_reqs(trace, qid0=6000), qps=10.0 * base_qps,
        seed=5, on_result=lambda r: (hit_lat if r.cache_hit
                                     else miss_lat).append(r.latency))
    # steady state: the cache is warm, every request resolves at the
    # front door without queueing — this run's p99 IS the hit path's
    # (the cold run's per-request hit/miss split is recorded too, but
    # at 10x capacity every arrival lands inside the initial cold-miss
    # backlog, so early repeats inherit queue wait from their original)
    steady = run_poisson_load(srv, _trace_reqs(trace, qid0=9000),
                              qps=10.0 * base_qps, seed=6)
    srv.stop()
    retr.attach_caches(None)

    # -- pass 3: overload, admission vs unbounded queue --------------
    slo_ms = 5.0 * service * 1e3
    over = _trace_reqs(np.arange(n_requests) % n_unique, qid0=7000)
    srv = RetrievalServer(ServeEngine(retr), n_threads=1)
    srv.start()
    noadm = run_poisson_load(srv, list(over), qps=overload * base_qps,
                             seed=9)
    srv.stop()
    adm_ctl = AdmissionController(slo_ms, shed_factor=3.0)
    srv = RetrievalServer(ServeEngine(retr), n_threads=1,
                          admission=adm_ctl)
    srv.start()
    for r in _trace_reqs(range(4), qid0=8000):       # seed the EWMAs
        srv.submit(r).result(timeout=300)
    adm = run_poisson_load(srv, [Request(
        qid=r.qid, method=r.method, q_emb=r.q_emb,
        term_ids=r.term_ids, term_weights=r.term_weights, k=r.k,
        trace_id=r.trace_id) for r in over], qps=overload * base_qps,
        seed=9)
    srv.stop()

    dd = degraded_delta(evaluate(name))
    out = {
        "service_time": service, "capacity_qps": base_qps,
        "skew": skew, "n_unique": n_unique,
        "uniform_half_load": uni.summary(),
        "zipf_10x": {
            **zipf.summary(),
            "offered_qps": 10.0 * base_qps,
            "hit_rate": zipf.cache_hits / max(len(zipf.latencies), 1),
            "hit_p99_ms": float(np.percentile(hit_lat, 99) * 1e3)
            if hit_lat else 0.0,
            "miss_p99_ms": float(np.percentile(miss_lat, 99) * 1e3)
            if miss_lat else 0.0,
            "steady_hit_p99_ms": steady.p99 * 1e3,
            "steady": steady.summary(),
            "caches": caches.stats()},
        "admission_overload": {
            "latency_slo_ms": slo_ms,
            "offered_qps": overload * base_qps,
            "no_admission": noadm.summary(),
            "with_admission": adm.summary(),
            "controller": adm_ctl.stats()},
        "degraded_quality": dd,
    }
    z = out["zipf_10x"]
    a = out["admission_overload"]
    print(f"cache[zipf@10x] hit-rate={z['hit_rate']:.2f}  "
          f"steady-hit-p99={z['steady_hit_p99_ms']:.2f}ms  "
          f"miss-p99={z['miss_p99_ms']:.1f}ms  "
          f"cold-run p99={z['p99'] * 1e3:.1f}ms")
    print(f"admission[{overload:.0f}x] slo={slo_ms:.0f}ms  "
          f"degraded={adm.degraded} shed={adm.shed}  "
          f"p99 {a['with_admission']['p99'] * 1e3:.1f}ms vs "
          f"{a['no_admission']['p99'] * 1e3:.1f}ms unbounded")
    print(f"degraded quality: ΔMRR@10={dd['MRR@10_delta']:+.4f} "
          f"ΔnDCG@10={dd['nDCG@10_delta']:+.4f}")
    return out


def measure_chaos_sweep(name: str = "marco", method: str = "hybrid",
                        n_queries: int = 120, n_shards: int = 2,
                        n_replicas: int = 2, quick: bool = False):
    """Fault tolerance of the replicated fleet under live load: a
    2-shard × 2-replica topology of **remote** standalone workers
    (TCP endpoints, each an independently killable process), driven by
    Poisson load while a :class:`ChaosSchedule` SIGKILLs one replica
    of every shard mid-run and restarts it at the same port.

    Asserted per config: **zero failed requests** (failover absorbs
    the kills) and post-heal **bitwise parity** with the healthy
    baseline. Configs: ``clean`` (kill choreography only) and
    ``faulty`` (a seeded :class:`FaultSpec` additionally drops/delays/
    truncates/corrupts frames on every coordinator channel — per-op
    deadlines turn drops into sibling retries). ``quick`` runs just
    the faulty config (the CI chaos smoke). The full sweep then kills
    *every* replica of one shard under an ``allow_degraded``
    coordinator and asserts flagged partial answers + recovery parity.

    Recorded per config: p50/p99, failover/hedge/heal/degraded
    counters and the injected-fault census — the availability numbers
    ``bench_gate`` tracks alongside the latency ones."""
    import dataclasses as dc
    from concurrent.futures import ThreadPoolExecutor

    from benchmarks.common import _CACHE, DATASETS, sharded_dataset
    from repro.core.multistage import MultiStageParams
    from repro.core.plaid import PlaidParams
    from repro.core.sharded import ProcessShardGroup
    from repro.index.sharding import shard_boundaries, split_index_tree
    from repro.serving.loadgen import (ChaosAction, ChaosSchedule,
                                       run_poisson_load)
    from repro.serving.worker import spawn_standalone

    corpus, _ = sharded_dataset(name, max(n_shards, 2))
    cfg = DATASETS[name]
    _, base = _CACHE[(name, "mmap", "serve_layout")]
    group_dir = split_index_tree(base, n_shards,
                                 group_dir=base / f"shards{n_shards}")
    shard_dirs = [group_dir / str(i) for i in range(n_shards)]
    boundaries = shard_boundaries(cfg.n_docs, n_shards)
    plaid = PlaidParams(nprobe=4, candidate_cap=1024, ndocs=256, k=100)
    ms = MultiStageParams(first_k=200, k=100, alpha=0.3)

    def spawn(shard: int, port: int = 0):
        return spawn_standalone(
            shard_dirs[shard], shard, port=port,
            plaid_params=dc.asdict(plaid), ms_params=dc.asdict(ms))

    # the fleet: one standalone worker per (shard, replica), spawned
    # concurrently (each pays its own jax import + index mmap)
    slots = [(i, r) for i in range(n_shards) for r in range(n_replicas)]
    with ThreadPoolExecutor(len(slots)) as tp:
        spawned = list(tp.map(lambda s: spawn(s[0]), slots))
    workers = {s: {"proc": p, "port": port}
               for s, (p, port) in zip(slots, spawned)}
    endpoints = [[f"127.0.0.1:{workers[(i, r)]['port']}"
                  for r in range(n_replicas)] for i in range(n_shards)]

    def kill(shard: int, rid: int = 0):
        w = workers[(shard, rid)]
        w["proc"].kill()
        w["proc"].wait(timeout=10)

    def restart(shard: int, rid: int = 0):
        w = workers[(shard, rid)]
        w["proc"], w["port"] = spawn(shard, w["port"])

    def coordinator(**kw):
        return ProcessShardGroup(
            shard_dirs, boundaries, plaid_params=plaid,
            multistage_params=ms, replicas=0,
            replica_endpoints=endpoints, op_deadline_ms=2000.0,
            hedge_factor=4.0, hedge_floor_ms=250.0, **kw)

    def probe_pids(srv, n=8):
        return [srv.submit(r).result(timeout=300).pids
                for r in _requests(corpus, method, n)]

    n_q = 40 if quick else n_queries
    reqs = _requests(corpus, method, n_q)
    fault_str = "seed=7,drop=0.02,truncate=0.01,corrupt=0.01,delay=5:0.05"
    configs = ([("faulty", fault_str)] if quick
               else [("clean", None), ("faulty", fault_str)])
    out = {}
    try:
        for key, spec in configs:
            retr = coordinator(fault_spec=spec)
            srv = RetrievalServer(ServeEngine(retr, own_retriever=True),
                                  n_threads=2)
            srv.start()
            try:
                for r in _requests(corpus, method, 8):     # warm
                    srv.submit(r).result(timeout=300)
                baseline = probe_pids(srv)
                t = [srv.submit(r).result(timeout=300).service_time
                     for r in _requests(corpus, method, 8)]
                qps = 0.5 / float(np.mean(t))     # half of capacity
                dur = n_q / qps
                chaos = ChaosSchedule(
                    [ChaosAction(0.25 * dur, lambda i=i: kill(i),
                                 f"kill:shard{i}")
                     for i in range(n_shards)]
                    + [ChaosAction(0.55 * dur, lambda i=i: restart(i),
                                   f"restart:shard{i}")
                       for i in range(n_shards)]).start()
                res = run_poisson_load(srv, reqs, qps, seed=13,
                                       tolerate_failures=True)
                chaos.join(timeout=120)
                assert not chaos.errors, chaos.errors
                # the whole point: a SIGKILL per shard mid-run costs
                # zero requests — siblings absorb every failed op
                assert res.failed == 0, (
                    key, res.failed, [repr(e) for e in res.errors])
                time.sleep(1.0)       # let breakers on the restarted
                probe = probe_pids(srv)           # replicas cool off
                for a, b in zip(baseline, probe):
                    np.testing.assert_array_equal(a, b)
                counters = retr.pipeline_stats.snapshot()["counters"]
                faults = {}
                for ts in retr.transport_stats()["per_worker"]:
                    for fk, v in ts.get("faults_injected", {}).items():
                        faults[fk] = faults.get(fk, 0) + v
                out[key] = {
                    "n": n_q, "failed": int(res.failed),
                    "offered_qps": qps,
                    "p50_ms": res.p50 * 1e3, "p99_ms": res.p99 * 1e3,
                    "chaos_fired": list(chaos.fired),
                    "failover_retries": counters.get(
                        "failover_retries", 0),
                    "hedges": counters.get("hedges", 0),
                    "replica_heals": counters.get("replica_heals", 0),
                    "degraded_batches": counters.get(
                        "degraded_batches", 0),
                    "faults_injected": faults}
                print(f"chaos[{key:6s}] failed={res.failed}/{n_q}  "
                      f"p99={out[key]['p99_ms']:7.1f}ms  "
                      f"failovers={out[key]['failover_retries']}  "
                      f"heals={out[key]['replica_heals']}  "
                      f"faults={faults}")
            finally:
                srv.stop()
                retr.close()

        if not quick:
            # every replica of shard 1 down → flagged partial answers
            # over the survivors; restart → bitwise recovery
            retr = coordinator(allow_degraded=True)
            srv = RetrievalServer(ServeEngine(retr, own_retriever=True),
                                  n_threads=1)
            srv.start()
            try:
                baseline = probe_pids(srv)
                for rid in range(n_replicas):
                    kill(1, rid)
                degraded = [srv.submit(r).result(timeout=300)
                            for r in _requests(corpus, method, 8)]
                assert all(d.degraded and tuple(d.missing_shards) == (1,)
                           for d in degraded), degraded
                for rid in range(n_replicas):
                    restart(1, rid)
                deadline = time.monotonic() + 60
                healed = degraded
                while (time.monotonic() < deadline
                       and any(h.degraded for h in healed)):
                    time.sleep(0.5)
                    healed = [srv.submit(r).result(timeout=300)
                              for r in _requests(corpus, method, 8)]
                assert not any(h.degraded for h in healed), healed
                for a, h in zip(baseline, healed):
                    np.testing.assert_array_equal(a, h.pids)
                counters = retr.pipeline_stats.snapshot()["counters"]
                out["degraded"] = {
                    "missing_shards": [1],
                    "degraded_batches": counters.get(
                        "degraded_batches", 0),
                    "degraded_shard_ops": counters.get(
                        "degraded_shard_ops", 0),
                    "recovered": True}
                print(f"chaos[degraded] batches="
                      f"{out['degraded']['degraded_batches']} "
                      f"(shard 1 missing) → recovered bitwise")
            finally:
                srv.stop()
                retr.close()
    finally:
        for w in workers.values():
            w["proc"].kill()
        for w in workers.values():
            try:
                w["proc"].wait(timeout=10)
            except Exception:
                pass
    return out


def main(quick: bool = False):
    table = {"marco": measure("marco", n_queries=40 if quick else 60)}
    if not quick:
        table["lotte"] = measure("lotte", n_queries=60)
    sweep = measure_batch_sweep("marco",
                                n_queries=48 if quick else 96)
    table["marco"]["batch_sweep"] = {str(b): v for b, v in sweep.items()}
    table["marco"]["stage_breakdown"] = {
        be: measure_stage_breakdown("marco", n_queries=16 if quick else 32,
                                    backend=be)
        for be in STAGE1_BACKENDS}
    s1 = measure_stage1_backends("marco", B=16, rounds=2 if quick else 4)
    table["marco"]["stage1_backends"] = s1
    ps = measure_pipeline_sweep("marco", trials=3 if quick else 5)
    table["marco"]["pipeline_sweep"] = ps
    save("latency_fig12", table)   # persist before any shape check: a
    # failed assertion must not discard the minutes of measurements that
    # would be needed to diagnose it
    # paper-shape checks: splade fastest; saturation raises p95 sharply;
    # rerank/hybrid faster than full mmap'd ColBERT
    for name, res in table.items():
        assert res["splade"]["service_time"] <= \
            res["rerank"]["service_time"] * 1.2
        assert res["rerank"]["service_time"] < res["colbert"]["service_time"]
        for m in METHODS:
            pts = res[m]["points"]
            assert pts[-1]["p95"] > 1.5 * pts[0]["p95"], (name, m)
    # cross-query batching must pay for itself once the batch is deep
    assert sweep[16]["qps"] >= sweep[1]["qps"], sweep
    # a batched B=16 stage-1 dispatch must beat 16 B=1 dispatches on the
    # device backend (the tentpole's acceptance bar)
    assert s1["jax"]["batch_ms"] < s1["jax"]["loop_ms"], s1
    # the stage pipeline must actually overlap host gathers with device
    # dispatches (the depth2 >= depth1 throughput claim is asserted by
    # the --pipeline-sweep mode, where XLA CPU threading is pinned)
    assert ps["2"]["overlap_fraction"] > 0.0, ps
    return table


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pipeline-sweep", action="store_true",
                    help="run only the stage-graph pipeline sweep "
                         "(QPS + overlap fraction at depths 1/2/4) and "
                         "record it into the bench JSON")
    ap.add_argument("--shard-sweep", action="store_true",
                    help="run only the scatter-gather shard sweep "
                         "(QPS + gather-stage wall at shards 1/2/4) and "
                         "record it into the bench JSON")
    ap.add_argument("--worker-sweep", action="store_true",
                    help="run only the shard-worker backend sweep "
                         "(thread vs process workers at shards 1/2/4, "
                         "process on both shm and socket transports: "
                         "QPS, p99, per-worker RSS + segment bytes, "
                         "transport copy split, RPC dispatch counts) "
                         "and record it into the bench JSON")
    ap.add_argument("--cache-sweep", action="store_true",
                    help="run only the cache/admission sweep: Zipf "
                         "open-loop load at 10x the cache-off capacity "
                         "through the exact + stage-1 caches (hit rate, "
                         "hit vs miss p99, bitwise parity vs cache-off) "
                         "plus the SLO admission ladder under overload "
                         "(degraded/shed counts, p99 vs the unbounded "
                         "no-admission queue, nDCG delta of the "
                         "degraded plan) and record it into the bench "
                         "JSON")
    ap.add_argument("--chaos-sweep", action="store_true",
                    help="run only the fault-tolerance sweep: a "
                         "2-shard x 2-replica remote-worker fleet "
                         "under Poisson load with SIGKILL + seeded "
                         "fault-injection choreography (asserts zero "
                         "failed requests and post-heal parity; "
                         "--quick = the faulty config only, the CI "
                         "chaos smoke) and record it into the bench "
                         "JSON")
    args = ap.parse_args()
    if args.cache_sweep:
        sweep = measure_cache_sweep("marco", quick=args.quick)
        save("latency_cache_sweep", {"marco": {"cache_sweep": sweep}})
        z = sweep["zipf_10x"]
        # skewed traffic at 10x the cache-off capacity is servable
        # because repeats resolve at the front door: most requests hit,
        # and the hit path's tail sits far under the miss path's
        assert z["hit_rate"] > 0.5, sweep
        assert z["steady_hit_p99_ms"] < 0.5 * z["miss_p99_ms"], sweep
        # the admission ladder fired under overload and kept the tail
        # below the unbounded no-admission queue's
        a = sweep["admission_overload"]
        served = a["with_admission"]
        assert served["degraded"] + served["shed"] > 0, sweep
        assert served["p99"] < a["no_admission"]["p99"], sweep
        # degraded answers stay answers (graded-relevance guardrail)
        dq = sweep["degraded_quality"]
        assert dq["nDCG@10_degraded"] > 0.5 * dq["nDCG@10_full"], sweep
    elif args.chaos_sweep:
        sweep = measure_chaos_sweep("marco", quick=args.quick)
        save("latency_chaos_sweep", {"marco": {"chaos_sweep": sweep}})
    elif args.worker_sweep:
        sweep = measure_worker_sweep("marco")
        save("latency_worker_sweep", {"marco": {"worker_sweep": sweep}})
    elif args.shard_sweep:
        sweep = measure_shard_sweep("marco")
        save("latency_shard_sweep", {"marco": {"shard_sweep": sweep}})
        # the topology must pay for itself where it claims to: the
        # widest single segment's gather stream shrinks ~1/S — each mmap
        # file's page-in queue serves a strictly smaller slice (the
        # compaction guarantee; deterministic, so asserted hard). The
        # recorded gather_wall_s tracks the same drop when the host has
        # idle cores / cold pages to overlap (on a busy 2-core CI box
        # with a warm page cache the wall is noise-bound, so it is
        # recorded, not asserted).
        t1 = sweep["1"]["gather_tokens_max_segment"]
        for s_ in (2, 4):
            rec = sweep[str(s_)]
            assert rec["gather_tokens_max_segment"] < 0.75 * t1, sweep
    elif args.pipeline_sweep:
        # keep the full per-round query count even under --quick: short
        # rounds spend a third of their wall in pipeline fill/drain and
        # the depth comparison drowns in ramp effects
        sweep = measure_pipeline_sweep("marco", trials=5)
        save("latency_pipeline_sweep", {"marco": {"pipeline_sweep": sweep}})
        assert sweep["2"]["overlap_fraction"] > 0.0, sweep
        # depth 2 must stay within noise of depth 1: the fused stage-4
        # tail shrank the device wall that pipelining used to hide, so
        # the old strict depth2 >= depth1 margin (~2% pre-fusion) now
        # sits inside shared-host noise — the measured overlap fraction
        # above is the structural claim, the qps band guards against an
        # actual pipelining regression
        assert sweep["2"]["qps"] >= 0.9 * sweep["1"]["qps"], sweep
        # the fused single-dispatch tail must strictly beat the split
        # tail's stage-4 wall at depth 1 (synchronous — no overlap to
        # hide behind), and never execute a fuse_topk stage
        s4 = sweep["stage4_depth1"]
        assert s4["fused_wall_s"] < s4["split_wall_s"], s4
        assert s4["fuse_topk_dispatches_fused"] == 0, s4
    else:
        main(quick=args.quick)
