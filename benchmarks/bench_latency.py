"""Fig 1/2: p95 latency vs offered QPS per method under Poisson
arrivals through the concurrent server — the paper's serving
methodology (client-observed latency includes queueing; saturation
knee at the service-rate reciprocal) — plus a throughput-vs-batch-size
sweep for the cross-query micro-batcher, a per-stage latency breakdown
(stage 1 vs stages 2–4), and a stage-1 backend sweep
(host / jax / pallas, batched vs per-query)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, save
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import run_poisson_load
from repro.serving.server import RetrievalServer

METHODS = ["splade", "rerank", "hybrid", "colbert"]
BATCH_SIZES = (1, 4, 16)
STAGE1_BACKENDS = ("host", "jax")     # pallas rides on TPU runs only


def _requests(corpus, method, n):
    reqs = []
    for qi in range(n):
        reqs.append(Request(
            qid=qi, method=method, q_emb=corpus["q_embs"][qi],
            term_ids=corpus["q_term_ids"][qi],
            term_weights=corpus["q_term_weights"][qi], k=20))
    return reqs


def measure(name: str = "marco", n_queries: int = 60,
            n_threads: int = 1):
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for method in METHODS:
        engine = ServeEngine(retr)
        # measure service rate first (sequential warm run)
        warm = _requests(corpus, method, 10)
        srv = RetrievalServer(engine, n_threads=n_threads)
        srv.start()
        for r in warm:
            srv.submit(r).result(timeout=120)
        t = [srv.submit(r).result(timeout=120).service_time
             for r in _requests(corpus, method, 10)]
        service = float(np.mean(t))
        rate = 1.0 / service
        # offered loads relative to capacity: the paper sweeps QPS and
        # finds the knee at ~1/service_time
        out[method] = {"service_time": service, "capacity_qps": rate,
                       "points": []}
        for frac in (0.25, 0.5, 0.8, 1.5):
            qps = rate * frac
            res = run_poisson_load(srv, _requests(corpus, method,
                                                  n_queries), qps, seed=7)
            out[method]["points"].append(
                {"offered_qps": qps, "rel_load": frac,
                 **res.summary()})
        srv.stop()
        pts = out[method]["points"]
        print(f"{method:8s} svc={service * 1e3:6.1f}ms cap={rate:6.1f}qps  "
              + "  ".join(f"{p['rel_load']:.2f}x:p95={p['p95'] * 1e3:6.1f}ms"
                          for p in pts))
    return out


def measure_batch_sweep(name: str = "marco", method: str = "hybrid",
                        n_queries: int = 96,
                        batch_sizes=BATCH_SIZES):
    """Offline throughput (QPS) of the micro-batched server at several
    ``max_batch`` settings, all requests offered up-front so the batcher
    coalesces maximally. max_batch=1 is the sequential baseline."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for bs in batch_sizes:
        srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=bs,
                              batch_timeout_ms=4.0)
        srv.start()
        for r in _requests(corpus, method, 8):      # warm single-query path
            srv.submit(r).result(timeout=300)
        # warm the batched bucket: a burst deep enough to coalesce fully
        for f in [srv.submit(r) for r in _requests(corpus, method, 2 * bs)]:
            f.result(timeout=600)
        t0 = time.perf_counter()
        futs = [srv.submit(r) for r in _requests(corpus, method, n_queries)]
        for f in futs:
            f.result(timeout=600)
        wall = time.perf_counter() - t0
        srv.stop()
        out[bs] = {"qps": n_queries / wall, "wall_s": wall}
        print(f"batch={bs:3d}  qps={out[bs]['qps']:7.1f}  "
              f"wall={wall * 1e3:7.1f}ms")
    return out


def measure_stage_breakdown(name: str = "marco", method: str = "hybrid",
                            n_queries: int = 32, backend: str = "host"):
    """Per-stage latency split for one backend: stage-1 (SPLADE) wall
    time vs stages 2–4 (rerank + fusion), averaged per query."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    retr.set_splade_backend(backend)
    try:
        for qi in range(4):               # warm compile caches
            retr.search(method, q_emb=corpus["q_embs"][qi],
                        term_ids=corpus["q_term_ids"][qi],
                        term_weights=corpus["q_term_weights"][qi], k=20)
        retr.reset_stage_stats()
        t0 = time.perf_counter()
        for qi in range(n_queries):
            retr.search(method, q_emb=corpus["q_embs"][qi],
                        term_ids=corpus["q_term_ids"][qi],
                        term_weights=corpus["q_term_weights"][qi], k=20)
        wall = time.perf_counter() - t0
        st = retr.stage_stats
        out = {"backend": backend, "method": method,
               "stage1_ms_per_q": st["stage1_s"] / n_queries * 1e3,
               "rest_ms_per_q": st["rest_s"] / n_queries * 1e3,
               "total_ms_per_q": wall / n_queries * 1e3,
               "stage1_fraction": st["stage1_s"] / max(wall, 1e-12)}
    finally:
        retr.set_splade_backend("host")
    print(f"breakdown[{backend:6s}] stage1={out['stage1_ms_per_q']:6.2f}ms "
          f"rest={out['rest_ms_per_q']:6.2f}ms "
          f"({100 * out['stage1_fraction']:4.1f}% stage1)")
    return out


def measure_stage1_backends(name: str = "marco", B: int = 16,
                            rounds: int = 4,
                            backends=STAGE1_BACKENDS):
    """Stage-1 throughput per backend: one batched B-query dispatch vs
    B per-query dispatches on the same backend (the batching win the
    tentpole claims — batched must beat the loop)."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    tids = [corpus["q_term_ids"][i % len(corpus["q_term_ids"])]
            for i in range(B)]
    tw = [corpus["q_term_weights"][i % len(corpus["q_term_weights"])]
          for i in range(B)]
    out = {}
    for be in backends:
        retr.set_splade_backend(be)
        try:
            retr.run_splade_batch(tids, tw)           # warm batched shape
            for i in range(min(4, B)):                # warm B=1 shape
                retr.run_splade(tids[i], tw[i])
            t0 = time.perf_counter()
            for _ in range(rounds):
                retr.run_splade_batch(tids, tw)
            t_batch = (time.perf_counter() - t0) / rounds
            t0 = time.perf_counter()
            for _ in range(rounds):
                for i in range(B):
                    retr.run_splade(tids[i], tw[i])
            t_loop = (time.perf_counter() - t0) / rounds
        finally:
            retr.set_splade_backend("host")
        out[be] = {"batch_ms": t_batch * 1e3, "loop_ms": t_loop * 1e3,
                   "speedup": t_loop / max(t_batch, 1e-12),
                   "batch_qps": B / t_batch, "loop_qps": B / t_loop}
        print(f"stage1[{be:6s}] B={B:3d}: batched {t_batch * 1e3:7.2f}ms "
              f"vs {B}x1 {t_loop * 1e3:7.2f}ms "
              f"→ {out[be]['speedup']:.2f}x")
    return out


def main(quick: bool = False):
    table = {"marco": measure("marco", n_queries=40 if quick else 60)}
    if not quick:
        table["lotte"] = measure("lotte", n_queries=60)
    sweep = measure_batch_sweep("marco",
                                n_queries=48 if quick else 96)
    table["marco"]["batch_sweep"] = {str(b): v for b, v in sweep.items()}
    table["marco"]["stage_breakdown"] = {
        be: measure_stage_breakdown("marco", n_queries=16 if quick else 32,
                                    backend=be)
        for be in STAGE1_BACKENDS}
    s1 = measure_stage1_backends("marco", B=16, rounds=2 if quick else 4)
    table["marco"]["stage1_backends"] = s1
    save("latency_fig12", table)   # persist before any shape check: a
    # failed assertion must not discard the minutes of measurements that
    # would be needed to diagnose it
    # paper-shape checks: splade fastest; saturation raises p95 sharply;
    # rerank/hybrid faster than full mmap'd ColBERT
    for name, res in table.items():
        assert res["splade"]["service_time"] <= \
            res["rerank"]["service_time"] * 1.2
        assert res["rerank"]["service_time"] < res["colbert"]["service_time"]
        for m in METHODS:
            pts = res[m]["points"]
            assert pts[-1]["p95"] > 1.5 * pts[0]["p95"], (name, m)
    # cross-query batching must pay for itself once the batch is deep
    assert sweep[16]["qps"] >= sweep[1]["qps"], sweep
    # a batched B=16 stage-1 dispatch must beat 16 B=1 dispatches on the
    # device backend (the tentpole's acceptance bar)
    assert s1["jax"]["batch_ms"] < s1["jax"]["loop_ms"], s1
    return table


if __name__ == "__main__":
    main()
