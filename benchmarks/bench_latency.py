"""Fig 1/2: p95 latency vs offered QPS per method under Poisson
arrivals through the concurrent server — the paper's serving
methodology (client-observed latency includes queueing; saturation
knee at the service-rate reciprocal) — plus a throughput-vs-batch-size
sweep for the cross-query micro-batcher."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, save
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import run_poisson_load
from repro.serving.server import RetrievalServer

METHODS = ["splade", "rerank", "hybrid", "colbert"]
BATCH_SIZES = (1, 4, 16)


def _requests(corpus, method, n):
    reqs = []
    for qi in range(n):
        reqs.append(Request(
            qid=qi, method=method, q_emb=corpus["q_embs"][qi],
            term_ids=corpus["q_term_ids"][qi],
            term_weights=corpus["q_term_weights"][qi], k=20))
    return reqs


def measure(name: str = "marco", n_queries: int = 60,
            n_threads: int = 1):
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for method in METHODS:
        engine = ServeEngine(retr)
        # measure service rate first (sequential warm run)
        warm = _requests(corpus, method, 10)
        srv = RetrievalServer(engine, n_threads=n_threads)
        srv.start()
        for r in warm:
            srv.submit(r).result(timeout=120)
        t = [srv.submit(r).result(timeout=120).service_time
             for r in _requests(corpus, method, 10)]
        service = float(np.mean(t))
        rate = 1.0 / service
        # offered loads relative to capacity: the paper sweeps QPS and
        # finds the knee at ~1/service_time
        out[method] = {"service_time": service, "capacity_qps": rate,
                       "points": []}
        for frac in (0.25, 0.5, 0.8, 1.5):
            qps = rate * frac
            res = run_poisson_load(srv, _requests(corpus, method,
                                                  n_queries), qps, seed=7)
            out[method]["points"].append(
                {"offered_qps": qps, "rel_load": frac,
                 **res.summary()})
        srv.stop()
        pts = out[method]["points"]
        print(f"{method:8s} svc={service * 1e3:6.1f}ms cap={rate:6.1f}qps  "
              + "  ".join(f"{p['rel_load']:.2f}x:p95={p['p95'] * 1e3:6.1f}ms"
                          for p in pts))
    return out


def measure_batch_sweep(name: str = "marco", method: str = "hybrid",
                        n_queries: int = 96,
                        batch_sizes=BATCH_SIZES):
    """Offline throughput (QPS) of the micro-batched server at several
    ``max_batch`` settings, all requests offered up-front so the batcher
    coalesces maximally. max_batch=1 is the sequential baseline."""
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for bs in batch_sizes:
        srv = RetrievalServer(ServeEngine(retr), n_threads=1, max_batch=bs,
                              batch_timeout_ms=4.0)
        srv.start()
        for r in _requests(corpus, method, 8):      # warm single-query path
            srv.submit(r).result(timeout=300)
        # warm the batched bucket: a burst deep enough to coalesce fully
        for f in [srv.submit(r) for r in _requests(corpus, method, 2 * bs)]:
            f.result(timeout=600)
        t0 = time.perf_counter()
        futs = [srv.submit(r) for r in _requests(corpus, method, n_queries)]
        for f in futs:
            f.result(timeout=600)
        wall = time.perf_counter() - t0
        srv.stop()
        out[bs] = {"qps": n_queries / wall, "wall_s": wall}
        print(f"batch={bs:3d}  qps={out[bs]['qps']:7.1f}  "
              f"wall={wall * 1e3:7.1f}ms")
    return out


def main(quick: bool = False):
    table = {"marco": measure("marco", n_queries=40 if quick else 60)}
    if not quick:
        table["lotte"] = measure("lotte", n_queries=60)
    # paper-shape checks: splade fastest; saturation raises p95 sharply;
    # rerank/hybrid faster than full mmap'd ColBERT
    for name, res in table.items():
        assert res["splade"]["service_time"] <= \
            res["rerank"]["service_time"] * 1.2
        assert res["rerank"]["service_time"] < res["colbert"]["service_time"]
        for m in METHODS:
            pts = res[m]["points"]
            assert pts[-1]["p95"] > 1.5 * pts[0]["p95"], (name, m)
    sweep = measure_batch_sweep("marco",
                                n_queries=48 if quick else 96)
    table["marco"]["batch_sweep"] = {str(b): v for b, v in sweep.items()}
    # cross-query batching must pay for itself once the batch is deep
    assert sweep[16]["qps"] >= sweep[1]["qps"], sweep
    save("latency_fig12", table)
    return table


if __name__ == "__main__":
    main()
