"""Fig 1/2: p95 latency vs offered QPS per method under Poisson
arrivals through the concurrent server — the paper's serving
methodology (client-observed latency includes queueing; saturation
knee at the service-rate reciprocal)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, save
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import run_poisson_load
from repro.serving.server import RetrievalServer

METHODS = ["splade", "rerank", "hybrid", "colbert"]


def _requests(corpus, method, n):
    reqs = []
    for qi in range(n):
        reqs.append(Request(
            qid=qi, method=method, q_emb=corpus["q_embs"][qi],
            term_ids=corpus["q_term_ids"][qi],
            term_weights=corpus["q_term_weights"][qi], k=20))
    return reqs


def measure(name: str = "marco", n_queries: int = 60,
            n_threads: int = 1):
    corpus, index, sidx, retr = dataset(name, mode="mmap")
    out = {}
    for method in METHODS:
        engine = ServeEngine(retr)
        # measure service rate first (sequential warm run)
        warm = _requests(corpus, method, 10)
        srv = RetrievalServer(engine, n_threads=n_threads)
        srv.start()
        for r in warm:
            srv.submit(r).result(timeout=120)
        t = [srv.submit(r).result(timeout=120).service_time
             for r in _requests(corpus, method, 10)]
        service = float(np.mean(t))
        rate = 1.0 / service
        # offered loads relative to capacity: the paper sweeps QPS and
        # finds the knee at ~1/service_time
        out[method] = {"service_time": service, "capacity_qps": rate,
                       "points": []}
        for frac in (0.25, 0.5, 0.8, 1.5):
            qps = rate * frac
            res = run_poisson_load(srv, _requests(corpus, method,
                                                  n_queries), qps, seed=7)
            out[method]["points"].append(
                {"offered_qps": qps, "rel_load": frac,
                 **res.summary()})
        srv.stop()
        pts = out[method]["points"]
        print(f"{method:8s} svc={service * 1e3:6.1f}ms cap={rate:6.1f}qps  "
              + "  ".join(f"{p['rel_load']:.2f}x:p95={p['p95'] * 1e3:6.1f}ms"
                          for p in pts))
    return out


def main(quick: bool = False):
    table = {"marco": measure("marco", n_queries=40 if quick else 60)}
    if not quick:
        table["lotte"] = measure("lotte", n_queries=60)
    # paper-shape checks: splade fastest; saturation raises p95 sharply;
    # rerank/hybrid faster than full mmap'd ColBERT
    for name, res in table.items():
        assert res["splade"]["service_time"] <= \
            res["rerank"]["service_time"] * 1.2
        assert res["rerank"]["service_time"] < res["colbert"]["service_time"]
        for m in METHODS:
            pts = res[m]["points"]
            assert pts[-1]["p95"] > 1.5 * pts[0]["p95"], (name, m)
    save("latency_fig12", table)
    return table


if __name__ == "__main__":
    main()
