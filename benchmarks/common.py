"""Shared benchmark fixtures: three synthetic datasets standing in for
the paper's MS MARCO (in-domain), Wikipedia/NQ (OOD, large), and LoTTE
Lifestyle (OOD, small), with noise profiles that mirror each setting."""

from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.data.synth import SynthCfg, make_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import SpladeIndex, build_splade_index

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench"

# dataset profiles: in-domain has mild noise (α tuned here); the OOD
# sets skew the semantic/lexical error balance like the paper's
# Wikipedia (ColBERT generalises better) and LoTTE (lexical helps).
DATASETS = {
    "marco": SynthCfg(n_docs=4000, n_queries=300, n_topics=96, seed=11),
    "wiki": SynthCfg(n_docs=8000, n_queries=250, n_topics=128,
                     sem_noise=1.7, lex_gap=0.45, lex_drop=0.30, seed=23),
    "lotte": SynthCfg(n_docs=1200, n_queries=200, n_topics=48,
                      sem_noise=1.35, confuser=0.5, lex_gap=0.30,
                      lex_drop=0.18, seed=37),
}

_CACHE: dict = {}


def dataset(name: str, mode: str = "mmap"):
    """(corpus, ColBERTIndex, SpladeIndex, MultiStageRetriever)."""
    key = (name, mode)
    if key in _CACHE:
        return _CACHE[key]
    cfg = DATASETS[name]
    corpus = make_corpus(cfg)
    d = pathlib.Path(tempfile.mkdtemp(prefix=f"bench_{name}_"))
    build_colbert_index(d, corpus["doc_embs"], corpus["doc_lens"],
                        nbits=4, kmeans_iters=6)
    index = ColBERTIndex(d, mode=mode)
    sidx = build_splade_index(corpus["doc_term_ids"],
                              corpus["doc_term_weights"], cfg.vocab,
                              cfg.n_docs)
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=4,
                                                candidate_cap=1024,
                                                ndocs=256, k=100))
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=200, k=100,
                                                alpha=0.3))
    out = (corpus, index, sidx, retr)
    _CACHE[key] = out
    return out


def sharded_dataset(name: str, n_shards: int, mode: str = "mmap"):
    """(corpus, retriever) with the dataset's index split into
    ``n_shards`` contiguous doc ranges behind a ``ShardedRetriever``
    (n_shards=1 → the plain single-index retriever). The split reuses
    one serve-layout copy of the index per dataset."""
    from repro.core.sharded import build_sharded_retriever
    from repro.index.sharding import shard_boundaries, split_index_tree

    key = (name, mode, "sharded", n_shards)
    if key in _CACHE:
        return _CACHE[key]
    cfg = DATASETS[name]
    base_key = (name, mode, "serve_layout")
    if base_key in _CACHE:
        corpus, base = _CACHE[base_key]
    else:
        corpus = make_corpus(cfg)
        base = pathlib.Path(tempfile.mkdtemp(prefix=f"bench_{name}_sh_"))
        build_colbert_index(base / "colbert", corpus["doc_embs"],
                            corpus["doc_lens"], nbits=4, kmeans_iters=6)
        build_splade_index(corpus["doc_term_ids"],
                           corpus["doc_term_weights"], cfg.vocab,
                           cfg.n_docs).save(base / "splade")
        _CACHE[base_key] = (corpus, base)
    plaid = PlaidParams(nprobe=4, candidate_cap=1024, ndocs=256, k=100)
    ms = MultiStageParams(first_k=200, k=100, alpha=0.3)
    if n_shards == 1:
        index = ColBERTIndex(base / "colbert", mode=mode)
        retr = MultiStageRetriever(
            SpladeIndex.load(base / "splade", mmap=(mode == "mmap")),
            PLAIDSearcher(index, plaid), ms)
    else:
        # distinct group dir per shard count: an open retriever's mmaps
        # must never alias a group being re-split at another count
        group = split_index_tree(base, n_shards,
                                 group_dir=base / f"shards{n_shards}")
        retr = build_sharded_retriever(
            [group / str(i) for i in range(n_shards)],
            shard_boundaries(cfg.n_docs, n_shards), mode=mode,
            plaid_params=plaid, multistage_params=ms)
    _CACHE[key] = (corpus, retr)
    return corpus, retr


def process_sharded_dataset(name: str, n_shards: int,
                            mode: str = "mmap",
                            transport: str | None = None,
                            arena_bytes: int | None = None):
    """(corpus, ProcessShardGroup) over the same on-disk shard split
    :func:`sharded_dataset` uses (n_shards=1 runs the whole index in a
    single worker process), so thread/process sweeps compare identical
    bytes. ``transport`` selects the worker tensor path (``shm`` ring
    arenas / ``socket`` stream / None = platform default). NOT cached:
    worker processes are a held resource — callers own the returned
    group and must ``close()`` it."""
    from repro.core.multistage import MultiStageParams
    from repro.core.plaid import PlaidParams
    from repro.core.sharded import build_shard_group
    from repro.index.sharding import shard_boundaries, split_index_tree

    corpus, _ = sharded_dataset(name, max(n_shards, 2), mode=mode)
    cfg = DATASETS[name]
    _, base = _CACHE[(name, mode, "serve_layout")]
    group = split_index_tree(base, n_shards,
                             group_dir=base / f"shards{n_shards}")
    retr = build_shard_group(
        [group / str(i) for i in range(n_shards)],
        shard_boundaries(cfg.n_docs, n_shards), workers="process",
        mode=mode,
        plaid_params=PlaidParams(nprobe=4, candidate_cap=1024,
                                 ndocs=256, k=100),
        multistage_params=MultiStageParams(first_k=200, k=100,
                                           alpha=0.3),
        transport=transport, arena_bytes=arena_bytes)
    return corpus, retr


def run_all_queries(retr, corpus, method: str, n_queries=None, alpha=None,
                    k=100):
    n = n_queries or len(corpus["qrels"])
    ranked, lat = [], []
    for qi in range(n):
        t0 = time.perf_counter()
        pids, _ = retr.search(method, q_emb=corpus["q_embs"][qi],
                              term_ids=corpus["q_term_ids"][qi],
                              term_weights=corpus["q_term_weights"][qi],
                              alpha=alpha, k=k)
        lat.append(time.perf_counter() - t0)
        ranked.append(pids)
    return np.stack(ranked), np.asarray(lat)


def save(name: str, payload: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))
    return payload
