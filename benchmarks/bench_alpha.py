"""Table 3: Hybrid quality as a function of α (0 = Rerank, 1 = SPLADE).
The paper's signature shape: quality first rises, then falls."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DATASETS, dataset, run_all_queries, save
from repro.eval import metrics

ALPHAS = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def sweep(name: str, n_queries: int = 150):
    corpus, _, _, retr = dataset(name)
    qrels = corpus["qrels"][:n_queries]
    out = {}
    for a in ALPHAS:
        ranked, _ = run_all_queries(retr, corpus, "hybrid",
                                    n_queries=n_queries, alpha=a)
        out[a] = metrics.mrr_at_k(ranked, qrels, 10)
    return out


def main(quick: bool = False):
    names = ["marco"] if quick else list(DATASETS)
    table = {}
    for name in names:
        curve = sweep(name, n_queries=100 if quick else 150)
        table[name] = curve
        vals = list(curve.values())
        print(f"\n== {name} α sweep (MRR@10) ==")
        print("  ".join(f"{a:.1f}:{v:.4f}" for a, v in curve.items()))
        best = int(np.argmax(vals))
        print(f"best α = {ALPHAS[best]}")
        # rise-then-fall: interior max beats both endpoints on ≥1 set
        table[f"{name}_best_alpha"] = ALPHAS[best]
    interior_win = any(
        0 < ALPHAS[int(np.argmax(list(table[n].values())))] < 1
        for n in names)
    assert interior_win, "expected an interior-α optimum on some dataset"
    save("alpha_table3", table)
    return table


if __name__ == "__main__":
    main()
