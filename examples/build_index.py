"""Index-build pipeline: encoders → compressed artifacts → mmap serving.

    PYTHONPATH=src python examples/build_index.py [--out DIR]

Runs the full offline stage of ColBERT-serve: encode a token corpus
with the (untrained, demo) ColBERT + SPLADE encoders, train centroids,
fit the residual codec, write the PagedStore + IVF + SPLADE postings to
disk, then reopen everything memory-mapped and run a query through the
Hybrid path.
"""

import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.colbert_serve import smoke_cfg
from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.data.synth import make_token_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.models import colbert as CB
from repro.models import splade as SP
from repro.models.encoder import EncoderCfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--docs", type=int, default=512)
    args = ap.parse_args()
    out = pathlib.Path(args.out or tempfile.mkdtemp(prefix="index_"))

    cfg = smoke_cfg()
    ccfg = cfg.colbert
    rng = np.random.default_rng(0)
    doc_toks, doc_lens = make_token_corpus(rng, args.docs,
                                           ccfg.encoder.vocab,
                                           ccfg.doc_maxlen)

    print("encoding corpus with ColBERT ...")
    cparams = CB.init(jax.random.PRNGKey(0), ccfg)
    t0 = time.time()
    embs, valid = jax.jit(lambda t, l: CB.encode_docs(cparams, ccfg, t, l))(
        jnp.asarray(doc_toks), jnp.asarray(doc_lens))
    print(f"  {args.docs} docs in {time.time() - t0:.1f}s")

    print("building compressed index (k-means → 4-bit residuals → IVF)")
    build_colbert_index(out / "colbert", np.asarray(embs), doc_lens,
                        nbits=4, n_centroids=128, kmeans_iters=6)

    print("encoding corpus with SPLADE + building impact postings")
    scfg = SP.SpladeCfg(encoder=EncoderCfg(
        name="splade-demo", vocab=ccfg.encoder.vocab, d_model=64,
        n_layers=1, n_heads=4, d_ff=128, max_len=64), top_terms=16)
    sparams = SP.init(jax.random.PRNGKey(1), scfg)
    mask = np.arange(ccfg.doc_maxlen)[None] < doc_lens[:, None]
    vec = jax.jit(lambda t, m: SP.encode(sparams, scfg, t, m))(
        jnp.asarray(doc_toks), jnp.asarray(mask))
    ids, w = SP.sparsify(vec, scfg.top_terms)
    sidx = build_splade_index(np.asarray(ids), np.asarray(w),
                              ccfg.encoder.vocab, args.docs)
    sidx.save(out / "splade")

    print("reopening memory-mapped + serving one Hybrid query")
    index = ColBERTIndex(out / "colbert", mode="mmap")
    sidx2 = SpladeIndex.load(out / "splade", mmap=True)
    retr = MultiStageRetriever(
        sidx2, PLAIDSearcher(index, PlaidParams(nprobe=4,
                                                candidate_cap=256,
                                                ndocs=64)),
        MultiStageParams(first_k=50, k=10, alpha=0.3))
    q_toks = jnp.asarray(doc_toks[7:8, :ccfg.query_maxlen])
    q_emb = CB.encode_queries(cparams, ccfg, q_toks,
                              jnp.asarray([ccfg.query_maxlen]))[0]
    q_vec = SP.encode(sparams, scfg, q_toks,
                      jnp.ones_like(q_toks, bool))
    q_ids, q_w = SP.sparsify(q_vec, 16)
    pids, scores = retr.search("hybrid", q_emb=np.asarray(q_emb),
                               term_ids=np.asarray(q_ids[0]),
                               term_weights=np.asarray(q_w[0]))
    print(f"  query=doc7 → top5 pids {pids[:5].tolist()}")
    print(f"  artifacts in {out}:")
    for p in sorted(out.rglob("*")):
        if p.is_file():
            print(f"    {p.relative_to(out)}  {p.stat().st_size / 1e3:.1f} kB")


if __name__ == "__main__":
    main()
