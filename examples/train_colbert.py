"""Train a ColBERT encoder contrastively (in-batch negatives) with the
fault-tolerant loop, then build an index from it and check retrieval.

    PYTHONPATH=src python examples/train_colbert.py [--steps 300]

Demonstrates: synthetic token corpus → contrastive training (AdamW,
checkpoint every 50 steps, resumable — re-run the command and it
continues) → corpus encoding → index build → MaxSim retrieval quality
before vs after training.
"""

import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.colbert_serve import smoke_cfg
from repro.data.synth import make_token_corpus
from repro.models import colbert as CB
from repro.training.optimizer import AdamWCfg
from repro.training.train_loop import LoopCfg, SeekableData, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    ccfg = smoke_cfg().colbert
    rng = np.random.default_rng(0)
    n_docs = 256
    doc_toks, doc_lens = make_token_corpus(rng, n_docs, ccfg.encoder.vocab,
                                           ccfg.doc_maxlen)

    # queries = noisy prefixes of their target docs
    def make_batch(step):
        r = np.random.default_rng(step)
        idx = r.integers(0, n_docs, args.batch)
        q = doc_toks[idx, :ccfg.query_maxlen].copy()
        noise = r.random(q.shape) < 0.15
        q[noise] = r.integers(4, ccfg.encoder.vocab, noise.sum())
        return {
            "q_tokens": jnp.asarray(q),
            "q_lens": jnp.full((args.batch,), ccfg.query_maxlen, jnp.int32),
            "d_tokens": jnp.asarray(doc_toks[idx]),
            "d_lens": jnp.asarray(doc_lens[idx]),
        }

    def loss_fn(params, batch):
        q = CB.encode_queries(params, ccfg, batch["q_tokens"],
                              batch["q_lens"])
        d, dv = CB.encode_docs(params, ccfg, batch["d_tokens"],
                               batch["d_lens"])
        s = jnp.einsum("qik,bjk->qbij", q, d)
        s = jnp.where(dv[None, :, None, :], s, -1e30)
        scores = jnp.sum(jnp.maximum(jnp.max(s, -1), 0.0), -1)
        logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
        nll = -jnp.mean(jnp.diag(logp))
        acc = jnp.mean(jnp.argmax(scores, -1) == jnp.arange(args.batch))
        return nll, {"nll": nll, "acc": acc}

    params = CB.init(jax.random.PRNGKey(0), ccfg)

    def retrieval_accuracy(p):
        d_emb, d_valid = CB.encode_docs(p, ccfg, jnp.asarray(doc_toks),
                                        jnp.asarray(doc_lens))
        hits = 0
        for i in range(0, 64):
            q = CB.encode_queries(
                p, ccfg, jnp.asarray(doc_toks[i:i + 1, :ccfg.query_maxlen]),
                jnp.asarray([ccfg.query_maxlen]))[0]
            s = CB.maxsim(q, d_emb, d_valid)
            hits += int(jnp.argmax(s)) == i
        return hits / 64

    print(f"pre-training retrieval accuracy : {retrieval_accuracy(params):.3f}")
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="colbert_ckpt_")
    opt = AdamWCfg(lr=2e-3, weight_decay=0.01, warmup_steps=20,
                   total_steps=args.steps)
    params, _, report = run(
        loss_fn, params, SeekableData(make_batch), opt,
        LoopCfg(total_steps=args.steps, ckpt_every=50, ckpt_dir=ckpt,
                log_every=20))
    if report.resumed_from:
        print(f"(resumed from checkpointed step {report.resumed_from})")
    print(f"loss: {report.losses[0]:.3f} → {report.losses[-1]:.3f} "
          f"over {len(report.losses)} steps")
    print(f"post-training retrieval accuracy: {retrieval_accuracy(params):.3f}")
    print(f"checkpoints in {ckpt} (re-run with --ckpt {ckpt} to resume)")


if __name__ == "__main__":
    main()
