"""End-to-end serving driver (the paper's deployment scenario):

    PYTHONPATH=src python examples/serve_concurrent.py [--tcp]

Brings up the concurrent retrieval server over a memory-mapped index,
drives it with Poisson traffic at several offered loads (batched
concurrent clients), and reports client-observed p50/p95/p99 — the
paper's Fig 1/2 methodology. --tcp also exercises the newline-JSON TCP
front with a real socket client.
"""

import argparse
import pathlib
import sys
import tempfile
import threading
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.data.synth import SynthCfg, make_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import build_splade_index
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import run_open_loop, run_poisson_load
from repro.serving.server import (RetrievalServer, TCPRetrievalServer,
                                  tcp_query)


def build_stack(splade_backend="host", splade_max_df=None,
                rerank_backend="fused"):
    cfg = SynthCfg(n_docs=2500, n_queries=200, seed=3)
    corpus = make_corpus(cfg)
    d = tempfile.mkdtemp(prefix="serve_")
    build_colbert_index(d, corpus["doc_embs"], corpus["doc_lens"],
                        nbits=4, n_centroids=256, kmeans_iters=4)
    index = ColBERTIndex(d, mode="mmap")
    sidx = build_splade_index(corpus["doc_term_ids"],
                              corpus["doc_term_weights"], cfg.vocab,
                              cfg.n_docs)
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=4,
                                                candidate_cap=1024,
                                                ndocs=256))
    retr = MultiStageRetriever(
        sidx, searcher,
        MultiStageParams(first_k=200, alpha=0.3,
                         splade_backend=splade_backend,
                         splade_max_df=splade_max_df,
                         rerank_backend=rerank_backend))
    if retr.rerank_backend != rerank_backend:
        print(f"rerank backend {rerank_backend!r} unavailable — "
              f"using {retr.rerank_backend!r}")
    return corpus, retr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tcp", action="store_true")
    ap.add_argument("--method", default="hybrid")
    ap.add_argument("--n", type=int, default=50)
    ap.add_argument("--threads", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=1,
                    help="micro-batch size (1 = request-at-a-time)")
    ap.add_argument("--batch-timeout-ms", type=float, default=2.0,
                    help="max wait to coalesce a micro-batch")
    ap.add_argument("--latency-slo-ms", type=float, default=None,
                    help="adaptive micro-batching: shrink/grow the "
                         "effective batch cap to keep batch service "
                         "time (EWMA) under this SLO")
    ap.add_argument("--splade-backend", default="host",
                    choices=["host", "jax", "pallas"],
                    help="stage-1 scorer backend")
    ap.add_argument("--splade-max-df", type=int, default=None,
                    help="padded-postings df cap for jax/pallas "
                         "(memory vs exactness; default: exact)")
    ap.add_argument("--rerank-backend", default="fused",
                    choices=["fused", "split"],
                    help="stage-4 tail: fused single-dispatch "
                         "decompress+MaxSim+top-k vs the legacy split "
                         "dispatches (bitwise-identical results)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="stage-graph pipelining: 1 = synchronous, "
                         ">=2 overlaps mmap gathers with device "
                         "scoring across micro-batches")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="drive with strictly open-loop Poisson "
                         "arrivals at this QPS instead of the "
                         "capacity-relative sweep")
    args = ap.parse_args()

    print("building index + retriever ...")
    corpus, retr = build_stack(splade_backend=args.splade_backend,
                               splade_max_df=args.splade_max_df,
                               rerank_backend=args.rerank_backend)
    # backend already configured via MultiStageParams in build_stack
    server = RetrievalServer(
        ServeEngine(retr, pipeline_depth=args.pipeline_depth),
        n_threads=args.threads, max_batch=args.max_batch,
        batch_timeout_ms=args.batch_timeout_ms,
        latency_slo_ms=args.latency_slo_ms)
    server.start()

    def reqs(n):
        return [Request(qid=i, method=args.method,
                        q_emb=corpus["q_embs"][i % 200],
                        term_ids=corpus["q_term_ids"][i % 200],
                        term_weights=corpus["q_term_weights"][i % 200],
                        k=20) for i in range(n)]

    # warm up + measure capacity
    for r in reqs(8):
        server.submit(r).result(timeout=120)
    if args.max_batch > 1:
        # warm the coalesced batch shapes, then measure capacity as burst
        # throughput — a lone probe request would pay the full
        # batch_timeout_ms coalescing window and understate capacity
        for f in [server.submit(r) for r in reqs(2 * args.max_batch)]:
            f.result(timeout=120)
        n_cap = 4 * args.max_batch
        t0 = time.perf_counter()
        for f in [server.submit(r) for r in reqs(n_cap)]:
            f.result(timeout=120)
        cap = n_cap / (time.perf_counter() - t0)
        svc = 1.0 / cap
    else:
        svc = np.mean([server.submit(r).result(timeout=120).service_time
                       for r in reqs(8)])
        cap = 1.0 / svc
    print(f"service time {svc * 1e3:.1f} ms → capacity ≈ {cap:.1f} QPS "
          f"({args.threads} thread(s), max_batch={args.max_batch})\n")
    print(f"{'offered':>10s} {'p50':>9s} {'p95':>9s} {'p99':>9s} "
          f"{'achieved':>9s}")
    if args.arrival_rate is not None:
        # strictly open-loop at exactly the requested rate (no sweep):
        # what you ask for is what gets offered
        rates = [args.arrival_rate]
    else:
        rates = [cap * frac for frac in (0.3, 0.6, 0.9, 1.5)]
    for rate in rates:
        if args.arrival_rate is not None:
            res = run_open_loop(server, reqs(args.n), arrival_rate=rate,
                                seed=0)
        else:
            res = run_poisson_load(server, reqs(args.n), qps=rate,
                                   seed=0, burst=args.max_batch)
        s = res.summary()
        print(f"{s['offered_qps']:8.1f}/s {s['p50'] * 1e3:7.1f}ms "
              f"{s['p95'] * 1e3:7.1f}ms {s['p99'] * 1e3:7.1f}ms "
              f"{s['achieved_qps']:7.1f}/s")
    print("\nhealth:", server.health())

    if args.tcp:
        tcp = TCPRetrievalServer(("127.0.0.1", 0), server)
        port = tcp.server_address[1]
        threading.Thread(target=tcp.serve_forever, daemon=True).start()
        print(f"\nTCP front on :{port}; sending one JSON query ...")
        out = tcp_query("127.0.0.1", port, {
            "qid": 0, "method": args.method,
            "q_emb": corpus["q_embs"][0].tolist(),
            "term_ids": corpus["q_term_ids"][0].tolist(),
            "term_weights": corpus["q_term_weights"][0].tolist(), "k": 5})
        print("response:", {k: out[k] for k in ("qid", "pids", "latency")})
        tcp.shutdown()

    server.drain()
    server.stop()
    print("drained + stopped cleanly.")


if __name__ == "__main__":
    main()
