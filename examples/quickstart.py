"""Quickstart: build a ColBERT-serve stack end-to-end on synthetic data.

    PYTHONPATH=src python examples/quickstart.py

Steps: synthetic corpus → ColBERT index (k-means + 4-bit residuals +
IVF, memory-mapped) → SPLADE impact index → the four systems from the
paper (ColBERTv2 / SPLADEv2 / Rerank / Hybrid) → quality + access stats.
"""

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.data.synth import SynthCfg, make_corpus
from repro.eval import metrics
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import build_splade_index


def main():
    print("1) synthesising corpus (complementary lexical+semantic views)")
    cfg = SynthCfg(n_docs=2000, n_queries=150, seed=0)
    corpus = make_corpus(cfg)

    print("2) building the compressed ColBERT index (mmap'd pool)")
    d = tempfile.mkdtemp(prefix="quickstart_")
    build_colbert_index(d, corpus["doc_embs"], corpus["doc_lens"],
                        nbits=4, n_centroids=256, kmeans_iters=6)
    index = ColBERTIndex(d, mode="mmap")
    print(f"   pool: {index.store.total_bytes() / 1e6:.1f} MB on disk, "
          f"{index.n_centroids} centroids, {index.store.n_tokens} tokens")

    print("3) building the SPLADE impact index (PISA adaptation)")
    sidx = build_splade_index(corpus["doc_term_ids"],
                              corpus["doc_term_weights"], cfg.vocab,
                              cfg.n_docs)

    searcher = PLAIDSearcher(index, PlaidParams(nprobe=4,
                                                candidate_cap=1024,
                                                ndocs=256, k=100))
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=200, alpha=0.3))

    print("4) running the paper's four systems\n")
    print(f"{'method':8s}  MRR@10   R@5    R@50   S@5")
    index.store.stats.reset()
    for method in ("colbert", "splade", "rerank", "hybrid"):
        ranked = []
        for qi in range(cfg.n_queries):
            pids, _ = retr.search(method, q_emb=corpus["q_embs"][qi],
                                  term_ids=corpus["q_term_ids"][qi],
                                  term_weights=corpus["q_term_weights"][qi])
            ranked.append(pids)
        r = np.stack(ranked)
        q = corpus["qrels"]
        print(f"{method:8s}  {metrics.mrr_at_k(r, q, 10):.4f}  "
              f"{metrics.recall_at_k(r, q, 5):.4f} "
              f"{metrics.recall_at_k(r, q, 50):.4f} "
              f"{metrics.success_at_k(r, q, 5):.4f}")

    st = index.store.stats
    print(f"\nmmap pool access: {st.tokens_read} token rows, "
          f"{len(st.unique_pages or ())} unique 4KiB pages "
          f"({100 * index.store.resident_fraction_estimate():.0f}% of pool)")
    print("done.")


if __name__ == "__main__":
    main()
