"""Sweep synth-corpus noise knobs until the paper's quality ordering holds:
hybrid > colbert ~ rerank > splade, with headroom (no metric at 1.0)."""
import dataclasses
import tempfile

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.data.synth import SynthCfg, make_corpus
from repro.eval import metrics
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import build_splade_index


def run(cfg):
    corpus = make_corpus(cfg)
    tmp = tempfile.mkdtemp()
    build_colbert_index(tmp, corpus["doc_embs"], corpus["doc_lens"], nbits=4,
                        n_centroids=256, kmeans_iters=5)
    index = ColBERTIndex(tmp, mode="ram")
    sidx = build_splade_index(corpus["doc_term_ids"],
                              corpus["doc_term_weights"],
                              cfg.vocab, cfg.n_docs)
    searcher = PLAIDSearcher(index, PlaidParams(nprobe=4, candidate_cap=1024,
                                                ndocs=256, k=100),
                             device_resident=True)
    retr = MultiStageRetriever(sidx, searcher,
                               MultiStageParams(first_k=200, k=100, alpha=0.3))
    out = {}
    for m in ["colbert", "splade", "rerank", "hybrid"]:
        r = []
        for qi in range(cfg.n_queries):
            pids, _ = retr.search(m, q_emb=corpus["q_embs"][qi],
                                  term_ids=corpus["q_term_ids"][qi],
                                  term_weights=corpus["q_term_weights"][qi])
            r.append(pids)
        out[m] = metrics.mrr_at_k(np.stack(r), corpus["qrels"], 10)
    return out


base = SynthCfg(n_docs=1200, n_queries=100)
for sem_noise in [1.4, 1.8, 2.2]:
    for confuser in [0.45, 0.65]:
        cfg = dataclasses.replace(base, sem_noise=sem_noise, confuser=confuser)
        r = run(cfg)
        flag = "✓" if r["hybrid"] > max(r["colbert"], r["splade"]) and \
            r["colbert"] > r["splade"] and r["colbert"] < 0.97 else " "
        print(f"sem={sem_noise} conf={confuser}: "
              + " ".join(f"{m}={v:.3f}" for m, v in r.items()) + f"  {flag}")
