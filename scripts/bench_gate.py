#!/usr/bin/env python
"""Bench-regression gate: a pinned-seed mini serving benchmark whose
trajectory CI refuses to let slide.

Runs a small, fully deterministic workload (synthetic corpus, fixed
seeds, 2-shard pipelined serving of a mixed closed-loop load through
the full front door: coordinator caches + a generously-provisioned
admission controller — a healthy run must shed zero requests, cache-on
cold results must keep the pinned CRC/token volume, and a repeat pass
must serve every request from the exact cache bitwise without touching
a residual token), then a mini thread-vs-process worker comparison
over the same split (process rankings must match the thread run
exactly; QPS plus the transport's zero-copy/copied byte split and RPC
dispatch counts are recorded). The gate runs **frozen**: live-index
counters (delta docs, tombstones, compactions, generation) are
recorded and hard-asserted zero — the mutable-index overlay must stay
inert when unused, so the pinned CRC genuinely pins the frozen
layout. It writes the measured metrics to
``results/bench_ci.json``, and compares them against the committed
baseline in ``results/bench_baseline.json``:

* **perf metrics** (QPS, gather-stage wall) are gated with a ±tolerance
  band (default 50%, override with ``--tolerance`` or
  ``BENCH_GATE_TOL``) — wide on purpose: shared CI boxes are noisy, and
  the gate is meant to catch a *halved* throughput or a gather stage
  that stopped overlapping, not a 5% wobble;
* **determinism metrics** (result checksum, residual tokens gathered)
  are gated tightly (2%): same seeds + same code must touch the same
  candidates, so drift here is a correctness change, not noise.

The first run (no baseline on disk) seeds the baseline and passes —
commit the file to pin the trajectory. ``--update-baseline`` reseeds
after an accepted change to the serving cost model.

Wired in as ``scripts/ci.sh bench-gate`` (part of ``ci.sh all``).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile
import time
import zlib

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

RESULTS = REPO / "results"
CI_JSON = RESULTS / "bench_ci.json"
BASELINE_JSON = RESULTS / "bench_baseline.json"

N_QUERIES = 96
METHODS = ("hybrid", "rerank", "splade", "colbert")


def run_bench() -> dict:
    import numpy as np

    from repro.core.multistage import MultiStageParams
    from repro.core.plaid import PlaidParams
    from repro.core.sharded import build_shard_group
    from repro.data.synth import SynthCfg, make_corpus
    from repro.index.builder import build_colbert_index
    from repro.index.sharding import load_group, split_index_tree
    from repro.index.splade_index import build_splade_index
    from repro.serving.admission import AdmissionController
    from repro.serving.context import CacheHierarchy
    from repro.serving.engine import Request, ServeEngine
    from repro.serving.loadgen import run_closed_loop
    from repro.serving.server import RetrievalServer

    cfg = SynthCfg(n_docs=800, n_queries=160, seed=5)
    corpus = make_corpus(cfg)
    base = pathlib.Path(tempfile.mkdtemp(prefix="bench_gate_"))
    build_colbert_index(base / "colbert", corpus["doc_embs"],
                        corpus["doc_lens"], nbits=4, n_centroids=128,
                        kmeans_iters=4)
    build_splade_index(corpus["doc_term_ids"],
                       corpus["doc_term_weights"], cfg.vocab,
                       cfg.n_docs).save(base / "splade")
    group = split_index_tree(base, 2)
    dirs, bounds = load_group(group)
    plaid = PlaidParams(nprobe=4, candidate_cap=512, ndocs=128)
    ms = MultiStageParams(first_k=100, k=50, alpha=0.3)
    retr = build_shard_group(dirs, bounds, workers="thread", mode="mmap",
                             plaid_params=plaid, multistage_params=ms)

    reqs = [Request(qid=i, method=METHODS[i % len(METHODS)],
                    q_emb=corpus["q_embs"][i % cfg.n_queries],
                    term_ids=corpus["q_term_ids"][i % cfg.n_queries],
                    term_weights=corpus["q_term_weights"][i % cfg.n_queries],
                    k=20)
            for i in range(N_QUERIES)]

    # the full front door rides along: coordinator caches + a
    # generously-provisioned admission controller. A healthy gate run
    # must never shed, and with every request in the stream distinct
    # the caches only store during the perf pass — the QPS band
    # measures the cold path, not hits
    caches = CacheHierarchy(exact_entries=256, stage1_entries=256)
    admission = AdmissionController(latency_slo_ms=60_000.0)
    srv = RetrievalServer(ServeEngine(retr, pipeline_depth=2,
                                      caches=caches),
                          n_threads=1, max_batch=8, batch_timeout_ms=4.0,
                          admission=admission)
    srv.start()
    try:
        for f in [srv.submit(r) for r in reqs[:16]]:      # warm compiles
            f.result(timeout=600)
        retr.reset_stage_stats()
        res = run_closed_loop(srv, reqs, concurrency=4)   # perf pass
        snap = retr.pipeline_stats.snapshot()
        gather_wall = sum(r["wall_s"] for n, r in snap["stages"].items()
                          if n.startswith("host_gather"))
        # determinism pass runs request-at-a-time on purpose: token
        # counts and rankings must not depend on which requests the
        # micro-batcher happened to coalesce (dedup'd gathers make the
        # *batched* token volume timing-dependent). Caches are cleared
        # first so the pass runs cold — cache-on cold results must keep
        # the pinned CRC and token volume (caches never perturb the
        # cold path)
        caches.clear()
        stores = [sh.searcher.index.store for sh in retr.shards]
        tok0 = sum(s.stats.snapshot()["residual_tokens_read"]
                   for s in stores)
        pids_crc = 0
        for q in reqs[:32]:
            out = srv.submit(q).result(timeout=600)
            pids_crc = zlib.crc32(
                np.ascontiguousarray(out.pids).tobytes(), pids_crc)
        tokens = sum(s.stats.snapshot()["residual_tokens_read"]
                     for s in stores) - tok0
        # cache-hit repeat pass: the same 32 requests again — every one
        # must resolve from the exact cache, bitwise the cold answer
        # (same CRC) without touching a single residual token
        hit_crc = 0
        for q in reqs[:32]:
            out = srv.submit(q).result(timeout=600)
            assert out.cache_hit, f"qid {q.qid} missed on repeat"
            hit_crc = zlib.crc32(
                np.ascontiguousarray(out.pids).tobytes(), hit_crc)
        assert hit_crc == pids_crc, (
            f"cache-hit rankings diverged from cold ({hit_crc} vs "
            f"{pids_crc})")
        hit_tokens = sum(s.stats.snapshot()["residual_tokens_read"]
                         for s in stores) - tok0 - tokens
        assert hit_tokens == 0, (
            f"cache hits read {hit_tokens} residual tokens")
        # a healthy, generously-provisioned gate run never sheds
        adm_stats = admission.stats()
        assert adm_stats["sheds"] == 0, adm_stats
        assert adm_stats["degraded_admits"] == 0, adm_stats
        cache_stats = caches.stats()
        assert cache_stats["exact"]["hits"] >= 32, cache_stats
        # live-index inertness: the gate never enables mutations, so the
        # frozen serve path must not have touched the mutable-index
        # machinery — no LiveView materialized, zero generation bumps.
        # Anything else means the live overlay leaks into the frozen
        # path and the pinned CRC band no longer pins the frozen layout
        assert getattr(retr, "live", None) is None, \
            "live state materialized on the frozen path"
        thread_gen = int(getattr(retr, "index_generation", 0))
        assert thread_gen == 0, \
            f"frozen path bumped index generation to {thread_gen}"
    finally:
        srv.stop()
        retr.attach_caches(None)

    # mini thread-vs-process worker comparison: the same shard split and
    # request stream through shared-nothing worker processes over the
    # default transport (shm ring arenas where /dev/shm is writable).
    # Rankings must match the thread run exactly (parity is a hard
    # in-run assert); QPS and the transport byte split are recorded so
    # the trajectory of the process-worker cliff stays visible in CI.
    pg = build_shard_group(dirs, bounds, workers="process", mode="mmap",
                           plaid_params=plaid, multistage_params=ms)
    srv = RetrievalServer(ServeEngine(pg, pipeline_depth=2),
                          n_threads=1, max_batch=8, batch_timeout_ms=4.0)
    srv.start()
    try:
        for f in [srv.submit(r) for r in reqs[:16]]:     # warm workers
            f.result(timeout=600)
        pres = run_closed_loop(srv, reqs[:48], concurrency=4)
        crc = 0
        for q in reqs[:32]:
            out = srv.submit(q).result(timeout=600)
            crc = zlib.crc32(
                np.ascontiguousarray(out.pids).tobytes(), crc)
        assert crc == pids_crc, (
            "process-group rankings diverged from thread workers "
            f"({crc} vs {pids_crc})")
        ts = pg.transport_stats()
        counters = pg.pipeline_stats.snapshot()["counters"]
        # a healthy single-replica bench must never have needed the
        # fleet machinery: any failover/hedge/degraded activity here
        # means workers died (or stalled) during the gate run
        assert counters.get("degraded_batches", 0) == 0, counters
        assert counters.get("failover_retries", 0) == 0, counters
        process_workers = {
            "qps": pres.achieved_qps, "p99_ms": pres.p99 * 1e3,
            "transport": ts["transport"],
            "bytes_zero_copy": int(ts["total"]["bytes_zero_copy"]),
            "bytes_copied": int(ts["total"]["bytes_copied"]),
            "rpc_dispatches": int(counters.get("rpc_dispatches", 0)),
            "rpc_coalesced_ops": int(
                counters.get("rpc_coalesced_ops", 0)),
            "failover_retries": int(counters.get("failover_retries", 0)),
            "hedges": int(counters.get("hedges", 0)),
            "replica_heals": int(counters.get("replica_heals", 0)),
            "degraded_batches": int(counters.get("degraded_batches", 0))}
        # same inertness bar for the process group: no live overlay, no
        # generation bumps, no delta/tombstone/compaction activity
        assert getattr(pg, "live", None) is None, \
            "live state materialized on the frozen process-group path"
        proc_gen = int(getattr(pg, "index_generation", 0))
        assert proc_gen == 0, \
            f"frozen process path bumped index generation to {proc_gen}"
    finally:
        srv.stop()
        pg.close()

    import platform

    import jax

    return {
        # rerank_backend records which stage-4 tail produced the perf
        # numbers (the shards resolve "fused" → "split" when Pallas is
        # missing); the checksum band stays identical either way — the
        # fused tail is bitwise the split one
        "config": {"n_docs": cfg.n_docs, "seed": cfg.seed,
                   "n_queries": N_QUERIES, "shards": 2,
                   "pipeline_depth": 2, "max_batch": 8,
                   "rerank_backend": retr.rerank_backend},
        # determinism holds per (jax build, machine) — fp reduction
        # order is an XLA/ISA property, so the exact bands only apply
        # when the environment matches the baseline's
        "env": {"jax": jax.__version__,
                "machine": platform.machine(),
                "python": platform.python_version()},
        "perf": {"qps": res.achieved_qps,
                 "p50_ms": res.p50 * 1e3, "p99_ms": res.p99 * 1e3,
                 "gather_wall_s": gather_wall},
        # recorded (not perf-gated — worker spawn + a 1-core box make
        # it noisy); parity with the thread run is asserted in-run
        "process_workers": process_workers,
        # recorded front-door trajectory: cache hit/miss/eviction and
        # admission counters (zero sheds + bitwise/zero-token hit
        # repeats are hard in-run asserts above, not baseline bands)
        "front_door": {"caches": cache_stats, "admission": adm_stats},
        # live-index trajectory: the gate runs frozen, so every counter
        # must stay zero — recorded (and hard-asserted in-run) so a
        # change that wakes the mutable-index machinery on the frozen
        # path shows up as a red gate, not a silent perf tax
        "live_index": {"enabled": False,
                       "generation": thread_gen,
                       "process_generation": proc_gen,
                       "delta_docs": 0, "tombstones": 0,
                       "compactions": 0},
        "determinism": {"pids_crc32": pids_crc,
                        "residual_tokens_read": int(tokens),
                        "served": int(len(res.latencies)),
                        "failed": int(res.failed)},
    }


def compare(metrics: dict, baseline: dict, tol: float) -> list:
    """Gate ``metrics`` against ``baseline``; returns failure strings.

    The exact determinism bands (result checksum, gather volume) only
    apply when the environment matches the baseline's — a different
    jax build or CPU ISA legitimately changes fp reduction order, and
    a permanently red gate on new hardware teaches people to ignore
    it. On an env mismatch the gate reports the skip and keeps the
    (wide) perf band, and the right move is to reseed on the new
    environment (``--update-baseline``)."""
    fails = []
    mp, bp = metrics["perf"], baseline["perf"]
    if mp["qps"] < bp["qps"] * (1 - tol):
        fails.append(f"QPS regressed: {mp['qps']:.1f} < "
                     f"{(1 - tol):.2f}x baseline {bp['qps']:.1f}")
    if mp["gather_wall_s"] > bp["gather_wall_s"] * (1 + tol) + 0.05:
        fails.append(
            f"gather wall regressed: {mp['gather_wall_s']:.3f}s > "
            f"{(1 + tol):.2f}x baseline {bp['gather_wall_s']:.3f}s")
    md, bd = metrics["determinism"], baseline["determinism"]
    if md["served"] != bd["served"] or md["failed"]:
        fails.append(f"served/failed drifted: {md} vs {bd}")
    li = metrics.get("live_index") or {}
    if any(li.get(k) for k in ("generation", "process_generation",
                               "delta_docs", "tombstones", "compactions")):
        fails.append(f"live-index counters nonzero on a frozen gate "
                     f"run: {li} — the mutable-index overlay leaked "
                     f"into the frozen path")
    if metrics.get("env") != baseline.get("env"):
        print(f"bench-gate: env changed ({baseline.get('env')} → "
              f"{metrics.get('env')}) — determinism bands skipped; "
              f"reseed with --update-baseline to re-arm them")
        return fails
    tok_m, tok_b = md["residual_tokens_read"], bd["residual_tokens_read"]
    if tok_b and abs(tok_m - tok_b) > 0.02 * tok_b:
        fails.append(f"residual gather volume drifted: {tok_m} vs "
                     f"baseline {tok_b} (>2%) — the candidate sets "
                     f"changed, not the machine")
    if md["pids_crc32"] != bd["pids_crc32"]:
        fails.append(f"result checksum changed: {md['pids_crc32']} vs "
                     f"{bd['pids_crc32']} — rankings drifted")
    return fails


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOL", 0.5)),
                    help="allowed relative perf regression (default "
                         "0.5 = 50%%; env BENCH_GATE_TOL)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="reseed the committed baseline from this run")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    metrics = run_bench()
    metrics["wall_s"] = time.perf_counter() - t0
    RESULTS.mkdir(parents=True, exist_ok=True)
    CI_JSON.write_text(json.dumps(metrics, indent=1))
    print(f"bench-gate: qps={metrics['perf']['qps']:.1f} "
          f"[rerank={metrics['config']['rerank_backend']}] "
          f"p99={metrics['perf']['p99_ms']:.1f}ms "
          f"gather={metrics['perf']['gather_wall_s'] * 1e3:.1f}ms "
          f"tokens={metrics['determinism']['residual_tokens_read']} "
          f"crc={metrics['determinism']['pids_crc32']} "
          f"→ {CI_JSON.relative_to(REPO)}")
    pw = metrics.get("process_workers") or {}
    if pw:
        print(f"bench-gate: process workers qps={pw['qps']:.1f} "
              f"({pw['transport']}: zero_copy={pw['bytes_zero_copy']}B "
              f"copied={pw['bytes_copied']}B "
              f"dispatches={pw['rpc_dispatches']} "
              f"coalesced={pw['rpc_coalesced_ops']}) "
              f"failovers={pw['failover_retries']} "
              f"hedges={pw['hedges']} "
              f"degraded={pw['degraded_batches']}")

    if args.update_baseline or not BASELINE_JSON.exists():
        BASELINE_JSON.write_text(json.dumps(metrics, indent=1))
        print(f"bench-gate: baseline "
              f"{'reseeded' if args.update_baseline else 'seeded'} at "
              f"{BASELINE_JSON.relative_to(REPO)} — commit it to pin "
              f"the perf trajectory")
        return 0

    baseline = json.loads(BASELINE_JSON.read_text())
    fails = compare(metrics, baseline, args.tolerance)
    if fails:
        print("bench-gate: REGRESSION", file=sys.stderr)
        for f in fails:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"bench-gate: PASS (qps within {args.tolerance:.0%} of "
          f"baseline {baseline['perf']['qps']:.1f}, determinism exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
