#!/usr/bin/env bash
# Tiered CI entrypoint — the same subcommands the GitHub workflow runs,
# so local runs and the CI matrix cannot drift.
#
#   ci.sh collect      fast-fail: the suite must import and collect
#   ci.sh unit         full tier-1 pytest run (regressions block merge)
#   ci.sh kernels      Pallas kernel parity in interpret mode
#   ci.sh smoke        serving-stack smokes: pipelined, sharded, and
#                      multi-process shard workers, end-to-end
#   ci.sh chaos        fault-tolerance smoke: 2-shard x 2-replica
#                      remote-worker fleet under load with seeded fault
#                      injection + SIGKILL mid-run (zero failed
#                      requests, post-heal parity)
#   ci.sh churn        live-index soak: seeded interleaved upsert/
#                      delete/query trace over a 2-shard process-worker
#                      stack, rebuild parity at every quiesce point and
#                      zero failed requests across the compaction swap
#   ci.sh bench-gate   pinned-seed mini benchmark vs committed baseline
#   ci.sh all          every stage above, in order (tier-1 default)
#
# Extra args after `unit` are forwarded to pytest (e.g.
# `ci.sh unit -k sharding`). Running with no subcommand = `all`.
# Each stage's wall time is reported in a summary at exit.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE_NAMES=()
STAGE_SECS=()

summary() {
    local status=$?
    if [ "${#STAGE_NAMES[@]}" -gt 0 ]; then
        echo
        echo "── ci stage summary ──────────────────────────"
        local i
        for i in "${!STAGE_NAMES[@]}"; do
            printf '  %-12s %6ss\n' "${STAGE_NAMES[$i]}" "${STAGE_SECS[$i]}"
        done
        echo "──────────────────────────────────────────────"
    fi
    return $status
}
trap summary EXIT

run_stage() {
    local name="$1"; shift
    echo "── ci stage: ${name} ──"
    local t0=$SECONDS
    "$@"
    STAGE_NAMES+=("$name")
    STAGE_SECS+=("$((SECONDS - t0))")
}

ensure_hypothesis() {
    # property tests are skipped without hypothesis (optional test
    # extra); install it when the image has network access
    python -c "import hypothesis" 2>/dev/null \
        || pip install -q hypothesis 2>/dev/null \
        || echo "hypothesis unavailable (offline image) — property tests skip"
}

stage_collect() {
    # cheapest possible fail: import errors and broken test modules
    # surface in seconds, before any index gets built. Output is
    # swallowed on success (thousands of test ids) but replayed on
    # failure — a silent red collect job would be undiagnosable.
    local out
    if ! out=$(python -m pytest -q --collect-only 2>&1); then
        printf '%s\n' "$out" | tail -60
        return 1
    fi
}

stage_unit() {
    ensure_hypothesis
    python -m pytest -x -q "$@"
}

stage_kernels() {
    # kernel parity in Pallas interpret mode, run explicitly: the kernel
    # bodies (maxsim, decompress+maxsim, splade single/batched, and the
    # fused rerank tail incl. its bitwise split-pipeline equivalence)
    # must match their jnp oracles even when a filtered unit run
    # skipped them
    python -m pytest -q tests/test_kernels.py tests/test_splade_stage1.py \
        -k "interpret or fused_rerank"
}

stage_smoke() {
    # pipelined smoke: full serving stack with the stage-graph executor
    # (pipeline_depth=2) over interpret-mode Pallas kernels
    python -m repro.launch.serve --pipeline-depth 2 --splade-backend pallas \
        --max-batch 8 --qps 100 --n 32

    # scatter-gather smoke: 2-shard group through the sharded plans
    # (per-shard mmap segments, fanout gathers, global top-k merge)
    python -m repro.launch.serve --shards 2 --pipeline-depth 2 \
        --max-batch 8 --qps 100 --n 32

    # process-group smoke: the same 2-shard topology with one
    # shared-nothing worker process per shard behind the RPC
    # coordinator, tensors over the zero-copy shm ring arenas
    # (spawn, serve, graceful shutdown — no orphans, no arena leaks)
    python -m repro.launch.serve --shards 2 --shard-workers process \
        --shard-transport shm \
        --pipeline-depth 2 --max-batch 8 --qps 100 --n 24

    # front-door smoke: coordinator caches + SLO admission under a
    # Zipf-skewed trace — repeats resolve from the exact cache, the
    # stage-1 cache backs the misses, and the generous SLO must not
    # shed a single request on a healthy run
    python -m repro.launch.serve --pipeline-depth 2 --max-batch 8 \
        --cache-exact 512 --cache-stage1 512 \
        --admission-slo-ms 60000 --skew 1.2 --qps 200 --n 48
}

stage_chaos() {
    # chaos smoke: a 2-shard x 2-replica fleet of standalone workers
    # on remote TCP endpoints, Poisson load with a seeded FaultyChannel
    # schedule (drops/delays/truncated/corrupt frames) while a timed
    # choreography SIGKILLs one replica of every shard mid-run and
    # restarts it — the sweep asserts zero failed requests and
    # post-heal bitwise parity with the healthy baseline
    python -m benchmarks.bench_latency --chaos-sweep --quick
}

stage_churn() {
    # live-index churn soak, the CI tier: every mutation and query goes
    # through the TCP front of a 2-shard process-worker group, with
    # from-scratch rebuild parity asserted at each quiesce point and a
    # compaction swap under concurrent traffic (results/churn_ci.json)
    python scripts/churn_soak.py --quick
}

stage_bench_gate() {
    python scripts/bench_gate.py
}

cmd="${1:-all}"
[ $# -gt 0 ] && shift

case "$cmd" in
    collect)    run_stage collect stage_collect ;;
    unit)       run_stage unit stage_unit "$@" ;;
    kernels)    run_stage kernels stage_kernels ;;
    smoke)      run_stage smoke stage_smoke ;;
    chaos)      run_stage chaos stage_chaos ;;
    churn)      run_stage churn stage_churn ;;
    bench-gate) run_stage bench-gate stage_bench_gate ;;
    all)
        run_stage collect stage_collect
        run_stage unit stage_unit "$@"
        run_stage kernels stage_kernels
        run_stage smoke stage_smoke
        run_stage chaos stage_chaos
        run_stage churn stage_churn
        run_stage bench-gate stage_bench_gate
        ;;
    *)
        echo "usage: ci.sh [collect|unit|kernels|smoke|chaos|churn|bench-gate|all]" >&2
        exit 2
        ;;
esac
