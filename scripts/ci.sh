#!/usr/bin/env bash
# Tier-1 verification — run this per PR; regressions here block merge.
# Mirrors ROADMAP.md's "Tier-1 verify" command.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# property tests are skipped without hypothesis (optional test extra);
# install it when the image has network access so they run in CI
python -c "import hypothesis" 2>/dev/null \
    || pip install -q hypothesis 2>/dev/null \
    || echo "hypothesis unavailable (offline image) — property tests skip"

python -m pytest -x -q "$@"

# kernel parity in Pallas interpret mode, run explicitly: the kernel
# bodies (maxsim, decompress+maxsim, splade single/batched) must match
# their jnp oracles even when the full run above is filtered by "$@"
python -m pytest -q tests/test_kernels.py tests/test_splade_stage1.py \
    -k "interpret"

# pipelined smoke: bring the full serving stack up with the stage-graph
# executor (pipeline_depth=2) over interpret-mode Pallas kernels
# (--splade-backend pallas lowers to interpret off-TPU), serve a
# Poisson load end-to-end, and shut down cleanly
python -m repro.launch.serve --pipeline-depth 2 --splade-backend pallas \
    --max-batch 8 --qps 100 --n 32

# scatter-gather smoke: split the index into a 2-shard group and serve
# the same pipelined load through the sharded plans (per-shard mmap
# segments, fanout gathers, global top-k merge)
python -m repro.launch.serve --shards 2 --pipeline-depth 2 \
    --max-batch 8 --qps 100 --n 32
