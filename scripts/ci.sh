#!/usr/bin/env bash
# Tier-1 verification — run this per PR; regressions here block merge.
# Mirrors ROADMAP.md's "Tier-1 verify" command.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
