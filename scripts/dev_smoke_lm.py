"""Dev scratch: tiny LM forward/loss/decode on CPU."""
import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T

attn = L.AttnCfg(d_model=64, n_heads=4, kv_heads=2, head_dim=16, qk_norm=True)
mla = L.MLACfg(d_model=64, n_heads=4, q_lora_rank=24, kv_lora_rank=16,
               qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
moe = L.MoECfg(d_model=64, d_ff_expert=32, n_experts=8, top_k=2, n_shared=1,
               d_ff_shared=32, sigmoid_router=True)
dense_block = T.BlockCfg(attn_kind="gqa", ffn_kind="dense", attn=attn, d_ff=128)
moe_block = T.BlockCfg(attn_kind="mla", ffn_kind="moe", mla=mla, moe=moe)

cfg = T.LMCfg(name="tiny", d_model=64, vocab=256,
              segments=(((dense_block,), 2), ((moe_block,), 2)),
              use_mtp=True, remat="full", attn_chunk=8,
              dtype=jnp.float32)

params = T.init(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
labels = jnp.roll(tokens, -1, axis=1)

loss, metrics = jax.jit(lambda p, t, l: T.lm_loss(p, cfg, t, l))(params, tokens, labels)
print("loss", loss, {k: float(v) for k, v in metrics.items()})
assert jnp.isfinite(loss)

# grads
g = jax.jit(jax.grad(lambda p: T.lm_loss(p, cfg, tokens, labels)[0]))(params)
gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(g)))
print("gnorm", gnorm)
assert jnp.isfinite(gnorm)

# prefill + decode
logits = jax.jit(lambda p, t: T.prefill(p, cfg, t))(params, tokens)
print("prefill logits", logits.shape)
caches = T.init_cache(cfg, batch=2, max_len=32)
tok = tokens[:, :1]
pos = jnp.zeros((2, 1), jnp.int32)
dec = jax.jit(lambda p, t, pos, c: T.decode_step(p, cfg, t, pos, c))
for i in range(4):
    lg, caches = dec(params, tok, pos, caches)
    tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    pos = pos + 1
    assert jnp.isfinite(lg).all()
print("decode ok", lg.shape)

# consistency: blockwise vs dense attention
cfg2 = T.LMCfg(name="tiny2", d_model=64, vocab=256,
               segments=(((dense_block,), 2),), remat="none",
               attn_chunk=8, dtype=jnp.float32)
p2 = T.init(jax.random.PRNGKey(0), cfg2)
h1, _ = T.forward(p2, cfg2, tokens)
cfg2d = T.LMCfg(name="tiny2d", d_model=64, vocab=256,
                segments=(((dense_block,), 2),), remat="none",
                use_blockwise_attn=False, dtype=jnp.float32)
h2, _ = T.forward(p2, cfg2d, tokens)
import numpy as np
np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)
print("blockwise == dense ✓")
print("ALL OK")
