#!/usr/bin/env python
"""Churn soak: a seeded, interleaved upsert/delete/query trace against
a live 2-shard **process-worker** serving stack, entirely through the
TCP front.

What it asserts (the live-index correctness bar, end to end):

* **Quiesce parity** — after every mutation phase the traffic stops and
  each query's TCP answer is compared bitwise (under the monotone
  surviving-pid map) against an in-process from-scratch rebuild of the
  surviving corpus with the serve index's geometry pinned.
* **Zero failed requests across the compaction swap** — background
  query threads hammer the front while a ``compact`` op merges the
  delta segment into a new index generation; every reply must be a
  well-formed, bitwise-correct answer (the swap is atomic under the
  writer gate).
* **Post-compaction generation hygiene** — the generation bumped, the
  delta drained, and parity still holds for fresh mutations layered on
  the compacted base.

Writes a machine-readable summary to ``results/churn_ci.json`` (CI
uploads it as an artifact). ``--quick`` is the CI tier; the default
runs a longer trace.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import threading
import time

import numpy as np

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.core.multistage import MultiStageParams, MultiStageRetriever  # noqa: E402
from repro.core.plaid import PLAIDSearcher, PlaidParams  # noqa: E402
from repro.core.sharded import build_shard_group  # noqa: E402
from repro.data.synth import SynthCfg, make_corpus  # noqa: E402
from repro.index.builder import ColBERTIndex, build_colbert_index  # noqa: E402
from repro.index.live import build_reference_indexes, map_global_to_ref  # noqa: E402
from repro.index.sharding import shard_boundaries, split_index_tree  # noqa: E402
from repro.index.splade_index import SpladeIndex, build_splade_index  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402
from repro.serving.server import RetrievalServer, tcp_query  # noqa: E402

# candidate_cap must not bind (rebuild parity needs both sides to keep
# every stage-2 candidate); host splade backend — a dirty live state
# forces it anyway (device scorers have no tombstone-exclusion path)
PLAID = PlaidParams(nprobe=4, candidate_cap=4096, ndocs=128, k=10)
MS = MultiStageParams(first_k=64, k=10, splade_backend="host")
METHODS = ("splade", "colbert", "rerank", "hybrid")


class Soak:
    def __init__(self, quick: bool, seed: int):
        self.rng = np.random.default_rng(seed)
        n_docs = 260 if quick else 420
        self.hold = 16 if quick else 40          # upsert pool
        self.cfg = SynthCfg(n_docs=n_docs, n_queries=16 if quick else 24,
                            vocab=512, dim=32, n_topics=12, doc_maxlen=20,
                            query_maxlen=6, seed=seed)
        self.corpus = make_corpus(self.cfg)
        self.base_n = n_docs - self.hold
        self.next_upsert = self.base_n
        self.tombstoned: set[int] = set()
        self.failures = 0
        self.ops = {"upsert": 0, "delete": 0, "query": 0, "compact": 0}
        self.parity_points = 0

    # -- stack -----------------------------------------------------------
    def build(self, root: pathlib.Path):
        c = self.corpus
        base = root / "base"
        build_colbert_index(base / "colbert", c["doc_embs"][:self.base_n],
                            c["doc_lens"][:self.base_n], nbits=4,
                            n_centroids=64, kmeans_iters=4)
        build_splade_index(c["doc_term_ids"][:self.base_n],
                           c["doc_term_weights"][:self.base_n],
                           self.cfg.vocab, self.base_n).save(base / "splade")
        self.base_index = ColBERTIndex(base / "colbert")
        self.quantum = SpladeIndex.load(base / "splade").quantum
        group_dir = split_index_tree(base, 2)
        retr = build_shard_group(
            [group_dir / str(i) for i in range(2)],
            shard_boundaries(self.base_n, 2), workers="process",
            plaid_params=PLAID, multistage_params=MS)
        retr.enable_live()
        self.engine = ServeEngine(retr, own_retriever=True)
        self.server = RetrievalServer(self.engine, n_threads=2,
                                      max_batch=4)
        self.server.start()
        tcp = self.server.serve_tcp("127.0.0.1", 0)
        threading.Thread(target=tcp.serve_forever, daemon=True).start()
        self.port = self.server.tcp_port
        self.oracle_root = root / "oracles"

    def call(self, payload: dict) -> dict:
        out = tcp_query("127.0.0.1", self.port, payload)
        if "error" in out:
            self.failures += 1
            raise AssertionError(f"request failed: {out}")
        return out

    # -- trace ops -------------------------------------------------------
    def op_upsert(self):
        j = self.next_upsert
        assert j < self.cfg.n_docs, "upsert pool exhausted"
        c = self.corpus
        L = int(c["doc_lens"][j])
        out = self.call({"op": "upsert",
                         "doc_emb": c["doc_embs"][j][:L].tolist(),
                         "doc_len": L,
                         "term_ids": c["doc_term_ids"][j].tolist(),
                         "term_weights": c["doc_term_weights"][j].tolist()})
        assert out["pid"] == j, (out, j)   # append-only global pids
        self.next_upsert += 1
        self.ops["upsert"] += 1

    def op_delete(self):
        alive = [g for g in range(self.next_upsert)
                 if g not in self.tombstoned]
        victim = int(self.rng.choice(alive))
        out = self.call({"op": "delete", "pid": victim})
        assert out["ok"] is True
        self.tombstoned.add(victim)
        self.ops["delete"] += 1

    def op_query(self, qi: int, method: str = "hybrid") -> dict:
        c = self.corpus
        out = self.call({"qid": int(qi), "method": method,
                         "q_emb": c["q_embs"][qi].tolist(),
                         "term_ids": c["q_term_ids"][qi].tolist(),
                         "term_weights": c["q_term_weights"][qi].tolist(),
                         "k": 10})
        self.ops["query"] += 1
        return out

    # -- parity ----------------------------------------------------------
    def quiesce_check(self, tag: str):
        """Stop traffic; compare every query/method answer from the TCP
        front against a from-scratch rebuild of the surviving corpus."""
        c = self.corpus
        survivors = np.array([g for g in range(self.next_upsert)
                              if g not in self.tombstoned], np.int64)
        rd = self.oracle_root / tag
        idx = self.base_index
        build_reference_indexes(
            rd / "colbert", rd / "splade",
            c["doc_embs"][survivors], c["doc_lens"][survivors],
            c["doc_term_ids"][survivors], c["doc_term_weights"][survivors],
            self.cfg.vocab, centroids=idx.centroids,
            bucket_cutoffs=idx.bucket_cutoffs,
            bucket_weights=idx.bucket_weights, nbits=idx.nbits,
            quantum=self.quantum)
        ref = MultiStageRetriever(
            SpladeIndex.load(rd / "splade", mmap=True),
            PLAIDSearcher(ColBERTIndex(rd / "colbert"), PLAID), MS)
        q = dict(q_embs=list(c["q_embs"]), term_ids=list(c["q_term_ids"]),
                 term_weights=list(c["q_term_weights"]))
        for method in METHODS:
            rp, rs = ref.search_batch(method, **q, k=10)
            for qi in range(self.cfg.n_queries):
                out = self.op_query(qi, method)
                got_p = map_global_to_ref(np.asarray(out["pids"], np.int64),
                                          survivors)
                got_s = np.asarray(out["scores"], np.float32)
                if not (np.array_equal(got_p, rp[qi])
                        and np.array_equal(got_s, np.asarray(rs[qi]))):
                    raise AssertionError(
                        f"parity broken at {tag} method={method} q={qi}:\n"
                        f"  served {got_p} {got_s}\n"
                        f"  oracle {rp[qi]} {np.asarray(rs[qi])}")
        self.parity_points += 1
        print(f"  quiesce[{tag}]: parity ok "
              f"({len(METHODS) * self.cfg.n_queries} answers, "
              f"{len(survivors)} survivors)")

    # -- phases ----------------------------------------------------------
    def mixed_phase(self, n_ops: int, p_upsert: float, p_delete: float):
        for _ in range(n_ops):
            r = self.rng.random()
            if r < p_upsert and self.next_upsert < self.cfg.n_docs:
                self.op_upsert()
            elif r < p_upsert + p_delete:
                self.op_delete()
            else:
                self.op_query(int(self.rng.integers(self.cfg.n_queries)))

    def compact_under_load(self, n_threads: int = 3):
        """Background TCP query threads across the compaction swap —
        every reply must succeed and match the pre-compaction answer
        (compaction must not change any result)."""
        expect = {}
        for qi in range(self.cfg.n_queries):
            out = self.op_query(qi)
            expect[qi] = (out["pids"], out["scores"])
        errors: list = []
        stop = threading.Event()
        served = [0] * n_threads

        def reader(t):
            rng = np.random.default_rng(1000 + t)
            while not stop.is_set():
                qi = int(rng.integers(self.cfg.n_queries))
                try:
                    out = self.op_query(qi)
                    if (out["pids"], out["scores"]) != expect[qi]:
                        raise AssertionError(
                            f"answer changed across swap q={qi}")
                    served[t] += 1
                except Exception as e:
                    errors.append(e)
                    return

        threads = [threading.Thread(target=reader, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        time.sleep(0.2)                  # readers in flight before swap
        out = self.call({"op": "compact"})
        self.ops["compact"] += 1
        time.sleep(0.2)                  # and after it
        stop.set()
        for t in threads:
            t.join(timeout=60)
        if errors:
            raise errors[0]
        assert sum(served) > 0, "no background queries overlapped the swap"
        print(f"  compacted {out['compacted']} docs under "
              f"{sum(served)} concurrent queries, zero failures")
        return out

    def health(self) -> dict:
        return self.call({"op": "health"})["health"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI tier: shorter trace, fewer quiesce points")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(REPO / "results/churn_ci.json"))
    args = ap.parse_args()

    t0 = time.time()
    soak = Soak(args.quick, args.seed)
    rounds = 1 if args.quick else 2
    with tempfile.TemporaryDirectory(prefix="churn_") as tmp:
        soak.build(pathlib.Path(tmp))
        try:
            h = soak.health()
            assert h["live"]["tombstones"] == 0, h["live"]
            print(f"serving 2-shard process group on :{soak.port} "
                  f"({soak.base_n} base docs, {soak.hold} upsert pool)")

            per_round = soak.hold // (2 * rounds)
            for r in range(rounds):
                # upsert-heavy churn, then quiesce
                soak.mixed_phase(8 * per_round, p_upsert=0.3, p_delete=0.1)
                soak.quiesce_check(f"r{r}-churn")
                # delete-heavy churn (hits base and delta docs)
                soak.mixed_phase(4 * per_round, p_upsert=0.05,
                                 p_delete=0.35)
                soak.quiesce_check(f"r{r}-deletes")
                # compaction swap under concurrent traffic
                soak.compact_under_load()
                soak.quiesce_check(f"r{r}-compacted")
                h = soak.health()
                live = h["live"]
                assert live["delta_docs"] == 0, live
                assert live["compactions"] == r + 1, live
                assert h["index_generation"] > 0
                assert h["failed"] == 0, h

            # post-compaction mutations still hold parity
            soak.mixed_phase(10, p_upsert=0.4, p_delete=0.2)
            soak.quiesce_check("post-compact-churn")
            h = soak.health()
            assert h["failed"] == 0 and soak.failures == 0
        finally:
            soak.server.shutdown_gracefully()
            soak.engine.close()

    report = {
        "quick": args.quick, "seed": args.seed,
        "elapsed_s": round(time.time() - t0, 2),
        "ops": soak.ops, "parity_points": soak.parity_points,
        "tombstones": len(soak.tombstoned),
        "upserted": soak.next_upsert - soak.base_n,
        "failed_requests": soak.failures,
        "final_live": h.get("live"),
        "index_generation": h.get("index_generation"),
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    print(f"churn soak OK: {soak.ops} → {out}")


if __name__ == "__main__":
    main()
