"""Dev scratch: end-to-end retrieval — synth corpus → index → 4 systems →
quality ordering + mmap accounting."""
import tempfile

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.store import rss_bytes
from repro.data.synth import SynthCfg, make_corpus
from repro.eval import metrics
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.splade_index import build_splade_index

cfg = SynthCfg(n_docs=1500, n_queries=120, seed=0)
corpus = make_corpus(cfg)

tmp = tempfile.mkdtemp()
build_colbert_index(tmp, corpus["doc_embs"], corpus["doc_lens"], nbits=4,
                    n_centroids=256, kmeans_iters=6)
index = ColBERTIndex(tmp, mode="mmap")
print("index: tokens", index.store.n_tokens, "centroids", index.n_centroids,
      "bytes", index.store.total_bytes())

sidx = build_splade_index(corpus["doc_term_ids"], corpus["doc_term_weights"],
                          cfg.vocab, cfg.n_docs)
searcher = PLAIDSearcher(index, PlaidParams(nprobe=4, candidate_cap=1024,
                                            ndocs=256, k=100))
retr = MultiStageRetriever(sidx, searcher,
                           MultiStageParams(first_k=200, k=100, alpha=0.3))

methods = ["colbert", "splade", "rerank", "hybrid"]
ranked = {m: [] for m in methods}
index.store.stats.reset()
for qi in range(cfg.n_queries):
    for m in methods:
        pids, scores = retr.search(
            m, q_emb=corpus["q_embs"][qi],
            term_ids=corpus["q_term_ids"][qi],
            term_weights=corpus["q_term_weights"][qi])
        ranked[m].append(pids)

qrels = corpus["qrels"]
for m in methods:
    r = np.stack(ranked[m])
    print(f"{m:8s} MRR@10={metrics.mrr_at_k(r, qrels, 10):.4f} "
          f"R@5={metrics.recall_at_k(r, qrels, 5):.4f} "
          f"R@50={metrics.recall_at_k(r, qrels, 50):.4f} "
          f"S@5={metrics.success_at_k(r, qrels, 5):.4f}")

print("store pages touched:", index.store.stats.pages_touched,
      "unique:", len(index.store.stats.unique_pages),
      "resident frac:", f"{index.store.resident_fraction_estimate():.3f}")
print("rss MB:", rss_bytes() / 1e6)

# alpha sweep shape
for alpha in [0.0, 0.3, 0.6, 1.0]:
    rr = []
    for qi in range(60):
        pids, _ = retr.search("hybrid", q_emb=corpus["q_embs"][qi],
                              term_ids=corpus["q_term_ids"][qi],
                              term_weights=corpus["q_term_weights"][qi],
                              alpha=alpha)
        rr.append(pids)
    print(f"alpha={alpha}: MRR@10={metrics.mrr_at_k(np.stack(rr), qrels[:60], 10):.4f}")
print("OK")
