"""Composable decoder-only LM with scanned layer segments.

A model is a list of *segments*; each segment is ``n_layers`` structurally
identical blocks whose params are stacked on a leading axis and executed
with ``jax.lax.scan`` (compact HLO — essential for 61-layer dry-runs).
Segments let us express e.g. DeepSeek-V3 (3 dense layers then 58 MoE
layers) or Llama-4 (dense/MoE interleave, expressed as 24 scans of a
[dense, moe] pair) without breaking scan homogeneity.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One transformer block: attention + FFN (dense or MoE)."""
    attn_kind: str = "gqa"                      # gqa | mla
    ffn_kind: str = "dense"                     # dense | moe
    attn: Optional[L.AttnCfg] = None
    mla: Optional[L.MLACfg] = None
    d_ff: int = 0
    moe: Optional[L.MoECfg] = None


@dataclasses.dataclass(frozen=True)
class LMCfg:
    name: str
    d_model: int
    vocab: int
    # segments: sequence of (BlockCfg-tuple, n_repeats). Each repeat scans
    # the tuple of blocks once, so interleaved patterns stay scannable.
    segments: Sequence[tuple[tuple[BlockCfg, ...], int]] = ()
    tie_embeddings: bool = False
    use_mtp: bool = False                       # DeepSeek-V3 multi-token prediction
    remat: str = "full"                         # none | full | dots
    attn_chunk: int = 1024
    use_blockwise_attn: bool = True
    dtype: Any = jnp.bfloat16
    seq_shard_axis: Optional[str] = None        # sequence-parallel residual stream
    logits_softcap: float = 0.0
    decode_opt: bool = False                    # window-slice + split-S decode
    decode_score_spec: Any = None               # P for (B,H,1,S) scores
    # train/prefill activation-sharding controls (the hillclimbed path):
    batch_spec: Any = None                      # P entry for the batch dim
    sharded_ce: bool = False                    # vocab-sharded CE loss
    remat_attn_chunks: bool = False             # flash-style chunk bwd
    moe_dp_slices: int = 0                      # data-local MoE dispatch

    @property
    def n_layers(self) -> int:
        return sum(len(blocks) * n for blocks, n in self.segments)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _block_init(key, cfg: LMCfg, bcfg: BlockCfg):
    ks = PRNGSeq(key)
    p: dict[str, Any] = {
        "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if bcfg.attn_kind == "gqa":
        p["attn"] = L.gqa_init(next(ks), bcfg.attn, cfg.dtype)
    elif bcfg.attn_kind == "mla":
        p["attn"] = L.mla_init(next(ks), bcfg.mla, cfg.dtype)
    else:
        raise ValueError(bcfg.attn_kind)
    if bcfg.ffn_kind == "dense":
        p["ffn"] = L.ffn_init(next(ks), cfg.d_model, bcfg.d_ff, cfg.dtype)
    elif bcfg.ffn_kind == "moe":
        p["ffn"] = L.moe_init(next(ks), bcfg.moe, cfg.dtype)
    else:
        raise ValueError(bcfg.ffn_kind)
    return p


def _seq_shard(cfg: LMCfg, x):
    from jax.sharding import PartitionSpec as P
    if cfg.seq_shard_axis is not None:
        x = jax.lax.with_sharding_constraint(
            x, P(cfg.batch_spec or "data", cfg.seq_shard_axis, None))
    elif cfg.batch_spec is not None:
        # keep the residual stream batch-sharded: without this GSPMD may
        # batch-replicate activations around attention (the baseline
        # pathology measured in EXPERIMENTS.md §Perf)
        x = jax.lax.with_sharding_constraint(x, P(cfg.batch_spec, None, None))
    return x


def _score_spec(cfg: LMCfg):
    if cfg.batch_spec is None:
        return None
    from jax.sharding import PartitionSpec as P
    return P(cfg.batch_spec, "model", None, None)   # (B, H, Lq, chunk)


def _block_apply(params, cfg: LMCfg, bcfg: BlockCfg, x, positions, *,
                 ep_axis: Optional[str] = None,
                 dp_axis: Optional[str] = None):
    h = L.rmsnorm_apply(params["ln_attn"], x)
    if bcfg.attn_kind == "gqa":
        a = L.gqa_apply(params["attn"], bcfg.attn, h, positions,
                        causal=True, chunk=cfg.attn_chunk,
                        use_blockwise=cfg.use_blockwise_attn,
                        score_spec=_score_spec(cfg),
                        remat_chunks=cfg.remat_attn_chunks)
    else:
        a = L.mla_apply(params["attn"], bcfg.mla, h, positions,
                        causal=True, chunk=cfg.attn_chunk,
                        use_blockwise=cfg.use_blockwise_attn,
                        score_spec=_score_spec(cfg),
                        remat_chunks=cfg.remat_attn_chunks)
    x = _seq_shard(cfg, x + a)
    h = L.rmsnorm_apply(params["ln_ffn"], x)
    aux = jnp.zeros((), jnp.float32)
    if bcfg.ffn_kind == "dense":
        f = L.ffn_apply(params["ffn"], h)
    else:
        f, moe_aux = L.moe_apply(params["ffn"], bcfg.moe, h, ep_axis=ep_axis,
                                 dp_axis=dp_axis,
                                 dp_slices=cfg.moe_dp_slices)
        aux = moe_aux["aux_loss"]
    x = _seq_shard(cfg, x + f)
    return x, aux


def _block_decode_apply(params, cfg: LMCfg, bcfg: BlockCfg, x, positions,
                        cache, cache_positions):
    h = L.rmsnorm_apply(params["ln_attn"], x)
    if bcfg.attn_kind == "gqa":
        a, new_cache, new_pos = L.gqa_decode_apply(
            params["attn"], bcfg.attn, h, positions, cache, cache_positions,
            opt=cfg.decode_opt, score_spec=cfg.decode_score_spec)
    else:
        a, new_cache, new_pos = L.mla_decode_apply(
            params["attn"], bcfg.mla, h, positions, cache, cache_positions)
    x = x + a
    h = L.rmsnorm_apply(params["ln_ffn"], x)
    if bcfg.ffn_kind == "dense":
        f = L.ffn_apply(params["ffn"], h)
    else:
        # sharding constraints only when a mesh context is implied
        dist = cfg.decode_opt and cfg.decode_score_spec is not None
        f, _ = L.moe_apply(params["ffn"], bcfg.moe, h,
                           ep_axis="model" if dist else None,
                           dp_axis="data" if dist else None)
    return x + f, new_cache, new_pos


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init(key, cfg: LMCfg):
    ks = PRNGSeq(key)
    params: dict[str, Any] = {
        "embed": L.embed_init(next(ks), cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(next(ks), cfg.d_model, cfg.vocab, cfg.dtype)
    for si, (blocks, n) in enumerate(cfg.segments):
        seg = {}
        for bi, bcfg in enumerate(blocks):
            layer_keys = jnp.stack(ks.take(n))
            seg[f"block{bi}"] = jax.vmap(
                lambda k, _cfg=bcfg: _block_init(k, cfg, _cfg))(layer_keys)
        params[f"seg{si}"] = seg
    if cfg.use_mtp:
        mtp_block = cfg.segments[-1][0][-1]
        params["mtp"] = {
            "norm_h": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "norm_e": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "proj": L.dense_init(next(ks), 2 * cfg.d_model, cfg.d_model, cfg.dtype),
            "block": _block_init(next(ks), cfg, mtp_block),
        }
    return params


def abstract_init(cfg: LMCfg):
    """Param tree as ShapeDtypeStructs (no allocation) — for the dry-run."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg: LMCfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, cfg: LMCfg, tokens, *, ep_axis: Optional[str] = None,
            dp_axis: Optional[str] = None):
    """tokens: (B, L) int32 → (hidden (B, L, D), aux_loss scalar)."""
    B, Lseq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Lseq, dtype=jnp.int32)[None], (B, Lseq))
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = _seq_shard(cfg, x)
    aux_total = jnp.zeros((), jnp.float32)

    for si, (blocks, n) in enumerate(cfg.segments):
        seg = params[f"seg{si}"]

        def one_repeat(x, layer_params, _blocks=blocks):
            aux = jnp.zeros((), jnp.float32)
            for bi, bcfg in enumerate(_blocks):
                x, a = _block_apply(layer_params[f"block{bi}"], cfg, bcfg, x,
                                    positions, ep_axis=ep_axis,
                                    dp_axis=dp_axis)
                aux = aux + a
            return x, aux

        body = _remat_wrap(one_repeat, cfg)
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, seg)
        aux_total = aux_total + jnp.sum(auxs)

    x = L.rmsnorm_apply(params["final_norm"], x)
    return x, aux_total


def logits_from_hidden(params, cfg: LMCfg, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bld,dv->blv", hidden, head.astype(cfg.dtype))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def _ce_nll(cfg: LMCfg, logits, labels):
    """Per-token NLL. The sharded path keeps the vocab axis partitioned:
    `take_along_axis` over a sharded vocab makes GSPMD all-gather the
    fp32 logits (measured: 40+ GB/device on the 151k-vocab models);
    the one-hot-fused form reduces locally and psums scalars instead."""
    safe = jnp.maximum(labels, 0)
    if not cfg.sharded_ce:
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    from jax.sharding import PartitionSpec as P
    logits = jax.lax.with_sharding_constraint(
        logits, P(cfg.batch_spec, None, "model"))
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (safe[..., None]
              == jnp.arange(logits.shape[-1], dtype=labels.dtype))
    logit_at = jnp.sum(logits * onehot.astype(logits.dtype), axis=-1)
    return lse - logit_at


def lm_loss(params, cfg: LMCfg, tokens, labels, *, ep_axis=None,
            dp_axis=None, mtp_weight: float = 0.3):
    """Cross-entropy (+ MoE aux + optional MTP). labels −100 are masked."""
    hidden, aux = forward(params, cfg, tokens, ep_axis=ep_axis,
                          dp_axis=dp_axis)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    nll = _ce_nll(cfg, logits, labels)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    metrics = {"ce_loss": loss, "aux_loss": aux}

    if cfg.use_mtp:
        # DeepSeek-V3 MTP (depth 1): combine hidden_t with emb(token_{t+1}),
        # run one extra block, predict token_{t+2}.
        mtp = params["mtp"]
        B, Lseq = tokens.shape
        emb_next = jnp.take(params["embed"], jnp.roll(tokens, -1, axis=1),
                            axis=0).astype(cfg.dtype)
        h = jnp.concatenate(
            [L.rmsnorm_apply(mtp["norm_h"], hidden),
             L.rmsnorm_apply(mtp["norm_e"], emb_next)], axis=-1)
        h = jnp.einsum("blk,kd->bld", h, mtp["proj"])
        positions = jnp.broadcast_to(jnp.arange(Lseq, dtype=jnp.int32)[None],
                                     (B, Lseq))
        mtp_block = cfg.segments[-1][0][-1]
        h, _ = _block_apply(mtp["block"], cfg, mtp_block, h, positions,
                            ep_axis=ep_axis)
        mtp_logits = logits_from_hidden(params, cfg, h).astype(jnp.float32)
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mtp_mask = mask * (jnp.arange(Lseq) < Lseq - 1)[None, :]
        nll2 = _ce_nll(cfg, mtp_logits, mtp_labels)
        mtp_loss = jnp.sum(nll2 * mtp_mask) / jnp.maximum(jnp.sum(mtp_mask), 1.0)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + mtp_weight * mtp_loss

    loss = loss + 0.001 * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill & decode
# ---------------------------------------------------------------------------

def init_cache(cfg: LMCfg, batch: int, max_len: int, dtype=None):
    """Per-segment stacked KV caches (layer-major, scan-compatible)."""
    dtype = dtype or cfg.dtype
    caches = {}
    for si, (blocks, n) in enumerate(cfg.segments):
        seg = {}
        for bi, bcfg in enumerate(blocks):
            if bcfg.attn_kind == "gqa":
                K, h = bcfg.attn.kv_heads, bcfg.attn.head_dim
                seg[f"block{bi}"] = {
                    "k": jnp.zeros((n, batch, max_len, K, h), dtype),
                    "v": jnp.zeros((n, batch, max_len, K, h), dtype),
                }
            else:
                seg[f"block{bi}"] = {
                    "c_kv": jnp.zeros((n, batch, max_len, bcfg.mla.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((n, batch, max_len, bcfg.mla.qk_rope_head_dim), dtype),
                }
        caches[f"seg{si}"] = seg
    caches["positions"] = jnp.full((batch, max_len), -1, jnp.int32)
    return caches


def abstract_cache(cfg: LMCfg, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def prefill(params, cfg: LMCfg, tokens):
    """Full-sequence forward for serving; returns last-position logits.

    (The KV cache fill for subsequent decode reuses ``forward``'s
    projections; for the dry-run cells the lowered computation is the
    full prefill forward + logits, which dominates cost.)
    """
    hidden, _ = forward(params, cfg, tokens)
    logits = logits_from_hidden(params, cfg, hidden[:, -1:, :])
    return logits


def decode_step(params, cfg: LMCfg, token, pos, caches):
    """One decode step. token: (B, 1) int32; pos: (B, 1) int32 absolute
    position; caches from ``init_cache``. Returns (logits, new_caches)."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    cache_positions = caches["positions"]
    new_caches = {"positions": cache_positions}

    for si, (blocks, n) in enumerate(cfg.segments):
        seg_params = params[f"seg{si}"]
        seg_cache = caches[f"seg{si}"]

        def body(carry, xs, _blocks=blocks):
            x, cache_pos = carry
            layer_params, layer_cache = xs
            new_layer_cache = {}
            for bi, bcfg in enumerate(_blocks):
                x, c, cache_pos = _block_decode_apply(
                    layer_params[f"block{bi}"], cfg, bcfg, x, pos,
                    layer_cache[f"block{bi}"], cache_pos)
                new_layer_cache[f"block{bi}"] = c
            return (x, cache_pos), new_layer_cache

        (x, cache_positions), new_seg = jax.lax.scan(
            body, (x, cache_positions), (seg_params, seg_cache))
        new_caches[f"seg{si}"] = new_seg

    new_caches["positions"] = cache_positions
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = logits_from_hidden(params, cfg, x)
    return logits, new_caches
