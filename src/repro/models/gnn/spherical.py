"""Real spherical harmonics (l ≤ 2) and exact Gaunt coupling tensors.

The closed-form real SH basis (9 components for l_max=2) feeds MACE's
density expansion; the Gaunt tensor G[a,b,c] = ∫ Y_a Y_b Y_c dΩ is the
real-basis product rule used to build higher-correlation equivariant
features. It is computed *exactly* at module-init time with a
Gauss-Legendre × trapezoid quadrature (integrands are polynomials of
degree ≤ 6 on the sphere, well inside the rule's exactness).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# lm index layout: [ (0,0), (1,-1), (1,0), (1,1), (2,-2), (2,-1), (2,0), (2,1), (2,2) ]
N_LM = 9
L_OF = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])


def real_sh_l2_np(xyz: np.ndarray) -> np.ndarray:
    """xyz: (..., 3) unit vectors → (..., 9) real SH values."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    return np.stack([
        np.full_like(x, c0),
        c1 * y, c1 * z, c1 * x,
        0.5 * np.sqrt(15 / np.pi) * x * y,
        0.5 * np.sqrt(15 / np.pi) * y * z,
        0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1),
        0.5 * np.sqrt(15 / np.pi) * x * z,
        0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
    ], axis=-1)


def real_sh_l2(xyz):
    """jnp twin of :func:`real_sh_l2_np`."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    return jnp.stack([
        jnp.full_like(x, c0),
        c1 * y, c1 * z, c1 * x,
        0.5 * np.sqrt(15 / np.pi) * x * y,
        0.5 * np.sqrt(15 / np.pi) * y * z,
        0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1),
        0.5 * np.sqrt(15 / np.pi) * x * z,
        0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
    ], axis=-1)


@functools.lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[a, b, c] = ∫_{S²} Y_a Y_b Y_c dΩ, exact for l ≤ 2."""
    n_theta, n_phi = 16, 32
    nodes, wts = np.polynomial.legendre.leggauss(n_theta)  # cosθ ∈ [-1,1]
    phi = (np.arange(n_phi) + 0.5) * (2 * np.pi / n_phi)
    w_phi = 2 * np.pi / n_phi

    ct = nodes[:, None]
    st = np.sqrt(1 - ct ** 2)
    x = st * np.cos(phi)[None, :]
    y = st * np.sin(phi)[None, :]
    z = np.broadcast_to(ct, x.shape)
    Y = real_sh_l2_np(np.stack([x, y, z], axis=-1))       # (T, P, 9)
    w = (wts[:, None] * w_phi)                             # (T, P)
    G = np.einsum("tp,tpa,tpb,tpc->abc", w, Y, Y, Y)
    G[np.abs(G) < 1e-12] = 0.0
    return G


def couple(a, b, gaunt):
    """Equivariant product: (..., 9) × (..., 9) → (..., 9) via Gaunt.
    c_k = Σ_ij G[i, j, k] a_i b_j — the real-SH function product rule."""
    return jnp.einsum("...i,...j,ijk->...k", a, b, gaunt)
