"""MACE (Batatia et al., arXiv:2206.07697): higher-order equivariant
message passing, adapted for JAX/TPU.

Structure per layer (l_max = 2, correlation ν = 3):

  1. density expansion   A_i = Σ_{j∈N(i)} R(r_ij) ⊙ Y(r̂_ij) ⊗ (W h_j)
     — radial Bessel basis → per-(channel, l) weights; segment_sum over
     the edge list is the scatter primitive (no sparse formats needed).
  2. product basis       B_i^{(ν)} = couple(B^{(ν−1)}, A) for ν = 2, 3
     — equivariant products through the exact real-SH Gaunt tensor
     (the TPU-friendly stand-in for path-resolved CG contractions; see
     DESIGN.md §hardware-adaptation).
  3. update              h′ = W₀ A + Σ_ν W_ν B^{(ν)} + residual.

Readouts: invariant (l=0) channels → MLP → per-node energy / class
logits; graph-level tasks segment_sum over a graph-id vector.

Citation-graph shapes (cora / ogbn-products) have no 3-D geometry; the
assignment still pairs them with MACE, so nodes get synthetic unit
positions and features enter through the initial channel embedding —
recorded in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import PRNGSeq
from repro.models import layers as L
from repro.models.gnn import spherical as sph


@dataclasses.dataclass(frozen=True)
class MACECfg:
    n_layers: int = 2
    d_hidden: int = 128          # channels K
    l_max: int = 2               # fixed at 2 (N_LM = 9)
    correlation: int = 3         # product-basis order ν
    n_rbf: int = 8
    r_cut: float = 2.0
    d_in: int = 16               # input node-feature dim
    n_out: int = 1               # energy (1) or class count
    readout: str = "node"        # node | graph
    dtype: Any = jnp.float32


def bessel_rbf(r, n_rbf: int, r_cut: float):
    """Radial Bessel basis with polynomial envelope (DimeNet-style)."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * r[..., None] / r_cut) / r[..., None]
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * u ** 3 + 15.0 * u ** 4 - 6.0 * u ** 5
    return rb * env[..., None]


def init(key, cfg: MACECfg):
    ks = PRNGSeq(key)
    K = cfg.d_hidden
    layers = []
    for _ in range(cfg.n_layers):
        lp = {
            # radial MLP: n_rbf → K·3 per-(channel, l) weights
            "radial_w1": L.dense_init(next(ks), cfg.n_rbf, 64, cfg.dtype),
            "radial_w2": L.dense_init(next(ks), 64, K * 3, cfg.dtype),
            "w_msg": L.dense_init(next(ks), K, K, cfg.dtype),
            "w_a": L.dense_init(next(ks), K, K, cfg.dtype),
            "w_b2": L.dense_init(next(ks), K, K, cfg.dtype),
            "w_b3": L.dense_init(next(ks), K, K, cfg.dtype),
        }
        layers.append(lp)
    return {
        "embed_in": L.dense_init(next(ks), cfg.d_in, K, cfg.dtype),
        "layers": layers,           # list — layer count is tiny (2)
        "ro_w1": L.dense_init(next(ks), K, K, cfg.dtype),
        "ro_w2": L.dense_init(next(ks), K, cfg.n_out, cfg.dtype),
    }


def _layer_apply(lp, cfg: MACECfg, h, pos, senders, receivers, gaunt,
                 n_nodes: int):
    """h: (N, K, 9) equivariant node features."""
    K = cfg.d_hidden
    # --- edge geometry -------------------------------------------------
    dr = pos[receivers] - pos[senders]                  # (E, 3)
    dist = jnp.linalg.norm(dr, axis=-1)
    rhat = dr / jnp.maximum(dist[..., None], 1e-9)
    Y = sph.real_sh_l2(rhat)                            # (E, 9)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.r_cut)        # (E, n_rbf)
    Rw = jax.nn.silu(rbf @ lp["radial_w1"]) @ lp["radial_w2"]
    Rw = Rw.reshape(-1, K, 3)                           # (E, K, l)
    Rlm = Rw[:, :, sph.L_OF]                            # (E, K, 9)

    # --- density expansion: A_i = Σ_j R ⊙ (h_j ⊗ Y) ----------------------
    # degenerate (zero-length / self) edges are masked: Y at the zero
    # vector is not a point on the sphere and would break equivariance
    edge_ok = (dist > 1e-6).astype(h.dtype)[:, None, None]
    hj = jnp.einsum("nkc,kq->nqc", h, lp["w_msg"])      # channel mix
    Yb = jnp.broadcast_to(Y[:, None, :], (Y.shape[0], K, sph.N_LM))
    msg = edge_ok * Rlm * sph.couple(hj[senders], Yb, gaunt)
    A = jax.ops.segment_sum(msg, receivers, num_segments=n_nodes)  # (N, K, 9)

    # --- higher-order product basis (correlation ν ≤ 3) ------------------
    B2 = sph.couple(A, A, gaunt)
    out = jnp.einsum("nkc,kq->nqc", A, lp["w_a"]) + \
        jnp.einsum("nkc,kq->nqc", B2, lp["w_b2"])
    if cfg.correlation >= 3:
        B3 = sph.couple(B2, A, gaunt)
        out = out + jnp.einsum("nkc,kq->nqc", B3, lp["w_b3"])
    return h + out / np.sqrt(9.0)


def forward(params, cfg: MACECfg, feats, pos, senders, receivers,
            graph_ids: Optional[jnp.ndarray] = None,
            n_graphs: int = 1):
    """feats: (N, d_in); pos: (N, 3); senders/receivers: (E,) int32.
    Returns per-node (N, n_out) or per-graph (n_graphs, n_out)."""
    n_nodes = feats.shape[0]
    gaunt = jnp.asarray(sph.gaunt_tensor(), cfg.dtype)
    K = cfg.d_hidden
    h0 = feats @ params["embed_in"]                       # (N, K)
    h = jnp.zeros((n_nodes, K, sph.N_LM), cfg.dtype)
    h = h.at[:, :, 0].set(h0)                             # scalars only at t=0

    for lp in params["layers"]:
        h = _layer_apply(lp, cfg, h, pos, senders, receivers, gaunt, n_nodes)

    inv = h[:, :, 0]                                      # invariant channels
    z = jax.nn.silu(inv @ params["ro_w1"]) @ params["ro_w2"]
    if cfg.readout == "graph":
        gid = graph_ids if graph_ids is not None else jnp.zeros(
            (n_nodes,), jnp.int32)
        return jax.ops.segment_sum(z, gid, num_segments=n_graphs)
    return z


def loss_fn(params, cfg: MACECfg, batch):
    """Node classification (citation graphs) or graph regression
    (molecules), selected by cfg.readout."""
    out = forward(params, cfg, batch["feats"], batch["pos"],
                  batch["senders"], batch["receivers"],
                  batch.get("graph_ids"), batch.get("n_graphs", 1))
    if cfg.readout == "graph":
        err = out[:, 0] - batch["targets"]
        return jnp.mean(jnp.square(err)), {"mse": jnp.mean(jnp.square(err))}
    labels = batch["labels"]
    mask = batch.get("label_mask", jnp.ones_like(labels, jnp.float32))
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"nll": loss}
