"""Layer-wise neighbour sampling (GraphSAGE-style) for minibatch GNN
training on large graphs — the host-side data pipeline feeding the
``minibatch_lg`` shape.

The graph is held as CSR (indptr/indices). ``sample_subgraph`` draws a
seed batch and fans out ``fanouts[i]`` neighbours per hop, returning a
*fixed-shape* padded subgraph (node ids, edge list in local ids, valid
masks) so the jitted train step never recompiles.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,) int64
    indices: np.ndarray     # (nnz,) int32
    n_nodes: int

    def degree(self, u) -> int:
        return int(self.indptr[u + 1] - self.indptr[u])

    def neighbors(self, u) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def random_graph(rng: np.random.Generator, n_nodes: int, avg_degree: int,
                 power: float = 1.2) -> CSRGraph:
    """Power-law-ish random graph in CSR (for tests/benchmarks)."""
    deg = np.minimum(
        rng.zipf(power, n_nodes) + avg_degree // 2, 10 * avg_degree)
    total = int(deg.sum())
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, total).astype(np.int32)
    return CSRGraph(indptr=indptr, indices=indices, n_nodes=n_nodes)


def sample_subgraph(graph: CSRGraph, seeds: np.ndarray,
                    fanouts: tuple[int, ...], rng: np.random.Generator,
                    *, max_nodes: int, max_edges: int):
    """Fan-out sampling. Returns fixed shapes:
       node_ids (max_nodes,) int32 (−1 pad) — position 0..n_seed-1 = seeds
       senders/receivers (max_edges,) int32 local ids (edge i: sender →
       receiver, receiver is the aggregation target; −1 pad)
       n_nodes, n_edges actual counts.
    """
    node_ids = list(seeds.astype(np.int64))
    local = {int(u): i for i, u in enumerate(seeds)}
    edges = []
    frontier = list(seeds.astype(np.int64))
    for f in fanouts:
        nxt = []
        for u in frontier:
            nbrs = graph.neighbors(int(u))
            if len(nbrs) == 0:
                continue
            pick = rng.choice(nbrs, size=min(f, len(nbrs)), replace=False)
            for v in pick:
                v = int(v)
                if v not in local:
                    if len(node_ids) >= max_nodes:
                        continue
                    local[v] = len(node_ids)
                    node_ids.append(v)
                    nxt.append(v)
                if len(edges) < max_edges:
                    edges.append((local[v], local[int(u)]))   # v → u
        frontier = nxt

    out_nodes = np.full(max_nodes, -1, np.int32)
    out_nodes[:len(node_ids)] = np.asarray(node_ids, np.int32)
    snd = np.full(max_edges, -1, np.int32)
    rcv = np.full(max_edges, -1, np.int32)
    if edges:
        e = np.asarray(edges, np.int32)
        snd[:len(e)] = e[:, 0]
        rcv[:len(e)] = e[:, 1]
    return {"node_ids": out_nodes, "senders": snd, "receivers": rcv,
            "n_nodes": len(node_ids), "n_edges": len(edges)}
