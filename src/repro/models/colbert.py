"""ColBERT encoder: contextualised late-interaction embeddings.

Query side: prepend [Q] marker, pad to ``query_maxlen`` with [MASK]
tokens (query augmentation, per Khattab & Zaharia 2020) — mask tokens
*do* attend and produce embeddings used in MaxSim.
Doc side: prepend [D] marker; padding is masked out of scoring.
Both sides project to ``dim`` (default 128) and L2-normalise.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import encoder as E
from repro.models import layers as L

MASK_TOKEN = 3
Q_MARKER = 1
D_MARKER = 2


@dataclasses.dataclass(frozen=True)
class ColBERTCfg:
    encoder: E.EncoderCfg
    dim: int = 128
    query_maxlen: int = 32
    doc_maxlen: int = 180


def init(key, cfg: ColBERTCfg):
    ks = PRNGSeq(key)
    return {
        "encoder": E.init(next(ks), cfg.encoder),
        "proj": L.dense_init(next(ks), cfg.encoder.d_model, cfg.dim),
    }


def _encode(params, cfg: ColBERTCfg, tokens, mask):
    h = E.apply(params["encoder"], cfg.encoder, tokens, mask)
    emb = jnp.einsum("bld,dk->blk", h, params["proj"].astype(h.dtype))
    norm = jnp.linalg.norm(emb.astype(jnp.float32), axis=-1, keepdims=True)
    return (emb.astype(jnp.float32) / jnp.maximum(norm, 1e-9)).astype(emb.dtype)


def encode_queries(params, cfg: ColBERTCfg, tokens, lengths):
    """tokens: (B, query_maxlen) int32 (unpadded content), lengths: (B,).

    Applies the [Q] marker and MASK augmentation: every slot beyond the
    real query tokens becomes [MASK] and *participates* in scoring.
    Returns (B, query_maxlen, dim) embeddings; all positions are valid.
    """
    B, Lq = tokens.shape
    pos = jnp.arange(Lq)[None]
    toks = jnp.where(pos < lengths[:, None], tokens, MASK_TOKEN)
    toks = jnp.concatenate(
        [jnp.full((B, 1), Q_MARKER, tokens.dtype), toks[:, :-1]], axis=1)
    mask = jnp.ones_like(toks, dtype=bool)
    return _encode(params, cfg, toks, mask)


def encode_docs(params, cfg: ColBERTCfg, tokens, lengths):
    """tokens: (B, doc_maxlen) int32, lengths: (B,).

    Returns (emb (B, doc_maxlen, dim), valid (B, doc_maxlen) bool)."""
    B, Ld = tokens.shape
    pos = jnp.arange(Ld)[None]
    valid = pos < lengths[:, None]
    toks = jnp.concatenate(
        [jnp.full((B, 1), D_MARKER, tokens.dtype), tokens[:, :-1]], axis=1)
    valid = jnp.concatenate([jnp.ones((B, 1), bool), valid[:, :-1]], axis=1)
    emb = _encode(params, cfg, toks, valid)
    emb = emb * valid[..., None].astype(emb.dtype)
    return emb, valid


def maxsim(q_emb, d_emb, d_valid):
    """Late-interaction score. q_emb: (Lq, dim); d_emb: (C, Ld, dim);
    d_valid: (C, Ld) → scores (C,)."""
    s = jnp.einsum("qk,cdk->cqd", q_emb, d_emb, preferred_element_type=jnp.float32)
    s = jnp.where(d_valid[:, None, :], s, -1e30)
    per_q = jnp.max(s, axis=-1)                      # (C, Lq)
    per_q = jnp.where(per_q <= -1e29, 0.0, per_q)    # fully-empty docs
    return jnp.sum(per_q, axis=-1)
