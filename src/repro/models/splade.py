"""SPLADEv2: sparse lexical-and-expansion encoder.

MLM-head logits → log(1 + relu(w)) → max-pool over tokens → a |V|-dim
sparse representation. The efficiency-optimised BT-SPLADE-L of the
paper is expressed here as an asymmetric config: a small query encoder
and a larger doc encoder.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import encoder as E
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class SpladeCfg:
    encoder: E.EncoderCfg
    top_terms: int = 64           # terms kept per representation (serving)


def init(key, cfg: SpladeCfg):
    ks = PRNGSeq(key)
    d = cfg.encoder.d_model
    return {
        "encoder": E.init(next(ks), cfg.encoder),
        "mlm_transform": L.dense_init(next(ks), d, d),
        "mlm_ln": L.layernorm_init(d),
        # decoder ties to the embedding matrix (BERT-style); bias separate
        "mlm_bias": jnp.zeros((cfg.encoder.vocab,), jnp.float32),
    }


def encode(params, cfg: SpladeCfg, tokens, mask):
    """→ dense |V| sparse-activation vector per sequence: (B, V)."""
    h = E.apply(params["encoder"], cfg.encoder, tokens, mask)
    t = jnp.einsum("bld,dk->blk", h, params["mlm_transform"].astype(h.dtype))
    t = jax.nn.gelu(t.astype(jnp.float32))
    t = L.layernorm_apply(params["mlm_ln"], t)
    logits = jnp.einsum("bld,vd->blv", t,
                        params["encoder"]["embed"].astype(t.dtype))
    logits = logits + params["mlm_bias"]
    w = jnp.log1p(jax.nn.relu(logits))
    w = jnp.where(mask[..., None], w, 0.0)
    return jnp.max(w, axis=1)  # (B, V)


def sparsify(vec, top_terms: int):
    """Keep the top-k terms: returns (term_ids (B, k), weights (B, k));
    absent terms have weight 0."""
    w, ids = jax.lax.top_k(vec, top_terms)
    return ids.astype(jnp.int32), w


def flops_reg(vec):
    """FLOPS regulariser (Formal et al.): (mean_b |w_bv|)² summed over V."""
    return jnp.sum(jnp.square(jnp.mean(jnp.abs(vec), axis=0)))
