"""Multi-stage candidate scoring for recsys retrieval — the paper's
architecture transplanted: a cheap first-stage scorer narrows 10⁶
candidates to ``first_k``; the exact (expensive) model rescores only
those; optionally the two scores fuse with the same z-norm hybrid rule.

  stage 1 (SPLADE analogue)  — batched dot of the user state against
                               candidate item embeddings (1 matmul)
  stage 2 (ColBERT analogue) — exact model (full AutoInt interaction /
                               DIEN AUGRU) on the survivors only
  fusion  (Hybrid)           — α·N(dot) + (1−α)·N(exact), z-norm N

This is also what makes `TieredEmbedding` effective: stage 2 touches
``first_k`` rows instead of 10⁶, exactly the access-minimisation that
keeps the mmap'd ColBERT index fast in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import hybrid as hybrid_mod


@dataclasses.dataclass(frozen=True)
class TwoStageParams:
    first_k: int = 200
    k: int = 100
    alpha: float = 0.3
    normalizer: str = "znorm"


def two_stage_retrieve(coarse_scores: jnp.ndarray,
                       exact_fn: Callable[[jnp.ndarray], jnp.ndarray],
                       cand_ids: jnp.ndarray,
                       params: TwoStageParams = TwoStageParams(),
                       *, fuse: bool = True):
    """coarse_scores: (N,) stage-1 scores aligned with cand_ids (N,);
    exact_fn(ids (first_k,)) → (first_k,) exact scores.
    Returns (top_ids (k,), top_scores (k,))."""
    s1, keep = jax.lax.top_k(coarse_scores, params.first_k)
    ids = cand_ids[keep]
    s2 = exact_fn(ids)
    if fuse:
        mask = jnp.ones_like(s1, bool)
        final = hybrid_mod.hybrid_scores(s1, s2, mask, alpha=params.alpha,
                                         normalizer=params.normalizer)
    else:
        final = s2
    top, idx = jax.lax.top_k(final, params.k)
    return ids[idx], top
