"""DIEN (Zhou et al., arXiv:1809.03672): Deep Interest Evolution Network.

Pipeline per sample:
  behaviour sequence (item, cate) embeddings (L, 2·d)
    → interest extractor GRU (hidden = gru_dim)
    → auxiliary loss (hidden_t vs behaviour_{t+1}, sampled negatives)
    → target-conditioned attention scores over hidden states
    → AUGRU (attention-gated update) → final interest state
  concat [user, target, final_interest, Σ hist] → MLP 200-80 (Dice) → logit
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import layers as L
from repro.models.recsys import embedding as EB


@dataclasses.dataclass(frozen=True)
class DIENCfg:
    n_users: int = 1_000_000
    n_items: int = 1_000_000
    n_cates: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple[int, ...] = (200, 80)
    use_aux_loss: bool = True
    aux_weight: float = 1.0

    @property
    def beh_dim(self) -> int:          # behaviour embedding = item ⊕ cate
        return 2 * self.embed_dim


def init(key, cfg: DIENCfg):
    ks = PRNGSeq(key)
    d = cfg.embed_dim
    din = cfg.beh_dim
    g = cfg.gru_dim
    mlp_in = d + 2 * din + g           # user ⊕ target ⊕ Σhist ⊕ interest
    p = {
        "tables": {
            "user": jax.random.normal(next(ks), (cfg.n_users, d)) * 0.01,
            "item": jax.random.normal(next(ks), (cfg.n_items, d)) * 0.01,
            "cate": jax.random.normal(next(ks), (cfg.n_cates, d)) * 0.01,
        },
        "gru1": L.gru_init(next(ks), din, g),
        "gru2": L.gru_init(next(ks), g, g),          # AUGRU
        "att_w": jax.random.normal(next(ks), (g + din, 1)) * 0.05,
        "att_hidden": jax.random.normal(next(ks), (g + din, 36)) * 0.1,
        "att_out": jax.random.normal(next(ks), (36, 1)) * 0.1,
        "mlp": EB.mlp_init(next(ks), [mlp_in, *cfg.mlp_dims, 1]),
        "dice0": EB.dice_init(cfg.mlp_dims[0]),
        "dice1": EB.dice_init(cfg.mlp_dims[1]),
    }
    if cfg.use_aux_loss:
        p["aux_mlp"] = EB.mlp_init(next(ks), [g + din, 64, 1])
    return p


def _behaviour_emb(params, cfg: DIENCfg, items, cates,
                   shard_axis: Optional[str] = None):
    ei = EB.lookup(params["tables"]["item"], items, shard_axis=shard_axis)
    ec = EB.lookup(params["tables"]["cate"], cates)
    return jnp.concatenate([ei, ec], axis=-1)


def _attention_scores(params, hs, target):
    """hs: (B, L, g); target: (B, din) → (B, L, 1) in (0,1)."""
    B, Lh, g = hs.shape
    t = jnp.broadcast_to(target[:, None, :], (B, Lh, target.shape[-1]))
    z = jnp.concatenate([hs, t], axis=-1)
    a = jax.nn.sigmoid(z @ params["att_hidden"])
    return jax.nn.sigmoid(a @ params["att_out"])     # (B, L, 1)


def forward(params, cfg: DIENCfg, batch, *,
            shard_axis: Optional[str] = None, rng=None):
    """batch: user (B,), target_item (B,), target_cate (B,),
    hist_items (B, L), hist_cates (B, L), hist_len (B,) → (logits, aux)."""
    B = batch["user"].shape[0]
    eu = EB.lookup(params["tables"]["user"], batch["user"],
                   shard_axis=shard_axis)
    et = _behaviour_emb(params, cfg, batch["target_item"],
                        batch["target_cate"], shard_axis)
    eh = _behaviour_emb(params, cfg, batch["hist_items"],
                        batch["hist_cates"], shard_axis)   # (B, L, din)
    valid = (jnp.arange(cfg.seq_len)[None, :]
             < batch["hist_len"][:, None])                  # (B, L)
    eh = eh * valid[..., None].astype(eh.dtype)

    h0 = jnp.zeros((B, cfg.gru_dim), eh.dtype)
    hs, _ = L.gru_scan(params["gru1"], eh, h0)              # (B, L, g)
    hs = hs * valid[..., None].astype(hs.dtype)

    aux = jnp.zeros((), jnp.float32)
    if cfg.use_aux_loss:
        # hidden_t should predict behaviour_{t+1}; negatives are the
        # batch-rolled behaviours (cheap sampled negatives).
        h_t = hs[:, :-1]                                    # (B, L-1, g)
        e_pos = eh[:, 1:]
        e_neg = jnp.roll(eh[:, 1:], 1, axis=0)
        m = (valid[:, 1:]).astype(jnp.float32)
        pos_in = jnp.concatenate([h_t, e_pos], axis=-1)
        neg_in = jnp.concatenate([h_t, e_neg], axis=-1)
        lp = EB.mlp_apply(params["aux_mlp"], pos_in)[..., 0]
        ln = EB.mlp_apply(params["aux_mlp"], neg_in)[..., 0]
        aux_raw = (jnp.maximum(lp, 0) - lp + jnp.log1p(jnp.exp(-jnp.abs(lp)))
                   + jnp.maximum(ln, 0) + jnp.log1p(jnp.exp(-jnp.abs(ln))))
        aux = jnp.sum(aux_raw * m) / jnp.maximum(jnp.sum(m), 1.0)

    att = _attention_scores(params, hs, et)                 # (B, L, 1)
    att = att * valid[..., None].astype(att.dtype)
    h0 = jnp.zeros((B, cfg.gru_dim), hs.dtype)
    _, h_final = L.gru_scan(params["gru2"], hs, h0, atts=att[..., 0:1])

    hist_sum = jnp.sum(eh, axis=1)
    z = jnp.concatenate([eu, et, hist_sum, h_final], axis=-1)
    n = len(cfg.mlp_dims)
    x = z
    for i in range(n + 1):
        x = x @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
        if i < n:
            x = EB.dice_apply(params[f"dice{i}"], x)
    return x[:, 0], aux


def loss_fn(params, cfg: DIENCfg, batch, *,
            shard_axis: Optional[str] = None):
    logits, aux = forward(params, cfg, batch, shard_axis=shard_axis)
    bce = EB.bce_loss(logits, batch["label"])
    loss = bce + cfg.aux_weight * aux
    return loss, {"bce": bce, "aux": aux}


def serve_score(params, cfg: DIENCfg, batch, *,
                shard_axis: Optional[str] = None):
    logits, _ = forward(params, cfg, batch, shard_axis=shard_axis)
    return jax.nn.sigmoid(logits)


def retrieval_scores(params, cfg: DIENCfg, query, cand_items, cand_cates,
                     *, shard_axis: Optional[str] = None,
                     chunk: int = 8192):
    """One user vs N candidates, exact DIEN scoring.

    The GRU interest extraction runs ONCE for the user; only the
    target-conditioned attention + AUGRU + MLP rerun per candidate
    (scanned in chunks to bound memory) — the same "compute the
    expensive shared state once" idea as the paper's multi-stage split.
    query: user (,), hist_items (L,), hist_cates (L,), hist_len (,).
    """
    eu = EB.lookup(params["tables"]["user"], query["user"][None],
                   shard_axis=shard_axis)                    # (1, d)
    eh = _behaviour_emb(params, cfg, query["hist_items"][None],
                        query["hist_cates"][None], shard_axis)
    valid = (jnp.arange(cfg.seq_len)[None, :]
             < query["hist_len"][None, None])
    eh = eh * valid[..., None].astype(eh.dtype)
    h0 = jnp.zeros((1, cfg.gru_dim), eh.dtype)
    hs, _ = L.gru_scan(params["gru1"], eh, h0)               # (1, L, g)
    hs = hs * valid[..., None].astype(hs.dtype)
    hist_sum = jnp.sum(eh, axis=1)                           # (1, din)

    N = cand_items.shape[0]
    n_chunks = -(-N // chunk)
    pad = n_chunks * chunk - N
    ci = jnp.pad(cand_items, (0, pad))
    cc = jnp.pad(cand_cates, (0, pad))
    ci = ci.reshape(n_chunks, chunk)
    cc = cc.reshape(n_chunks, chunk)

    def one_chunk(_, ids):
        items, cates = ids
        et = _behaviour_emb(params, cfg, items, cates, shard_axis)  # (C, din)
        C = et.shape[0]
        hsb = jnp.broadcast_to(hs, (C, cfg.seq_len, cfg.gru_dim))
        att = _attention_scores(params, hsb, et)
        att = att * valid[..., None].astype(att.dtype)
        h0c = jnp.zeros((C, cfg.gru_dim), hs.dtype)
        _, h_final = L.gru_scan(params["gru2"], hsb, h0c,
                                atts=att[..., 0:1])
        z = jnp.concatenate([
            jnp.broadcast_to(eu, (C, eu.shape[-1])), et,
            jnp.broadcast_to(hist_sum, (C, hist_sum.shape[-1])),
            h_final], axis=-1)
        n = len(cfg.mlp_dims)
        x = z
        for i in range(n + 1):
            x = x @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
            if i < n:
                x = EB.dice_apply(params[f"dice{i}"], x)
        return None, x[:, 0]

    _, scores = jax.lax.scan(one_chunk, None, (ci, cc))
    return scores.reshape(-1)[:N]
