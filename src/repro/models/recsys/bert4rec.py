"""BERT4Rec (Sun et al., arXiv:1904.06690): bidirectional transformer
over item sequences trained with masked-item (Cloze) prediction.

With 10⁶-item vocabularies the full softmax is replaced by sampled
softmax over ``n_negatives`` shared negatives per batch (logQ-corrected
candidate sampling is unnecessary for uniform negatives at this scale).
Encoder-only: there is no autoregressive decode path — all four recsys
shapes are forward scoring passes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import layers as L
from repro.models.recsys import embedding as EB


@dataclasses.dataclass(frozen=True)
class BERT4RecCfg:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    n_masked: int = 30            # fixed Cloze positions per sample
    n_negatives: int = 256
    d_ff_mult: int = 4

    @property
    def mask_id(self) -> int:     # the [MASK] item id
        return 0

    @property
    def attn(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.embed_dim, n_heads=self.n_heads,
                         kv_heads=self.n_heads,
                         head_dim=self.embed_dim // self.n_heads,
                         use_rope=False)


def init(key, cfg: BERT4RecCfg):
    ks = PRNGSeq(key)

    def block_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln_attn": L.layernorm_init(cfg.embed_dim),
            "ln_ffn": L.layernorm_init(cfg.embed_dim),
            "attn": L.gqa_init(k1, cfg.attn),
            "ffn": {
                "w1": L.dense_init(jax.random.fold_in(k2, 0), cfg.embed_dim,
                                   cfg.d_ff_mult * cfg.embed_dim),
                "b1": jnp.zeros((cfg.d_ff_mult * cfg.embed_dim,)),
                "w2": L.dense_init(jax.random.fold_in(k2, 1),
                                   cfg.d_ff_mult * cfg.embed_dim,
                                   cfg.embed_dim),
                "b2": jnp.zeros((cfg.embed_dim,)),
            },
        }

    block_keys = jnp.stack(ks.take(cfg.n_blocks))
    return {
        "item_embed": jax.random.normal(
            next(ks), (cfg.n_items, cfg.embed_dim)) * 0.02,
        "pos_embed": jax.random.normal(
            next(ks), (cfg.seq_len, cfg.embed_dim)) * 0.02,
        "blocks": jax.vmap(block_init)(block_keys),
        "final_ln": L.layernorm_init(cfg.embed_dim),
        "out_bias": jnp.zeros((cfg.n_items,), jnp.float32),
    }


def encode(params, cfg: BERT4RecCfg, items, valid, *,
           shard_axis: Optional[str] = None):
    """items: (B, L); valid: (B, L) bool → (B, L, d) bidirectional."""
    B, Lh = items.shape
    x = EB.lookup(params["item_embed"], items, shard_axis=shard_axis)
    x = x + params["pos_embed"][None, :Lh]
    pos = jnp.where(valid, jnp.arange(Lh, dtype=jnp.int32)[None], -1)

    def body(x, bp):
        h = L.layernorm_apply(bp["ln_attn"], x)
        a = L.gqa_apply(bp["attn"], cfg.attn, h, pos, causal=False,
                        use_blockwise=False)
        x = x + a
        h = L.layernorm_apply(bp["ln_ffn"], x)
        h = jax.nn.gelu(h @ bp["ffn"]["w1"] + bp["ffn"]["b1"])
        x = x + h @ bp["ffn"]["w2"] + bp["ffn"]["b2"]
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.layernorm_apply(params["final_ln"], x)


def loss_fn(params, cfg: BERT4RecCfg, batch, *,
            shard_axis: Optional[str] = None):
    """batch: items (B, L) with [MASK]=0 at Cloze slots, valid (B, L),
    mask_positions (B, M) int32, mask_labels (B, M) int32,
    negatives (n_negatives,) int32 (shared across the batch)."""
    h = encode(params, cfg, batch["items"], batch["valid"],
               shard_axis=shard_axis)
    M = batch["mask_positions"].shape[1]
    hm = jnp.take_along_axis(
        h, batch["mask_positions"][..., None].repeat(cfg.embed_dim, -1),
        axis=1)                                           # (B, M, d)

    e_pos = EB.lookup(params["item_embed"], batch["mask_labels"],
                      shard_axis=shard_axis)              # (B, M, d)
    e_neg = EB.lookup(params["item_embed"], batch["negatives"],
                      shard_axis=shard_axis)              # (N, d)
    b_pos = params["out_bias"][batch["mask_labels"]]
    b_neg = params["out_bias"][batch["negatives"]]

    s_pos = jnp.sum(hm * e_pos, axis=-1) + b_pos          # (B, M)
    s_neg = jnp.einsum("bmd,nd->bmn", hm, e_neg) + b_neg  # (B, M, N)
    # sampled softmax: positive vs negatives
    logits = jnp.concatenate([s_pos[..., None], s_neg], axis=-1)
    logits = logits.astype(jnp.float32)
    nll = -jax.nn.log_softmax(logits, axis=-1)[..., 0]
    m = (batch["mask_labels"] > 0).astype(jnp.float32)
    loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, {"cloze_nll": loss}


def user_state(params, cfg: BERT4RecCfg, items, lengths, *,
               shard_axis: Optional[str] = None):
    """Append [MASK] at the end and read its hidden state (B, d)."""
    B, Lh = items.shape
    pos_idx = jnp.minimum(lengths, Lh - 1)
    items = items.at[jnp.arange(B), pos_idx].set(cfg.mask_id)
    valid = jnp.arange(Lh)[None, :] <= pos_idx[:, None]
    h = encode(params, cfg, items, valid, shard_axis=shard_axis)
    return jnp.take_along_axis(
        h, pos_idx[:, None, None].repeat(cfg.embed_dim, -1), axis=1)[:, 0]


def serve_score(params, cfg: BERT4RecCfg, batch, *,
                shard_axis: Optional[str] = None):
    """batch: items (B, L), lengths (B,), cand (B, C) → (B, C)."""
    u = user_state(params, cfg, batch["items"], batch["lengths"],
                   shard_axis=shard_axis)
    e = EB.lookup(params["item_embed"], batch["cand"],
                  shard_axis=shard_axis)
    return jnp.einsum("bd,bcd->bc", u, e) + \
        params["out_bias"][batch["cand"]]


def retrieval_scores(params, cfg: BERT4RecCfg, query, cand_ids, *,
                     shard_axis: Optional[str] = None):
    """One user vs N candidates — batched dot."""
    u = user_state(params, cfg, query["items"][None],
                   query["length"][None], shard_axis=shard_axis)
    e = EB.lookup(params["item_embed"], cand_ids, shard_axis=shard_axis)
    return (u @ e.T)[0] + params["out_bias"][cand_ids]
