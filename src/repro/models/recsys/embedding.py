"""Embedding substrate for the recsys archs.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — lookups are
built from ``jnp.take`` + ``jax.ops.segment_sum`` (the system-building
requirement, not a stub). Three access paths:

* ``lookup``      — single-hot field lookup (CTR models), row-sharded
                    tables get a sharding constraint so GSPMD lowers the
                    gather to collectives over the 'model' axis.
* ``bag_lookup``  — multi-hot bag with sum/mean/max reduction and
                    optional per-sample weights (the EmbeddingBag twin).
* ``TieredEmbedding`` — the paper's memory-mapping technique applied to
                    huge tables: cold rows live in a host-side
                    PagedStore-backed pool, hot row-blocks are cached in
                    device memory with LRU eviction. Used by the serving
                    examples/benchmarks; the jitted dry-run path uses
                    the device-resident sharded table.

Field packing: CTR models concatenate per-field vocabularies into one
(total_rows, dim) table with per-field offsets — one gather instead of
39, and one table to shard.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import cdiv


# ---------------------------------------------------------------------------
# Packed multi-field table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """Per-field vocabulary sizes packed into one table."""
    vocab_sizes: tuple[int, ...]

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    def offsets(self) -> np.ndarray:
        off = np.zeros(self.n_fields, np.int64)
        np.cumsum(self.vocab_sizes[:-1], out=off[1:])
        return off


def packed_table_init(key, spec: FieldSpec, dim: int, dtype=jnp.float32,
                      scale: float = 0.01):
    rows = spec.total_rows
    return jax.random.normal(key, (rows, dim), jnp.float32).astype(dtype) * scale


def pack_field_ids(spec: FieldSpec, field_ids):
    """field_ids: (..., n_fields) per-field local ids → global row ids."""
    off = jnp.asarray(spec.offsets(), jnp.int32)
    return field_ids.astype(jnp.int32) + off


# ---------------------------------------------------------------------------
# Lookup primitives
# ---------------------------------------------------------------------------

def lookup(table, ids, *, shard_axis: Optional[str] = None):
    """Single-hot lookup. table: (R, d); ids: (...,) int32 → (..., d).

    With ``shard_axis`` the table is constrained row-sharded so GSPMD
    turns the gather into a collective lookup over that axis.
    """
    if shard_axis is not None:
        from jax.sharding import PartitionSpec as P
        table = jax.lax.with_sharding_constraint(table, P(shard_axis, None))
    return jnp.take(table, ids, axis=0, mode="clip")


def bag_lookup(table, ids, valid, *, mode: str = "sum",
               weights: Optional[jnp.ndarray] = None,
               shard_axis: Optional[str] = None):
    """EmbeddingBag: ids (..., bag) int32, valid (..., bag) bool
    → (..., d) reduced over the bag.

    mode: 'sum' | 'mean' | 'max'. ``weights`` (..., bag) scales rows
    before a sum/mean reduction (per-sample-weights semantics).
    """
    rows = lookup(table, ids, shard_axis=shard_axis)        # (..., bag, d)
    v = valid[..., None].astype(rows.dtype)
    if mode == "max":
        neg = jnp.asarray(-1e30, rows.dtype)
        m = jnp.max(jnp.where(v > 0, rows, neg), axis=-2)
        return jnp.where(jnp.any(valid, axis=-1)[..., None], m, 0.0)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    s = jnp.sum(rows * v, axis=-2)
    if mode == "mean":
        n = jnp.maximum(jnp.sum(valid, axis=-1, keepdims=True), 1)
        s = s / n.astype(s.dtype)
    return s


def ragged_bag_lookup(table, flat_ids, segment_ids, n_segments: int,
                      *, mode: str = "sum",
                      weights: Optional[jnp.ndarray] = None):
    """True ragged EmbeddingBag: flat_ids (N,), segment_ids (N,) sorted
    → (n_segments, d). This is the segment_sum formulation."""
    rows = jnp.take(table, flat_ids, axis=0, mode="clip")
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=n_segments)
    s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(segment_ids, jnp.float32),
                                  segment_ids, num_segments=n_segments)
        s = s / jnp.maximum(cnt[:, None], 1.0)
    return s


# ---------------------------------------------------------------------------
# TieredEmbedding — the paper's technique on recsys tables
# ---------------------------------------------------------------------------

class TieredEmbedding:
    """Huge-table embedding with host(mmap)→device block paging.

    The table's rows live in a file (optionally memory-mapped, exactly
    like the ColBERT residual pool); fixed-size row-blocks are fetched
    to device on demand and LRU-evicted. ``lookup_host`` assembles rows
    through the cache and reports hit/miss counters so benchmarks can
    show the RAM/latency trade directly (Table 1/Fig 2 analogues).
    """

    def __init__(self, path, *, mode: str = "mmap", block_rows: int = 4096,
                 capacity_blocks: int = 64):
        import json
        import pathlib
        self.path = pathlib.Path(path)
        meta = json.loads((self.path / "meta.json").read_text())
        self.rows, self.dim = meta["rows"], meta["dim"]
        shape = (self.rows, self.dim)
        if mode == "mmap":
            self.pool = np.memmap(self.path / "table.bin", np.float32, "r",
                                  shape=shape)
        else:
            self.pool = np.fromfile(self.path / "table.bin",
                                    np.float32).reshape(shape)
        self.mode = mode
        self.block_rows = block_rows
        self.capacity = capacity_blocks
        from collections import OrderedDict
        self._cache: "OrderedDict[int, jax.Array]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.rows_read = 0

    @staticmethod
    def write(path, table: np.ndarray):
        import json
        import pathlib
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        table.astype(np.float32).tofile(path / "table.bin")
        (path / "meta.json").write_text(json.dumps(
            {"rows": int(table.shape[0]), "dim": int(table.shape[1])}))
        return path

    def _block(self, b: int):
        if b in self._cache:
            self._cache.move_to_end(b)
            self.hits += 1
            return self._cache[b]
        self.misses += 1
        lo = b * self.block_rows
        hi = min(lo + self.block_rows, self.rows)
        blk = np.zeros((self.block_rows, self.dim), np.float32)
        blk[: hi - lo] = self.pool[lo:hi]
        arr = jax.device_put(blk)
        self._cache[b] = arr
        while len(self._cache) > self.capacity:
            self._cache.popitem(last=False)
        return arr

    def lookup_host(self, ids: np.ndarray) -> np.ndarray:
        """ids: (...,) int → rows (..., dim) float32 through the cache."""
        ids = np.asarray(ids)
        flat = ids.reshape(-1)
        self.rows_read += flat.size
        out = np.zeros((flat.size, self.dim), np.float32)
        blocks = flat // self.block_rows
        for b in np.unique(blocks):
            sel = blocks == b
            arr = self._block(int(b))
            off = flat[sel] - int(b) * self.block_rows
            out[sel] = np.asarray(jnp.take(arr, off, axis=0))
        return out.reshape(*ids.shape, self.dim)

    def resident_bytes(self) -> int:
        return len(self._cache) * self.block_rows * self.dim * 4

    def total_bytes(self) -> int:
        return self.rows * self.dim * 4


# ---------------------------------------------------------------------------
# Small shared blocks
# ---------------------------------------------------------------------------

def mlp_init(key, dims: Sequence[int], dtype=jnp.float32):
    """dims: [in, h1, ..., out]."""
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": jax.random.normal(ks[i], (dims[i], dims[i + 1]),
                                   jnp.float32).astype(dtype)
        * (2.0 / dims[i]) ** 0.5
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype)
        for i in range(len(dims) - 1)
    }


def mlp_apply(params, x, *, act=jax.nn.relu, final_act=None):
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def dice_init(d: int, dtype=jnp.float32):
    """Dice activation (Zhou et al., DIN/DIEN): data-adaptive PReLU gate."""
    return {"alpha": jnp.zeros((d,), dtype)}


def dice_apply(params, x, eps: float = 1e-8):
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.var(x, axis=0, keepdims=True)
    p = jax.nn.sigmoid((x - mu) * jax.lax.rsqrt(var + eps))
    return p * x + (1.0 - p) * params["alpha"] * x


def bce_loss(logits, labels):
    """Binary cross-entropy from logits. labels ∈ {0, 1} float."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
