"""SASRec (Kang & McAuley, arXiv:1808.09781): self-attentive sequential
recommendation. Causal transformer over the item history; training uses
the paper's binary CE with one sampled negative per position; serving
scores the last hidden state against candidate item embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import layers as L
from repro.models.recsys import embedding as EB


@dataclasses.dataclass(frozen=True)
class SASRecCfg:
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0            # inference-style determinism

    @property
    def attn(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.embed_dim, n_heads=self.n_heads,
                         kv_heads=self.n_heads,
                         head_dim=self.embed_dim // self.n_heads,
                         use_rope=False)


def init(key, cfg: SASRecCfg):
    ks = PRNGSeq(key)

    def block_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln_attn": L.layernorm_init(cfg.embed_dim),
            "ln_ffn": L.layernorm_init(cfg.embed_dim),
            "attn": L.gqa_init(k1, cfg.attn),
            "ffn": {  # SASRec uses a 2-layer pointwise FFN, same width
                "w1": L.dense_init(jax.random.fold_in(k2, 0), cfg.embed_dim,
                                   cfg.embed_dim),
                "b1": jnp.zeros((cfg.embed_dim,)),
                "w2": L.dense_init(jax.random.fold_in(k2, 1), cfg.embed_dim,
                                   cfg.embed_dim),
                "b2": jnp.zeros((cfg.embed_dim,)),
            },
        }

    block_keys = jnp.stack(ks.take(cfg.n_blocks))
    return {
        "item_embed": jax.random.normal(
            next(ks), (cfg.n_items, cfg.embed_dim)) * 0.02,
        "pos_embed": jax.random.normal(
            next(ks), (cfg.seq_len, cfg.embed_dim)) * 0.02,
        "blocks": jax.vmap(block_init)(block_keys),
        "final_ln": L.layernorm_init(cfg.embed_dim),
    }


def encode(params, cfg: SASRecCfg, items, valid, *,
           shard_axis: Optional[str] = None):
    """items: (B, L) int32; valid: (B, L) bool → hidden (B, L, d)."""
    B, Lh = items.shape
    x = EB.lookup(params["item_embed"], items, shard_axis=shard_axis)
    x = x + params["pos_embed"][None, :Lh]
    x = x * valid[..., None].astype(x.dtype)
    pos = jnp.where(valid, jnp.arange(Lh, dtype=jnp.int32)[None], -1)

    def body(x, bp):
        h = L.layernorm_apply(bp["ln_attn"], x)
        a = L.gqa_apply(bp["attn"], cfg.attn, h, pos, causal=True,
                        use_blockwise=False)
        x = x + a
        h = L.layernorm_apply(bp["ln_ffn"], x)
        h = jax.nn.relu(h @ bp["ffn"]["w1"] + bp["ffn"]["b1"])
        x = x + h @ bp["ffn"]["w2"] + bp["ffn"]["b2"]
        x = x * valid[..., None].astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.layernorm_apply(params["final_ln"], x)


def loss_fn(params, cfg: SASRecCfg, batch, *,
            shard_axis: Optional[str] = None):
    """batch: items (B, L), pos_labels (B, L), neg_labels (B, L),
    valid (B, L). Binary CE pos-vs-neg per position (paper's loss)."""
    valid = batch["valid"]
    h = encode(params, cfg, batch["items"], valid, shard_axis=shard_axis)
    e_pos = EB.lookup(params["item_embed"], batch["pos_labels"],
                      shard_axis=shard_axis)
    e_neg = EB.lookup(params["item_embed"], batch["neg_labels"],
                      shard_axis=shard_axis)
    s_pos = jnp.sum(h * e_pos, axis=-1).astype(jnp.float32)
    s_neg = jnp.sum(h * e_neg, axis=-1).astype(jnp.float32)
    m = valid.astype(jnp.float32)
    nll = (jnp.maximum(s_pos, 0) - s_pos + jnp.log1p(jnp.exp(-jnp.abs(s_pos)))
           + jnp.maximum(s_neg, 0) + jnp.log1p(jnp.exp(-jnp.abs(s_neg))))
    loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, {"bce": loss}


def user_state(params, cfg: SASRecCfg, items, lengths, *,
               shard_axis: Optional[str] = None):
    """Final-position hidden state: the user representation (B, d)."""
    B, Lh = items.shape
    valid = jnp.arange(Lh)[None, :] < lengths[:, None]
    h = encode(params, cfg, items, valid, shard_axis=shard_axis)
    last = jnp.maximum(lengths - 1, 0)
    return jnp.take_along_axis(h, last[:, None, None].repeat(
        cfg.embed_dim, -1), axis=1)[:, 0]


def serve_score(params, cfg: SASRecCfg, batch, *,
                shard_axis: Optional[str] = None):
    """Score each user's next-item candidates: batch has items (B, L),
    lengths (B,), cand (B, C) → scores (B, C)."""
    u = user_state(params, cfg, batch["items"], batch["lengths"],
                   shard_axis=shard_axis)
    e = EB.lookup(params["item_embed"], batch["cand"],
                  shard_axis=shard_axis)        # (B, C, d)
    return jnp.einsum("bd,bcd->bc", u, e)


def retrieval_scores(params, cfg: SASRecCfg, query, cand_ids, *,
                     shard_axis: Optional[str] = None):
    """One user vs N candidates: a (1, d)×(d, N) matmul — batched dot,
    not a loop. query: items (L,), length ()."""
    u = user_state(params, cfg, query["items"][None],
                   query["length"][None], shard_axis=shard_axis)  # (1, d)
    e = EB.lookup(params["item_embed"], cand_ids,
                  shard_axis=shard_axis)                          # (N, d)
    return (u @ e.T)[0]
