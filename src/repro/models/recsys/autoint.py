"""AutoInt (Song et al., arXiv:1810.11921): CTR prediction via
multi-head self-attention over field embeddings.

Per sample: 39 categorical fields → (F, d) embeddings → L residual
interacting layers of multi-head self-attention over the *fields* axis
→ flatten → logit (+ optional first-order LR term, as in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models.recsys import embedding as EB


@dataclasses.dataclass(frozen=True)
class AutoIntCfg:
    fields: EB.FieldSpec
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32              # total attention width (all heads)
    use_lr: bool = True           # first-order term
    dtype = jnp.float32

    @property
    def n_fields(self) -> int:
        return self.fields.n_fields


def init(key, cfg: AutoIntCfg):
    ks = PRNGSeq(key)
    d, da = cfg.embed_dim, cfg.d_attn
    layers = []
    d_in = d
    for _ in range(cfg.n_attn_layers):
        layers.append({
            "wq": jax.random.normal(next(ks), (d_in, da)) * (1 / d_in) ** 0.5,
            "wk": jax.random.normal(next(ks), (d_in, da)) * (1 / d_in) ** 0.5,
            "wv": jax.random.normal(next(ks), (d_in, da)) * (1 / d_in) ** 0.5,
            "w_res": jax.random.normal(next(ks), (d_in, da)) * (1 / d_in) ** 0.5,
        })
        d_in = da
    p = {
        "tables": {"packed": EB.packed_table_init(next(ks), cfg.fields, d)},
        "layers": layers,
        "w_out": jax.random.normal(next(ks),
                                   (cfg.n_fields * d_in, 1)) * 0.01,
        "b_out": jnp.zeros((1,)),
    }
    if cfg.use_lr:
        p["lr_weight"] = jnp.zeros((cfg.fields.total_rows, 1), jnp.float32)
    return p


def _interact(layers, cfg: AutoIntCfg, e):
    """e: (B, F, d) → (B, F, d_attn) after L interacting layers."""
    H = cfg.n_heads
    for lp in layers:
        q = e @ lp["wq"]
        k = e @ lp["wk"]
        v = e @ lp["wv"]
        B, F, da = q.shape
        dh = da // H
        qh = q.reshape(B, F, H, dh)
        kh = k.reshape(B, F, H, dh)
        vh = v.reshape(B, F, H, dh)
        s = jnp.einsum("bfhd,bghd->bhfg", qh, kh,
                       preferred_element_type=jnp.float32)
        a = jax.nn.softmax(s, axis=-1).astype(vh.dtype)
        o = jnp.einsum("bhfg,bghd->bfhd", a, vh).reshape(B, F, da)
        e = jax.nn.relu(o + e @ lp["w_res"])
    return e


def forward(params, cfg: AutoIntCfg, field_ids, *,
            shard_axis: Optional[str] = None):
    """field_ids: (B, F) per-field local ids → logits (B,)."""
    rows = EB.pack_field_ids(cfg.fields, field_ids)
    e = EB.lookup(params["tables"]["packed"], rows, shard_axis=shard_axis)
    h = _interact(params["layers"], cfg, e)
    B = h.shape[0]
    logit = (h.reshape(B, -1) @ params["w_out"])[:, 0] + params["b_out"][0]
    if cfg.use_lr:
        lr = EB.lookup(params["lr_weight"], rows, shard_axis=shard_axis)
        logit = logit + jnp.sum(lr[..., 0], axis=-1)
    return logit


def loss_fn(params, cfg: AutoIntCfg, batch, *,
            shard_axis: Optional[str] = None):
    logits = forward(params, cfg, batch["fields"], shard_axis=shard_axis)
    loss = EB.bce_loss(logits, batch["label"])
    return loss, {"bce": loss}


def serve_score(params, cfg: AutoIntCfg, batch, *,
                shard_axis: Optional[str] = None):
    """CTR probabilities for a serving batch."""
    return jax.nn.sigmoid(
        forward(params, cfg, batch["fields"], shard_axis=shard_axis))


# ---------------------------------------------------------------------------
# Retrieval scoring (1 query × n_candidates) — multi-stage, the paper's
# candidate-narrowing idea applied to recsys (see retrieval.py).
# ---------------------------------------------------------------------------

def retrieval_scores(params, cfg: AutoIntCfg, user_fields, cand_ids,
                     item_field: int, *, shard_axis: Optional[str] = None):
    """user_fields: (F,) one query's fields; cand_ids: (N,) candidate
    local-ids for field ``item_field`` → exact AutoInt logits (N,)."""
    N = cand_ids.shape[0]
    fields = jnp.broadcast_to(user_fields[None, :], (N, cfg.n_fields))
    fields = fields.at[:, item_field].set(cand_ids)
    return forward(params, cfg, fields, shard_axis=shard_axis)
