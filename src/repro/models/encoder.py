"""Bidirectional transformer encoder (BERT-style) shared by ColBERT and
SPLADE. Pre-LN blocks, learned absolute positions, padding masks via
position == -1 sentinels."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.utils import PRNGSeq
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_len: int = 512
    dtype: Any = jnp.float32

    @property
    def attn(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         kv_heads=self.n_heads,
                         head_dim=self.d_model // self.n_heads,
                         use_rope=False)


def init(key, cfg: EncoderCfg):
    ks = PRNGSeq(key)

    def layer_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln_attn": L.layernorm_init(cfg.d_model, cfg.dtype),
            "ln_ffn": L.layernorm_init(cfg.d_model, cfg.dtype),
            "attn": L.gqa_init(k1, cfg.attn, cfg.dtype),
            "ffn": L.ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    layer_keys = jnp.stack(ks.take(cfg.n_layers))
    return {
        "embed": L.embed_init(next(ks), cfg.vocab, cfg.d_model, cfg.dtype),
        "pos_embed": L.embed_init(next(ks), cfg.max_len, cfg.d_model, cfg.dtype),
        "final_ln": L.layernorm_init(cfg.d_model, cfg.dtype),
        "layers": jax.vmap(layer_init)(layer_keys),
    }


def apply(params, cfg: EncoderCfg, tokens, mask):
    """tokens: (B, L) int32; mask: (B, L) bool → hidden (B, L, D)."""
    B, Lseq = tokens.shape
    pos = jnp.arange(Lseq, dtype=jnp.int32)[None]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jnp.take(params["pos_embed"], jnp.minimum(pos, cfg.max_len - 1), axis=0)
    x = x.astype(cfg.dtype)
    positions = jnp.where(mask, jnp.broadcast_to(pos, (B, Lseq)), -1)

    def body(x, lp):
        h = L.layernorm_apply(lp["ln_attn"], x)
        a = L.gqa_apply(lp["attn"], cfg.attn, h, positions, causal=False,
                        use_blockwise=False)
        x = x + a
        h = L.layernorm_apply(lp["ln_ffn"], x)
        x = x + L.ffn_apply(lp["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.layernorm_apply(params["final_ln"], x)
