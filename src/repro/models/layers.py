"""Core neural layers, functional style.

Conventions
-----------
* ``init_*`` functions return nested dicts of arrays; ``*_apply``
  functions are pure.
* All matmul params are stored as ``(in, out)`` so that stacking layers
  along a leading axis keeps einsum strings readable.
* Shapes: B batch, L sequence, D d_model, H q heads, K kv heads,
  h head_dim, F d_ff, E experts, V vocab.
* Compute dtype is taken from the input; params may be fp32/bf16.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.utils import cdiv


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32).astype(dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype=jnp.float32).astype(dtype) * 0.02


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., L, n_heads, head_dim); positions: (..., L)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (h/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, h/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., L, 1, h/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure XLA
# ---------------------------------------------------------------------------
# Avoids materialising the (L, L) score matrix: scans over KV chunks
# carrying a running (max, denominator, accumulator). This is the
# XLA-portable twin of a Pallas flash kernel; on real TPUs the Pallas
# kernel in repro/kernels/flash_attention is swapped in via config.

def _attn_chunk_update(carry, kc, vc, q, mask_chunk, scale,
                       score_spec=None):
    m_prev, l_prev, acc_prev = carry
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc, preferred_element_type=jnp.float32)
    if score_spec is not None:
        s = jax.lax.with_sharding_constraint(s, score_spec)
    s = s * scale
    s = jnp.where(mask_chunk, s, -1e30)
    m_cur = jnp.max(s, axis=-1)  # (B, H, Lq)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])  # (B, H, Lq, Ck)
    l_corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * l_corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc_new = acc_prev * l_corr.transpose(0, 2, 1)[..., None] + pv
    return (m_new, l_new, acc_new)


def blockwise_attention(q, k, v, *, causal: bool, q_positions, kv_positions,
                        chunk: int = 1024, scale: Optional[float] = None,
                        window: int = 0, score_spec=None,
                        remat_chunks: bool = False):
    """Memory-efficient attention.

    q: (B, Lq, H, h); k, v: (B, Lkv, K, h) with H % K == 0 (GQA).
    Returns (B, Lq, H, h) in q.dtype.

    ``score_spec`` pins the per-chunk score panel's sharding (batch ×
    heads) so GSPMD never batch-replicates it; ``remat_chunks``
    checkpoints each chunk update so the backward pass recomputes score
    panels per chunk instead of saving the whole stack (flash-style
    bwd).
    """
    B, Lq, H, h = q.shape
    _, Lkv, K, _ = k.shape
    hv = v.shape[-1]
    assert H % K == 0
    groups = H // K
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    scale = (1.0 / h ** 0.5) if scale is None else scale

    n_chunks = cdiv(Lkv, chunk)
    pad = n_chunks * chunk - Lkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)

    k = k.reshape(B, n_chunks, chunk, H, h).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, n_chunks, chunk, H, hv).transpose(1, 0, 2, 3, 4)
    kpos = kv_positions.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    m0 = jnp.full((B, H, Lq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, Lq), dtype=jnp.float32)
    a0 = jnp.zeros((B, Lq, H, hv), dtype=jnp.float32)

    def body(carry, xs):
        kc, vc, kp = xs
        valid = kp[:, None, None, :] >= 0  # (B,1,1,Ck)
        if causal:
            mask = (kp[:, None, None, :] <= q_positions[:, None, :, None]) & valid
        else:
            mask = jnp.broadcast_to(valid, (B, 1, Lq, chunk))
        if window > 0:  # chunked-local (iRoPE-style) attention
            mask = mask & (kp[:, None, None, :]
                           > q_positions[:, None, :, None] - window)
        return _attn_chunk_update(carry, kc, vc, q, mask, scale,
                                  score_spec), None

    if remat_chunks:
        body = jax.checkpoint(body)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k, v, kpos))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def dense_attention(q, k, v, *, causal: bool, q_positions, kv_positions,
                    scale: Optional[float] = None, window: int = 0,
                    score_spec=None):
    """Reference full-materialisation attention (small L only).

    ``score_spec``: optional PartitionSpec pinned onto the score tensor
    (B, H, Lq, Lkv). Sharding the Lkv axis keeps the QK and PV einsums
    local to each KV shard — softmax statistics and the PV contraction
    then combine through tiny all-reduces instead of the KV cache being
    all-gathered (split-S / flash-decoding, expressed in GSPMD).
    """
    B, Lq, H, h = q.shape
    _, Lkv, K, _ = k.shape
    groups = H // K
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    scale = (1.0 / h ** 0.5) if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if score_spec is not None:
        s = jax.lax.with_sharding_constraint(s, score_spec)
    valid = (kv_positions >= 0)[:, None, None, :]
    if causal:
        mask = (kv_positions[:, None, None, :] <= q_positions[:, None, :, None]) & valid
    else:
        mask = jnp.broadcast_to(valid, s.shape)
    if window > 0:
        mask = mask & (kv_positions[:, None, None, :]
                       > q_positions[:, None, :, None] - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    window: int = 0        # >0: chunked-local attention (Llama-4 iRoPE)


def gqa_init(key, cfg: AttnCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    D, H, K, h = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], D, H * h, dtype),
        "wk": dense_init(ks[1], D, K * h, dtype),
        "wv": dense_init(ks[2], D, K * h, dtype),
        "wo": dense_init(ks[3], H * h, D, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * h,), dtype)
        p["bk"] = jnp.zeros((K * h,), dtype)
        p["bv"] = jnp.zeros((K * h,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(h, dtype)
        p["k_norm"] = rmsnorm_init(h, dtype)
    return p


def gqa_project_qkv(params, cfg: AttnCfg, x, positions):
    B, L, D = x.shape
    H, K, h = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = jnp.einsum("bld,dk->blk", x, params["wq"])
    k = jnp.einsum("bld,dk->blk", x, params["wk"])
    v = jnp.einsum("bld,dk->blk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, L, H, h)
    k = k.reshape(B, L, K, h)
    v = v.reshape(B, L, K, h)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        k = rmsnorm_apply(params["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q, jnp.maximum(positions, 0), cfg.rope_theta)
        k = apply_rope(k, jnp.maximum(positions, 0), cfg.rope_theta)
    return q, k, v


def gqa_apply(params, cfg: AttnCfg, x, positions, *, causal=True, chunk=1024,
              use_blockwise=True, score_spec=None, remat_chunks=False):
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    attn = blockwise_attention if use_blockwise else dense_attention
    kwargs = ({"chunk": chunk, "remat_chunks": remat_chunks}
              if use_blockwise else {})
    o = attn(q, k, v, causal=causal, q_positions=positions,
             kv_positions=positions, window=cfg.window,
             score_spec=score_spec, **kwargs)
    B, L = x.shape[:2]
    return jnp.einsum("blk,kd->bld", o.reshape(B, L, -1), params["wo"])


def gqa_decode_apply(params, cfg: AttnCfg, x, positions, kv_cache,
                     cache_positions, *, opt: bool = False,
                     score_spec=None):
    """Single-token decode. x: (B, 1, D); kv_cache: dict(k,v): (B, S, K, h).

    cache_positions: (B, S) int32; -1 marks unwritten slots. New K/V are
    scattered at ``positions`` (B, 1). Returns (out, new_cache).

    ``opt`` enables the long-context access-minimisation path:
      * chunked-local layers (cfg.window > 0) slice only the last
        ``window`` cache positions instead of touching the full cache
        (the serving analogue of the paper's "read only what you score");
      * global layers pin the score tensor's KV axis with ``score_spec``
        so a sequence-sharded cache is reduced in place (split-S) rather
        than all-gathered.
    """
    B, L, D = x.shape
    q, k_new, v_new = gqa_project_qkv(params, cfg, x, positions)
    slot = positions[:, 0]  # (B,) — cache is laid out by absolute position
    bidx = jnp.arange(B)
    k = kv_cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = kv_cache["v"].at[bidx, slot].set(v_new[:, 0])
    new_positions = cache_positions.at[bidx, slot].set(slot)

    if opt and score_spec is not None:
        # split-S: scores stay on the cache's sequence sharding; softmax
        # statistics and the PV contraction combine via tiny all-reduces
        # (flash-decoding in GSPMD). The window mask (chunked-local
        # iRoPE layers) rides along for free.
        o = dense_attention(q, k, v, causal=True, q_positions=positions,
                            kv_positions=new_positions, window=cfg.window,
                            score_spec=score_spec)
    elif opt and cfg.window > 0 and cfg.window < k.shape[1]:
        # window-slice path (useful when the cache is batch-sharded and
        # slicing is local): touch only the last `window` positions
        W = cfg.window
        start = jnp.maximum(slot - (W - 1), 0)                    # (B,)

        def win(arr, s):
            return jax.lax.dynamic_slice_in_dim(arr, s, W, axis=0)

        k_w = jax.vmap(win)(k, start)                             # (B,W,K,h)
        v_w = jax.vmap(win)(v, start)
        pos_w = jax.vmap(win)(new_positions, start)               # (B,W)
        o = dense_attention(q, k_w, v_w, causal=True,
                            q_positions=positions, kv_positions=pos_w,
                            window=cfg.window)
    else:
        o = dense_attention(q, k, v, causal=True, q_positions=positions,
                            kv_positions=new_positions, window=cfg.window)
    out = jnp.einsum("blk,kd->bld", o.reshape(B, L, -1), params["wo"])
    return out, {"k": k, "v": v}, new_positions


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2/V3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLACfg:
    d_model: int
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLACfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    D, H = cfg.d_model, cfg.n_heads
    qh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], D, cfg.q_lora_rank, dtype),
        "q_a_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, H * qh, dtype),
        "wkv_a": dense_init(ks[2], D, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_a_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[3], cfg.kv_lora_rank,
                            H * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * cfg.v_head_dim, D, dtype),
    }


def mla_apply(params, cfg: MLACfg, x, positions, *, causal=True, chunk=1024,
              use_blockwise=True, score_spec=None, remat_chunks=False):
    """Training/prefill MLA: materialise per-head K/V from the latent."""
    B, L, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = jnp.einsum("bld,dr->blr", x, params["wq_a"])
    q = rmsnorm_apply(params["q_a_norm"], q)
    q = jnp.einsum("blr,rk->blk", q, params["wq_b"]).reshape(B, L, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bld,dr->blr", x, params["wkv_a"])
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm_apply(params["kv_a_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,L,1,dr)

    kvb = jnp.einsum("blr,rk->blk", c_kv, params["wkv_b"]).reshape(B, L, H, dn + dv)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k_rope_b = jnp.broadcast_to(k_rope, (B, L, H, dr))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = 1.0 / (dn + dr) ** 0.5
    attn = blockwise_attention if use_blockwise else dense_attention
    kwargs = ({"chunk": chunk, "remat_chunks": remat_chunks}
              if use_blockwise else {})
    o = attn(q_full, k_full, v, causal=causal, q_positions=positions,
             kv_positions=positions, scale=scale, score_spec=score_spec,
             **kwargs)
    return jnp.einsum("blk,kd->bld", o.reshape(B, L, H * dv), params["wo"])


def mla_decode_apply(params, cfg: MLACfg, x, positions, cache, cache_positions):
    """Absorbed-matrix MLA decode: attends directly over the compressed
    latent cache (c_kv, k_rope) — the memory win that makes MLA serve-
    friendly. cache: {"c_kv": (B,S,r), "k_rope": (B,S,dr)}.
    """
    B, L, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)

    q = jnp.einsum("bld,dr->blr", x, params["wq_a"])
    q = rmsnorm_apply(params["q_a_norm"], q)
    q = jnp.einsum("blr,rk->blk", q, params["wq_b"]).reshape(B, L, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bld,dr->blr", x, params["wkv_a"])
    c_kv_new = rmsnorm_apply(params["kv_a_norm"], kv[..., :r])
    k_rope_new = apply_rope(kv[:, :, None, cfg.kv_lora_rank:], positions,
                            cfg.rope_theta)[:, :, 0, :]

    bidx = jnp.arange(B)
    slot = positions[:, 0]
    c_kv = cache["c_kv"].at[bidx, slot].set(c_kv_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, slot].set(k_rope_new[:, 0])
    new_positions = cache_positions.at[bidx, slot].set(slot)

    # Absorb W^{UK}: q_nope (B,L,H,dn) @ wkv_b_k (r, H, dn) -> (B,L,H,r)
    wkv_b = params["wkv_b"].reshape(r, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("blhd,rhd->blhr", q_nope, w_uk)

    s = jnp.einsum("blhr,bsr->bhls", q_lat, c_kv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("blhd,bsd->bhls", q_rope, k_rope,
                       preferred_element_type=jnp.float32)
    s = s * (1.0 / (dn + dr) ** 0.5)
    mask = (new_positions[:, None, None, :] <= positions[:, None, :, None]) & \
           (new_positions >= 0)[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhls,bsr->blhr", p, c_kv)  # (B,L,H,r)
    o = jnp.einsum("blhr,rhd->blhd", o_lat, w_uv)  # (B,L,H,dv)
    out = jnp.einsum("blk,kd->bld", o.reshape(B, L, H * dv), params["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope}, new_positions


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU and Mixture-of-Experts
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def ffn_apply(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # sigmoid routing + bias (DeepSeek-V3 aux-loss-free) vs softmax (llama4 top-1)
    sigmoid_router: bool = False


def moe_init(key, cfg: MoECfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # router kept fp32
        "experts_w_gate": jax.random.normal(ks[1], (E, D, F), jnp.float32).astype(dtype) * (1.0 / D) ** 0.5,
        "experts_w_up": jax.random.normal(ks[2], (E, D, F), jnp.float32).astype(dtype) * (1.0 / D) ** 0.5,
        "experts_w_down": jax.random.normal(ks[3], (E, F, D), jnp.float32).astype(dtype) * (1.0 / F) ** 0.5,
    }
    if cfg.sigmoid_router:
        p["router_bias"] = jnp.zeros((E,), jnp.float32)  # load-balance bias (aux-free)
    if cfg.n_shared:
        p["shared"] = ffn_init(ks[4], D, cfg.d_ff_shared or cfg.d_ff_expert, dtype)
    return p


def moe_route(params, cfg: MoECfg, x_flat):
    """Router: returns (weights (T, k), expert_ids (T, k), aux_metrics)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), params["router"])
    if cfg.sigmoid_router:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]
        _, ids = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, cfg.top_k)
        if cfg.top_k > 1:
            w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): mean prob per expert × frac routed
    probs_for_aux = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(frac * jnp.mean(probs_for_aux, axis=0))
    return w.astype(x_flat.dtype), ids, {"aux_loss": aux}


def moe_select_apply(params, cfg: MoECfg, x, *, ep_axis=None, dp_axis=None):
    """Selected-expert MoE for tiny token counts (low-batch decode).

    The buffer formulation streams EVERY local expert's weights through
    the core even when one token routes to one expert — at batch 1 that
    is the whole memory roofline. Here the routed experts' weights are
    gathered instead (T·k weight tiles), so HBM traffic scales with the
    *active* experts, the same access-minimisation idea the paper
    applies to the ColBERT index.
    """
    orig_shape = x.shape
    x_flat = x.reshape(-1, cfg.d_model)
    T, k = x_flat.shape[0], cfg.top_k
    w, ids, aux = moe_route(params, cfg, x_flat)
    flat_ids = ids.reshape(-1)                                 # (T·k,)
    wg = jnp.take(params["experts_w_gate"], flat_ids, axis=0)  # (Tk,D,F)
    wu = jnp.take(params["experts_w_up"], flat_ids, axis=0)
    wd = jnp.take(params["experts_w_down"], flat_ids, axis=0)
    if ep_axis is not None:
        from jax.sharding import PartitionSpec as P
        spec_in = P(None, dp_axis, ep_axis)
        wg = jax.lax.with_sharding_constraint(wg, spec_in)
        wu = jax.lax.with_sharding_constraint(wu, spec_in)
        wd = jax.lax.with_sharding_constraint(wd, P(None, ep_axis, dp_axis))
    x2 = jnp.repeat(x_flat, k, axis=0)                         # (Tk, D)
    g = jnp.einsum("td,tdf->tf", x2, wg)
    u = jnp.einsum("td,tdf->tf", x2, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x2.dtype) * u
    y2 = jnp.einsum("tf,tfd->td", h, wd)                       # (Tk, D)
    y = (y2.reshape(T, k, cfg.d_model)
         * w[..., None].astype(y2.dtype)).sum(axis=1)
    if cfg.n_shared:
        y = y + ffn_apply(params["shared"], x_flat)
    return y.reshape(orig_shape), aux


def _moe_expert_ffn(params, buffer):
    """Batched expert SwiGLU over a (..., E, C, D) buffer."""
    g = jnp.einsum("...ecd,edf->...ecf", buffer, params["experts_w_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", buffer, params["experts_w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buffer.dtype) * u
    return jnp.einsum("...ecf,efd->...ecd", h, params["experts_w_down"])


def moe_apply_local_dispatch(params, cfg: MoECfg, x, *, dp_slices: int,
                             ep_axis: Optional[str] = None,
                             dp_axis: Optional[str] = None):
    """Data-local MoE dispatch (the hillclimbed path).

    The global-index dispatch below makes GSPMD all-reduce full
    (T, d_model) fp32 tensors (measured: 40 GB/device per MoE layer on
    llama4 train). Here tokens are reshaped to (dp_slices, T_local, D)
    and each data shard sorts/scatters ONLY its slice (vmap over the
    sharded leading axis keeps every scatter local); expert weights are
    constrained gathered-on-EP before the matmul so the only wire cost
    is the per-layer FSDP weight all-gather — the floor for this
    parameter sharding.
    """
    from jax.sharding import PartitionSpec as P
    orig_shape = x.shape
    x_flat = x.reshape(-1, cfg.d_model)
    T = x_flat.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    T_loc = T // dp_slices
    C = max(8, int(cdiv(T_loc * k, E) * cfg.capacity_factor))

    w_all, ids_all, aux = moe_route(params, cfg, x_flat)
    x3 = x_flat.reshape(dp_slices, T_loc, cfg.d_model)
    w3 = w_all.reshape(dp_slices, T_loc, k)
    ids3 = ids_all.reshape(dp_slices, T_loc, k)
    if dp_axis is not None:
        x3 = jax.lax.with_sharding_constraint(x3, P(dp_axis, None, None))

    def dispatch_combine(xs, ws, ids):
        flat_e = ids.reshape(-1)
        tok_idx = jnp.repeat(jnp.arange(T_loc), k)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        run_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
        pos_in_e = jnp.arange(T_loc * k) - run_start[e_sorted]
        keep = pos_in_e < C
        buf_rows = jnp.where(keep, e_sorted, E)
        buf_cols = jnp.where(keep, pos_in_e, 0)
        src_tok = tok_idx[order]
        buffer = jnp.zeros((E + 1, C, cfg.d_model), xs.dtype)
        buffer = buffer.at[buf_rows, buf_cols].set(xs[src_tok],
                                                   mode="drop")
        return buffer[:E], (order, keep, e_sorted, buf_cols, src_tok)

    buffers, meta = jax.vmap(dispatch_combine)(x3, w3, ids3)
    if ep_axis is not None:
        buffers = jax.lax.with_sharding_constraint(
            buffers, P(dp_axis, ep_axis, None, None))
        # gather expert weights over the FSDP axis once per layer
        # (cheaper than reducing (dp, E, C, F) outputs)
        params = dict(params)
        for nm in ("experts_w_gate", "experts_w_up", "experts_w_down"):
            params[nm] = jax.lax.with_sharding_constraint(
                params[nm], P(ep_axis, None, None))
    y_buf = _moe_expert_ffn(params, buffers)        # (dp, E, C, D)
    if ep_axis is not None:
        y_buf = jax.lax.with_sharding_constraint(
            y_buf, P(dp_axis, ep_axis, None, None))

    def combine(yb, xs, ws, m):
        order, keep, e_sorted, buf_cols, src_tok = m
        y_choice = yb[jnp.where(keep, e_sorted, 0), buf_cols]
        y_choice = jnp.where(keep[:, None], y_choice, 0.0)
        w_sorted = ws.reshape(-1)[order]
        contrib = y_choice * w_sorted[:, None].astype(y_choice.dtype)
        y = jnp.zeros((T_loc, cfg.d_model), xs.dtype)
        return y.at[src_tok].add(contrib)

    y3 = jax.vmap(combine)(y_buf, x3, w3, meta)
    if dp_axis is not None:
        y3 = jax.lax.with_sharding_constraint(y3, P(dp_axis, None, None))
    y = y3.reshape(T, cfg.d_model)
    if cfg.n_shared:
        y = y + ffn_apply(params["shared"], x_flat)
    return y.reshape(orig_shape), aux


def moe_apply(params, cfg: MoECfg, x, *, ep_axis: Optional[str] = None,
              dp_axis: Optional[str] = None, select_threshold: int = 16,
              dp_slices: int = 0):
    """Capacity-based sort-free MoE dispatch.

    Logical formulation (GSPMD shards it): tokens are scattered into an
    (E, C, D) expert buffer via sorted positions, batched expert matmuls
    run on the buffer, results gather back. Sharding constraints place
    the buffer on the EP axis so that the scatter/gather lower to
    all-to-all style collectives.

    Token counts at or below ``select_threshold`` switch to the
    selected-expert path (weights gathered per routed expert) — the
    low-batch decode regime where streaming all experts is the
    bottleneck. ``dp_slices > 0`` switches to the data-local dispatch
    (see :func:`moe_apply_local_dispatch`).
    """
    orig_shape = x.shape
    x_flat = x.reshape(-1, cfg.d_model)
    T = x_flat.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    if select_threshold and T * k <= select_threshold:
        return moe_select_apply(params, cfg, x, ep_axis=ep_axis,
                                dp_axis=dp_axis)
    if dp_slices and T % dp_slices == 0 and T // dp_slices >= 1:
        return moe_apply_local_dispatch(params, cfg, x,
                                        dp_slices=dp_slices,
                                        ep_axis=ep_axis, dp_axis=dp_axis)
    C = max(8, int(cdiv(T * k, E) * cfg.capacity_factor))

    w, ids, aux = moe_route(params, cfg, x_flat)

    # Flatten (token, choice) pairs and compute per-expert positions via sort.
    flat_e = ids.reshape(-1)                              # (T*k,)
    tok_idx = jnp.repeat(jnp.arange(T), k)                # (T*k,)
    choice_w = w.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    # position within expert run: arange - index-of-run-start
    run_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")  # (E,)
    pos_in_e = jnp.arange(T * k) - run_start[e_sorted]
    keep = pos_in_e < C
    # scatter tokens into the expert buffer; dropped tokens go to a trash row
    buf_rows = jnp.where(keep, e_sorted, E)               # (T*k,)
    buf_cols = jnp.where(keep, pos_in_e, 0)
    src_tok = tok_idx[order]
    buffer = jnp.zeros((E + 1, C, cfg.d_model), dtype=x_flat.dtype)
    buffer = buffer.at[buf_rows, buf_cols].set(x_flat[src_tok], mode="drop")
    buffer = buffer[:E]
    if ep_axis is not None:
        from jax.sharding import PartitionSpec as P
        # Requires an ambient mesh (jax.sharding.use_mesh / `with mesh:`).
        # EP on the expert axis; the capacity axis optionally shards over
        # the data axis so the (E, C, D) buffer never concentrates.
        buffer = jax.lax.with_sharding_constraint(
            buffer, P(ep_axis, dp_axis, None))

    # Batched expert FFN on the buffer.
    g = jnp.einsum("ecd,edf->ecf", buffer, params["experts_w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buffer, params["experts_w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buffer.dtype) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["experts_w_down"])

    # Gather back: each kept (token, choice) reads its expert-buffer row.
    y_choice = y_buf[jnp.where(keep, e_sorted, 0), buf_cols]   # (T*k, D)
    y_choice = jnp.where(keep[:, None], y_choice, 0.0)
    w_sorted = choice_w[order]
    contrib = y_choice * w_sorted[:, None].astype(y_choice.dtype)
    y = jnp.zeros((T, cfg.d_model), dtype=x_flat.dtype)
    y = y.at[src_tok].add(contrib)

    if cfg.n_shared:
        y = y + ffn_apply(params["shared"], x_flat)
    return y.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
# GRU / AUGRU cells (DIEN)
# ---------------------------------------------------------------------------

def gru_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "w": dense_init(ks[0], d_in, 3 * d_hidden, dtype),
        "u": dense_init(ks[1], d_hidden, 3 * d_hidden, dtype),
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def gru_cell(params, h, x, att: Optional[jnp.ndarray] = None):
    """One GRU step. If ``att`` (B, 1) is given, runs AUGRU (DIEN):
    the update gate is scaled by the attention score."""
    zrg = jnp.einsum("bd,dk->bk", x, params["w"]) + \
          jnp.einsum("bd,dk->bk", h, params["u"]) + params["b"]
    d = h.shape[-1]
    z = jax.nn.sigmoid(zrg[:, :d])
    r = jax.nn.sigmoid(zrg[:, d:2 * d])
    g_in = jnp.einsum("bd,dk->bk", x, params["w"][:, 2 * d:]) + \
           r * jnp.einsum("bd,dk->bk", h, params["u"][:, 2 * d:]) + params["b"][2 * d:]
    g = jnp.tanh(g_in)
    if att is not None:
        z = z * att
    return (1.0 - z) * h + z * g


def gru_scan(params, xs, h0, atts: Optional[jnp.ndarray] = None):
    """xs: (B, L, d_in) → hidden states (B, L, d_hidden), final h."""
    def body(h, inp):
        if atts is None:
            x = inp
            h_new = gru_cell(params, h, x)
        else:
            x, a = inp
            h_new = gru_cell(params, h, x, a)
        return h_new, h_new
    seq = jnp.swapaxes(xs, 0, 1)
    if atts is None:
        h_last, hs = jax.lax.scan(body, h0, seq)
    else:
        a_seq = jnp.swapaxes(atts, 0, 1)
        h_last, hs = jax.lax.scan(body, h0, (seq, a_seq))
    return jnp.swapaxes(hs, 0, 1), h_last
