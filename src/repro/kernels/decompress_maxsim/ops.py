"""Public wrapper for the fused decompress+MaxSim kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.utils import round_up
from repro.kernels.decompress_maxsim.decompress_maxsim import (
    decompress_maxsim_pallas,
    decompress_maxsim_pallas_batch,
)
from repro.kernels.decompress_maxsim.ref import (decompress_maxsim_batch_ref,
                                                 decompress_maxsim_ref)


@functools.partial(jax.jit,
                   static_argnames=("nbits", "impl", "block_c", "gather"))
def decompress_maxsim_scores(q, packed, cids, doc_valid, centroids,
                             bucket_weights, *, nbits: int,
                             q_valid=None, impl: str = "auto",
                             block_c: int = 16, gather: str = "take"):
    """Fused scoring over compressed candidates.

    q: (Lq, d); packed: (C, Ld, d·nbits/8) uint8; cids: (C, Ld) int32;
    doc_valid: (C, Ld) bool → (C,) f32 scores.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if q_valid is None:
        q_valid = jnp.ones((q.shape[0],), bool)
    if impl == "ref":
        return decompress_maxsim_ref(q, packed, cids, doc_valid, centroids,
                                     bucket_weights, nbits, q_valid)

    C = packed.shape[0]
    Cp = round_up(max(C, 1), block_c)
    if Cp != C:
        packed = jnp.pad(packed, ((0, Cp - C), (0, 0), (0, 0)))
        cids = jnp.pad(cids, ((0, Cp - C), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, Cp - C), (0, 0)))
    out = decompress_maxsim_pallas(
        q.astype(jnp.float32), packed, cids.astype(jnp.int32),
        doc_valid.astype(jnp.int8), q_valid.astype(jnp.int8),
        centroids.astype(jnp.float32), bucket_weights.astype(jnp.float32),
        nbits=nbits, block_c=block_c, gather=gather,
        interpret=(impl == "interpret"))
    return out[:C]


@functools.partial(jax.jit,
                   static_argnames=("nbits", "impl", "block_c", "gather"))
def decompress_maxsim_scores_batch(q, packed, cids, doc_valid, centroids,
                                   bucket_weights, *, nbits: int,
                                   q_valid=None, impl: str = "auto",
                                   block_c: int = 16, gather: str = "take"):
    """Cross-query batched fused scoring (the stage-4 batch dispatch).

    q: (B, Lq, d); packed: (B, C, Ld, d·nbits/8) uint8; cids: (B, C, Ld)
    int32; doc_valid: (B, C, Ld) bool; q_valid: optional (B, Lq) bool
    (False on padded query tokens) → (B, C) f32 scores.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if q_valid is None:
        q_valid = jnp.ones(q.shape[:2], bool)
    if impl == "ref":
        return decompress_maxsim_batch_ref(q, packed, cids, doc_valid,
                                           centroids, bucket_weights, nbits,
                                           q_valid)

    B, C = packed.shape[:2]
    Cp = round_up(max(C, 1), block_c)
    if Cp != C:
        packed = jnp.pad(packed, ((0, 0), (0, Cp - C), (0, 0), (0, 0)))
        cids = jnp.pad(cids, ((0, 0), (0, Cp - C), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, 0), (0, Cp - C), (0, 0)))
    out = decompress_maxsim_pallas_batch(
        q.astype(jnp.float32), packed, cids.astype(jnp.int32),
        doc_valid.astype(jnp.int8), q_valid.astype(jnp.int8),
        centroids.astype(jnp.float32), bucket_weights.astype(jnp.float32),
        nbits=nbits, block_c=block_c, gather=gather,
        interpret=(impl == "interpret"))
    return out[:, :C]
