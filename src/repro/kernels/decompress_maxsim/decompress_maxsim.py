"""Pallas TPU kernel: fused residual decompression + MaxSim.

This is the TPU-native adaptation of the paper's memory-mapping insight.
On the CPU system, mmap avoids materialising the index in RAM; on TPU
the equivalent waste is materialising *decompressed fp32 embeddings* in
HBM between a decompression op and a scoring op. The fusion keeps the
decompressed tile strictly in VMEM:

  HBM traffic per doc token:  packed codes (d·nbits/8 = 64 B at 4-bit)
                              + centroid id (4 B) + valid (1 B)
  vs. unfused:                + fp32 embedding write+read (2·512 B)

  ⇒ ~16× less HBM traffic for the scoring stage, turning a memory-bound
  pipeline into an MXU-bound one (see benchmarks/bench_kernels.py).

Centroid rows are fetched from a VMEM-resident table — valid for tables
up to ~4 K centroids (2 MiB at d=128); larger tables take the
``gather='onehot'`` strategy (MXU one-hot matmul over K-tiles, always
lowerable) or fall back to the unfused path. Both strategies are
validated against the oracle in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _decode_tile(packed, cids, centroids, weights_oh, nbits, gather):
    """packed (T, d/cpb) u8, cids (T,) i32 → emb (T, d) f32 in-VMEM."""
    cpb = 8 // nbits
    mask = (1 << nbits) - 1
    shifts = (jnp.arange(cpb, dtype=jnp.uint8) * nbits)
    codes = (packed[..., None] >> shifts) & jnp.uint8(mask)
    T = packed.shape[0]
    codes = codes.reshape(T, packed.shape[1] * cpb)          # (T, d)

    # bucket LUT via one-hot (16-wide — trivial on the VPU/MXU)
    n_buckets = 1 << nbits
    oh = (codes[..., None] == jnp.arange(n_buckets, dtype=jnp.uint8)
          ).astype(jnp.float32)                              # (T, d, 2^b)
    res = jax.lax.dot_general(
        oh.reshape(-1, n_buckets), weights_oh,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(T, -1)   # (T, d)

    K = centroids.shape[0]
    if gather == "take":
        base = jnp.take(centroids, cids, axis=0)             # (T, d)
    else:  # onehot gather on the MXU — always lowerable
        coh = (cids[:, None] == jnp.arange(K, dtype=jnp.int32)
               ).astype(jnp.float32)                         # (T, K)
        base = jax.lax.dot_general(coh, centroids,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    return base + res


def _score_tile(q, packed, cids, valid, qv, centroids, weights, nbits,
                gather):
    """Shared kernel body: decode one (BC, Ld) tile in-VMEM and score it.
    q (Lq, d); packed (BC, Ld, d/cpb); cids/valid (BC, Ld); qv (Lq,);
    centroids (K, d); weights (2^nbits,) → (BC,) f32."""
    bc, ld = cids.shape
    emb = _decode_tile(packed.reshape(bc * ld, -1), cids.reshape(-1),
                       centroids, weights, nbits, gather)     # (BC·Ld, d)

    s = jax.lax.dot_general(q, emb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(q.shape[0], bc, ld)
    s = jnp.where(valid[None] != 0, s, NEG)
    per_q = jnp.max(s, axis=-1)
    per_q = jnp.where(per_q <= NEG / 2, 0.0, per_q)
    per_q = per_q * (qv[:, None] != 0).astype(per_q.dtype)
    return jnp.sum(per_q, axis=0)


def _kernel(q_ref, packed_ref, cids_ref, valid_ref, qvalid_ref,
            centroids_ref, weights_ref, out_ref, *, nbits, gather):
    out_ref[...] = _score_tile(q_ref[...], packed_ref[...], cids_ref[...],
                               valid_ref[...], qvalid_ref[...],
                               centroids_ref[...], weights_ref[...],
                               nbits, gather)


def _batch_kernel(q_ref, packed_ref, cids_ref, valid_ref, qvalid_ref,
                  centroids_ref, weights_ref, out_ref, *, nbits, gather):
    # leading grid axis walks the query batch; centroid/bucket tables
    # stay batch-invariant VMEM residents
    out_ref[0, :] = _score_tile(q_ref[0], packed_ref[0], cids_ref[0],
                                valid_ref[0], qvalid_ref[0],
                                centroids_ref[...], weights_ref[...],
                                nbits, gather)


@functools.partial(jax.jit,
                   static_argnames=("nbits", "block_c", "gather", "interpret"))
def decompress_maxsim_pallas(q, packed, cids, valid, q_valid, centroids,
                             bucket_weights, *, nbits: int, block_c: int = 16,
                             gather: str = "take", interpret: bool = False):
    C, Ld, pd = packed.shape
    Lq, d = q.shape
    K = centroids.shape[0]
    assert C % block_c == 0
    grid = (C // block_c,)
    kernel = functools.partial(_kernel, nbits=nbits, gather=gather)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Lq, d), lambda i: (0, 0)),
            pl.BlockSpec((block_c, Ld, pd), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((Lq,), lambda i: (0,)),
            pl.BlockSpec((K, d), lambda i: (0, 0)),      # whole table
            pl.BlockSpec((1 << nbits,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(q, packed, cids, valid, q_valid, centroids, bucket_weights)


@functools.partial(jax.jit,
                   static_argnames=("nbits", "block_c", "gather", "interpret"))
def decompress_maxsim_pallas_batch(q, packed, cids, valid, q_valid,
                                   centroids, bucket_weights, *, nbits: int,
                                   block_c: int = 16, gather: str = "take",
                                   interpret: bool = False):
    """Batched fused scoring: q (B, Lq, d); packed (B, C, Ld, pd);
    cids/valid (B, C, Ld); q_valid (B, Lq) → (B, C). The whole batch is
    one kernel launch — stage 4 scores B queries in one dispatch."""
    B, C, Ld, pd = packed.shape
    Lq, d = q.shape[1:]
    K = centroids.shape[0]
    assert C % block_c == 0
    grid = (B, C // block_c)
    kernel = functools.partial(_batch_kernel, nbits=nbits, gather=gather)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_c, Ld, pd), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, block_c, Ld), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_c, Ld), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Lq), lambda b, i: (b, 0)),
            pl.BlockSpec((K, d), lambda b, i: (0, 0)),   # whole table
            pl.BlockSpec((1 << nbits,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(q, packed, cids, valid, q_valid, centroids, bucket_weights)
