"""Pure-jnp oracle: residual decompression followed by MaxSim."""

import functools

import jax
import jax.numpy as jnp

from repro.index.residual import unpack_codes
from repro.kernels.maxsim.ref import maxsim_scores_ref


def decompress_maxsim_ref(q, packed, cids, doc_valid, centroids,
                          bucket_weights, nbits, q_valid=None):
    """q: (Lq, d); packed: (C, Ld, d·nbits/8) uint8; cids: (C, Ld) int32;
    doc_valid: (C, Ld) bool; centroids: (K, d); bucket_weights: (2^nbits,)
    → scores (C,) f32 — identical to decompress-then-maxsim."""
    codes = unpack_codes(packed, nbits)
    emb = centroids[cids] + bucket_weights[codes.astype(jnp.int32)]
    emb = emb * doc_valid[..., None]
    return maxsim_scores_ref(q, emb, doc_valid, q_valid)


def decompress_maxsim_batch_ref(q, packed, cids, doc_valid, centroids,
                                bucket_weights, nbits, q_valid=None):
    """Leading-batch-dim oracle: q (B, Lq, d); packed (B, C, Ld, pd);
    cids/doc_valid (B, C, Ld); q_valid optional (B, Lq) → (B, C) f32."""
    fn = functools.partial(decompress_maxsim_ref, nbits=nbits)
    if q_valid is None:
        return jax.vmap(lambda a, b, c, d: fn(a, b, c, d, centroids,
                                              bucket_weights))(
            q, packed, cids, doc_valid)
    return jax.vmap(lambda a, b, c, d, e: fn(a, b, c, d, centroids,
                                             bucket_weights, q_valid=e))(
        q, packed, cids, doc_valid, q_valid)
