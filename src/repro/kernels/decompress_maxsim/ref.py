"""Pure-jnp oracle: residual decompression followed by MaxSim."""

import jax.numpy as jnp

from repro.index.residual import unpack_codes
from repro.kernels.maxsim.ref import maxsim_scores_ref


def decompress_maxsim_ref(q, packed, cids, doc_valid, centroids,
                          bucket_weights, nbits, q_valid=None):
    """q: (Lq, d); packed: (C, Ld, d·nbits/8) uint8; cids: (C, Ld) int32;
    doc_valid: (C, Ld) bool; centroids: (K, d); bucket_weights: (2^nbits,)
    → scores (C,) f32 — identical to decompress-then-maxsim."""
    codes = unpack_codes(packed, nbits)
    emb = centroids[cids] + bucket_weights[codes.astype(jnp.int32)]
    emb = emb * doc_valid[..., None]
    return maxsim_scores_ref(q, emb, doc_valid, q_valid)
