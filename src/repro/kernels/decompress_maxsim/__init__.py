from repro.kernels.decompress_maxsim.ops import (
    decompress_maxsim_scores,
    decompress_maxsim_scores_batch,
)
from repro.kernels.decompress_maxsim.ref import (decompress_maxsim_batch_ref,
                                                 decompress_maxsim_ref)

__all__ = ["decompress_maxsim_scores", "decompress_maxsim_scores_batch",
           "decompress_maxsim_ref", "decompress_maxsim_batch_ref"]
