from repro.kernels.splade_score.ops import splade_block_scores
from repro.kernels.splade_score.ref import splade_block_scores_ref

__all__ = ["splade_block_scores", "splade_block_scores_ref"]
