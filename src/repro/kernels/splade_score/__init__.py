from repro.kernels.splade_score.ops import (splade_block_scores,
                                            splade_block_scores_batch,
                                            splade_block_topk_batch)
from repro.kernels.splade_score.ref import (splade_block_scores_batch_ref,
                                            splade_block_scores_ref)

__all__ = ["splade_block_scores", "splade_block_scores_batch",
           "splade_block_topk_batch", "splade_block_scores_ref",
           "splade_block_scores_batch_ref"]
