"""Pallas TPU kernel: block-partitioned impact scoring (the PISA
adaptation).

PISA's WAND-style scoring is pointer-chasing over compressed posting
lists — hostile to a vector unit. The TPU-native re-think partitions
the *score vector* over a grid of doc-id blocks; each grid step scans
every (query-term, posting) pair once and accumulates the entries whose
pid falls inside its block. The scatter becomes a dense one-hot matmul
on the MXU:

    scores[lo:hi] += wᵀ · onehot(pid − lo)     (E × BD one-hot panel)

Posting entries stream through VMEM in chunks so the one-hot panel is
bounded (chunk × BD fp32 ≤ 4 MiB by default). Work per block is
O(E · BD) MACs — embarrassingly parallel over blocks, no data-dependent
control flow, and the block grid is how the score vector shards over
the 'model' mesh axis in the distributed serve path.

The batched variant adds a leading batch axis to the grid (one kernel
launch scores the whole micro-batch): each (b, i) step owns query b's
postings and doc block i, so cross-query batches cost one dispatch
instead of B.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_block(pids, vals, lo, *, block_d: int, chunk: int):
    """Shared tile body: accumulate postings into one doc-id block.

    pids: (E,) int32 (−1 padded); vals: (E,) f32 (w_t · imp, 0 padded);
    lo: first pid of this block → (block_d,) f32 partial scores."""
    E = pids.shape[0]
    local = pids - lo
    acc = jnp.zeros((block_d,), jnp.float32)
    iota = jax.lax.iota(jnp.int32, block_d)
    for c in range(E // chunk):
        lc = jax.lax.dynamic_slice(local, (c * chunk,), (chunk,))
        vc = jax.lax.dynamic_slice(vals, (c * chunk,), (chunk,))
        oh = (lc[:, None] == iota[None, :]).astype(jnp.float32)  # (chunk, BD)
        acc = acc + jax.lax.dot_general(
            vc, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return acc


def _kernel(pids_ref, vals_ref, out_ref, *, block_d: int, chunk: int):
    i = pl.program_id(0)
    out_ref[...] = _score_block(pids_ref[...].reshape(-1),
                                vals_ref[...].reshape(-1), i * block_d,
                                block_d=block_d, chunk=chunk)


def _batch_kernel(pids_ref, vals_ref, out_ref, *, block_d: int, chunk: int):
    # grid (B, n_blocks): axis 0 walks the query batch, axis 1 the doc-id
    # blocks; blocks carry a size-1 batch dim squeezed before the body
    i = pl.program_id(1)
    out_ref[0, :] = _score_block(pids_ref[0].reshape(-1),
                                 vals_ref[0].reshape(-1), i * block_d,
                                 block_d=block_d, chunk=chunk)


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "block_d", "chunk", "interpret"))
def splade_block_pallas(post_pids, post_vals, *, n_docs: int,
                        block_d: int = 2048, chunk: int = 512,
                        interpret: bool = False):
    """post_pids: (Qt, max_df) int32; post_vals: (Qt, max_df) f32 (weight
    pre-multiplied, 0 at padding). Returns (n_docs_padded,) f32 scores;
    caller slices [:n_docs]."""
    Qt, max_df = post_pids.shape
    E = Qt * max_df
    assert E % chunk == 0, (E, chunk)
    n_blocks = -(-n_docs // block_d)
    kernel = functools.partial(_kernel, block_d=block_d, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((Qt, max_df), lambda i: (0, 0)),   # postings resident
            pl.BlockSpec((Qt, max_df), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_d,), jnp.float32),
        interpret=interpret,
    )(post_pids, post_vals)


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "block_d", "chunk", "interpret"))
def splade_block_pallas_batch(post_pids, post_vals, *, n_docs: int,
                              block_d: int = 2048, chunk: int = 512,
                              interpret: bool = False):
    """Batched stage-1 dispatch: post_pids (B, Qt, max_df) int32;
    post_vals (B, Qt, max_df) f32 → (B, n_docs_padded) f32; caller
    slices [:, :n_docs]. One kernel launch for the whole micro-batch."""
    B, Qt, max_df = post_pids.shape
    E = Qt * max_df
    assert E % chunk == 0, (E, chunk)
    n_blocks = -(-n_docs // block_d)
    kernel = functools.partial(_batch_kernel, block_d=block_d, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, Qt, max_df), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Qt, max_df), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, n_blocks * block_d), jnp.float32),
        interpret=interpret,
    )(post_pids, post_vals)
