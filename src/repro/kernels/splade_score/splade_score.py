"""Pallas TPU kernel: block-partitioned impact scoring (the PISA
adaptation).

PISA's WAND-style scoring is pointer-chasing over compressed posting
lists — hostile to a vector unit. The TPU-native re-think partitions
the *score vector* over a grid of doc-id blocks; each grid step scans
every (query-term, posting) pair once and accumulates the entries whose
pid falls inside its block. The scatter becomes a dense one-hot matmul
on the MXU:

    scores[lo:hi] += wᵀ · onehot(pid − lo)     (E × BD one-hot panel)

Posting entries stream through VMEM in chunks so the one-hot panel is
bounded (chunk × BD fp32 ≤ 4 MiB by default). Work per block is
O(E · BD) MACs — embarrassingly parallel over blocks, no data-dependent
control flow, and the block grid is how the score vector shards over
the 'model' mesh axis in the distributed serve path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pids_ref, vals_ref, out_ref, *, block_d: int, chunk: int):
    i = pl.program_id(0)
    lo = i * block_d
    pids = pids_ref[...].reshape(-1)       # (E,) int32, −1 padded
    vals = vals_ref[...].reshape(-1)       # (E,) f32 (w_t · imp, 0 padded)
    E = pids.shape[0]

    local = pids - lo
    acc = jnp.zeros((block_d,), jnp.float32)
    iota = jax.lax.iota(jnp.int32, block_d)
    for c in range(E // chunk):
        lc = jax.lax.dynamic_slice(local, (c * chunk,), (chunk,))
        vc = jax.lax.dynamic_slice(vals, (c * chunk,), (chunk,))
        oh = (lc[:, None] == iota[None, :]).astype(jnp.float32)  # (chunk, BD)
        acc = acc + jax.lax.dot_general(
            vc, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    out_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "block_d", "chunk", "interpret"))
def splade_block_pallas(post_pids, post_vals, *, n_docs: int,
                        block_d: int = 2048, chunk: int = 512,
                        interpret: bool = False):
    """post_pids: (Qt, max_df) int32; post_vals: (Qt, max_df) f32 (weight
    pre-multiplied, 0 at padding). Returns (n_docs_padded,) f32 scores;
    caller slices [:n_docs]."""
    Qt, max_df = post_pids.shape
    E = Qt * max_df
    assert E % chunk == 0, (E, chunk)
    n_blocks = -(-n_docs // block_d)
    kernel = functools.partial(_kernel, block_d=block_d, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((Qt, max_df), lambda i: (0, 0)),   # postings resident
            pl.BlockSpec((Qt, max_df), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_d,), jnp.float32),
        interpret=interpret,
    )(post_pids, post_vals)
