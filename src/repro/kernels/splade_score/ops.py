"""Public wrappers for the SPLADE block-scoring kernel (single-query
and leading-batch-dim variants, plus a fused scores→top-k entry point
for the serving stage-1 path)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.utils import round_up
from repro.kernels.splade_score.ref import (splade_block_scores_batch_ref,
                                            splade_block_scores_ref)
from repro.kernels.splade_score.splade_score import (splade_block_pallas,
                                                     splade_block_pallas_batch)


def _chunked(pids, vals, chunk: int):
    """Reshape (…, Qt, max_df) postings into chunk-aligned rows, padding
    the entry count up to a multiple of ``chunk`` with −1/0 entries."""
    *lead, Qt, max_df = pids.shape
    E = Qt * max_df
    Ep = round_up(E, chunk)
    if Ep == E:
        return pids, vals
    pad_rows = (Ep - E) // max_df + 1
    pad_width = [(0, 0)] * len(lead) + [(0, pad_rows), (0, 0)]
    pids = jnp.pad(pids, pad_width, constant_values=-1)
    vals = jnp.pad(vals, pad_width)
    pids = pids.reshape(*lead, -1)[..., :Ep].reshape(*lead, -1, chunk)
    vals = vals.reshape(*lead, -1)[..., :Ep].reshape(*lead, -1, chunk)
    return pids, vals


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "impl", "block_d", "chunk"))
def splade_block_scores(post_pids, post_imps, term_weights, *, n_docs: int,
                        impl: str = "auto", block_d: int = 2048,
                        chunk: int = 512):
    """Impact scores for one query over padded postings → (n_docs,) f32."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return splade_block_scores_ref(post_pids, post_imps, term_weights,
                                       n_docs)
    valid = (post_pids >= 0) & (term_weights[:, None] > 0)  # match ref mask
    vals = jnp.where(valid, term_weights[:, None] * post_imps, 0.0)
    pids = jnp.where(valid, post_pids, -1)
    pids, vals = _chunked(pids, vals, chunk)
    out = splade_block_pallas(pids.astype(jnp.int32),
                              vals.astype(jnp.float32),
                              n_docs=n_docs, block_d=block_d, chunk=chunk,
                              interpret=(impl == "interpret"))
    return out[:n_docs]


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "impl", "block_d", "chunk"))
def splade_block_scores_batch(post_pids, post_imps, term_weights, *,
                              n_docs: int, impl: str = "auto",
                              block_d: int = 2048, chunk: int = 512):
    """Cross-query batched impact scores.

    post_pids: (B, Qt, max_df) int32 (−1 pad); post_imps: (B, Qt, max_df)
    f32 (de-quantised); term_weights: (B, Qt) f32 (0 disables a term)
    → (B, n_docs) f32. One dispatch for the whole batch.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return splade_block_scores_batch_ref(post_pids, post_imps,
                                             term_weights, n_docs)
    valid = (post_pids >= 0) & (term_weights[:, :, None] > 0)
    vals = jnp.where(valid, term_weights[:, :, None] * post_imps, 0.0)
    pids = jnp.where(valid, post_pids, -1)
    pids, vals = _chunked(pids, vals, chunk)
    out = splade_block_pallas_batch(pids.astype(jnp.int32),
                                    vals.astype(jnp.float32),
                                    n_docs=n_docs, block_d=block_d,
                                    chunk=chunk,
                                    interpret=(impl == "interpret"))
    return out[:, :n_docs]


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "k", "impl", "block_d",
                                    "chunk"))
def splade_block_topk_batch(post_pids, post_imps, term_weights, *,
                            n_docs: int, k: int, impl: str = "auto",
                            block_d: int = 2048, chunk: int = 512):
    """Fused stage-1 dispatch: batched block scoring + per-query top-k in
    one jitted computation → (pids (B, k) int32, scores (B, k) f32),
    descending. ``k`` must be ≤ ``n_docs`` (caller clamps/pads)."""
    scores = splade_block_scores_batch(post_pids, post_imps, term_weights,
                                       n_docs=n_docs, impl=impl,
                                       block_d=block_d, chunk=chunk)
    top_scores, top_pids = jax.lax.top_k(scores, k)
    return top_pids.astype(jnp.int32), top_scores
