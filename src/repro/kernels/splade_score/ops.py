"""Public wrapper for the SPLADE block-scoring kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.utils import round_up
from repro.kernels.splade_score.ref import splade_block_scores_ref
from repro.kernels.splade_score.splade_score import splade_block_pallas


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "impl", "block_d", "chunk"))
def splade_block_scores(post_pids, post_imps, term_weights, *, n_docs: int,
                        impl: str = "auto", block_d: int = 2048,
                        chunk: int = 512):
    """Impact scores for one query over padded postings → (n_docs,) f32."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return splade_block_scores_ref(post_pids, post_imps, term_weights,
                                       n_docs)
    Qt, max_df = post_pids.shape
    vals = jnp.where(post_pids >= 0,
                     term_weights[:, None] * post_imps, 0.0)
    pids = jnp.where(post_pids >= 0, post_pids, -1)
    E = Qt * max_df
    Ep = round_up(E, chunk)
    if Ep != E:
        pad_rows = (Ep - E) // max_df + 1
        pids = jnp.pad(pids, ((0, pad_rows), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, pad_rows), (0, 0)))
        pids = pids.reshape(-1)[:Ep].reshape(-1, chunk)
        vals = vals.reshape(-1)[:Ep].reshape(-1, chunk)
    out = splade_block_pallas(pids.astype(jnp.int32),
                              vals.astype(jnp.float32),
                              n_docs=n_docs, block_d=block_d, chunk=chunk,
                              interpret=(impl == "interpret"))
    return out[:n_docs]
