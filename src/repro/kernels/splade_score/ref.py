"""Pure-jnp oracle for impact scoring over padded postings."""

import jax
import jax.numpy as jnp


def splade_block_scores_ref(post_pids, post_imps, term_weights, n_docs: int):
    """post_pids: (Qt, max_df) int32 (−1 pad); post_imps: (Qt, max_df)
    float32 (already de-quantised); term_weights: (Qt,) float32
    → scores (n_docs,) f32: scores[p] = Σ_t w_t · imp_{t,p}."""
    valid = (post_pids >= 0) & (term_weights[:, None] > 0)
    seg = jnp.where(valid, post_pids, n_docs).reshape(-1)
    vals = jnp.where(valid, term_weights[:, None] * post_imps, 0.0).reshape(-1)
    return jax.ops.segment_sum(vals, seg, num_segments=n_docs + 1)[:n_docs]


def splade_block_scores_batch_ref(post_pids, post_imps, term_weights,
                                  n_docs: int):
    """Batched oracle: post_pids/post_imps (B, Qt, max_df);
    term_weights (B, Qt) → (B, n_docs) f32 — one segment-sum per query,
    vmapped so the whole batch is a single XLA computation."""
    return jax.vmap(
        lambda p, i, w: splade_block_scores_ref(p, i, w, n_docs)
    )(post_pids, post_imps, term_weights)
