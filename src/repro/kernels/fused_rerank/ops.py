"""Public wrapper for the fused decompress+MaxSim+top-k rerank tail.

``impl`` selection follows the repo convention: ``auto`` takes the
Pallas kernel on TPU and the fused-XLA reference elsewhere (same fused
semantics, one dispatch either way); ``interpret`` runs the kernel body
Mosaic-free for CI parity. The module degrades gracefully when the
Pallas toolchain is absent (``HAVE_PALLAS``): ``auto`` then always
resolves to the reference and serving falls back to the split tail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.utils import round_up
from repro.kernels.fused_rerank.ref import (
    _pad_topk,
    fused_rerank_batch_ref,
    fused_rerank_ref,
)

try:
    from repro.kernels.fused_rerank.fused_rerank import (
        fused_rerank_pallas,
        fused_rerank_pallas_batch,
    )
    HAVE_PALLAS = True
except Exception:                                    # pragma: no cover
    fused_rerank_pallas = fused_rerank_pallas_batch = None
    HAVE_PALLAS = False


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return ("pallas" if HAVE_PALLAS
                and jax.default_backend() == "tpu" else "ref")
    if impl in ("pallas", "interpret") and not HAVE_PALLAS:
        raise RuntimeError("Pallas unavailable: fused_rerank impl "
                           f"{impl!r} cannot run (use impl='ref')")
    return impl


def _empty_topk(lead, k: int):
    shape = lead + (k,)
    return (jnp.full(shape, -jnp.inf, jnp.float32),
            jnp.full(shape, -1, jnp.int32))


@functools.partial(jax.jit, static_argnames=("nbits", "k", "impl",
                                             "block_c", "gather"))
def fused_rerank_topk(q, packed, cids, doc_valid, cand_mask, centroids,
                      bucket_weights, *, nbits: int, k: int, q_valid=None,
                      impl: str = "auto", block_c: int = 16,
                      gather: str = "take"):
    """Fused rerank tail over one query's compressed candidates.

    q: (Lq, d); packed: (C, Ld, d·nbits/8) uint8; cids: (C, Ld) int32;
    doc_valid: (C, Ld) bool; cand_mask: (C,) bool → (scores (k,) f32
    desc, idx (k,) i32) — exactly ``lax.top_k`` of the -inf-masked
    MaxSim scores, ``(-inf, -1)``-padded when ``k > C``.
    """
    impl = _resolve_impl(impl)
    C = packed.shape[0]
    kk = min(k, C)
    if kk == 0:
        return _empty_topk((), k)
    if q_valid is None:
        q_valid = jnp.ones((q.shape[0],), bool)
    if impl == "ref":
        return fused_rerank_ref(q, packed, cids, doc_valid, cand_mask,
                                centroids, bucket_weights, nbits, k,
                                q_valid)

    Cp = round_up(C, block_c)
    if Cp != C:
        packed = jnp.pad(packed, ((0, Cp - C), (0, 0), (0, 0)))
        cids = jnp.pad(cids, ((0, Cp - C), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, Cp - C), (0, 0)))
        cand_mask = jnp.pad(cand_mask, ((0, Cp - C),))
    # running-state width padded for lane alignment; the top-kk prefix
    # of a top-kp selection is the top-kk selection, so slicing is exact
    kp = min(round_up(kk, 8), Cp)
    vals, idx = fused_rerank_pallas(
        q.astype(jnp.float32), packed, cids.astype(jnp.int32),
        doc_valid.astype(jnp.int8), cand_mask.astype(jnp.int8),
        q_valid.astype(jnp.int8), centroids.astype(jnp.float32),
        bucket_weights.astype(jnp.float32), nbits=nbits, kp=kp,
        block_c=block_c, gather=gather, interpret=(impl == "interpret"))
    return _pad_topk(vals[:kk], idx[:kk], k)


@functools.partial(jax.jit, static_argnames=("nbits", "k", "impl",
                                             "block_c", "gather"))
def fused_rerank_topk_batch(q, packed, cids, doc_valid, cand_mask,
                            centroids, bucket_weights, *, nbits: int,
                            k: int, q_valid=None, impl: str = "auto",
                            block_c: int = 16, gather: str = "take"):
    """Cross-query batched fused tail — the stage-4 single dispatch.

    q: (B, Lq, d); packed: (B, C, Ld, d·nbits/8) uint8; cids/doc_valid:
    (B, C, Ld); cand_mask: (B, C) bool; q_valid: optional (B, Lq) bool
    → (scores (B, k) f32 desc, idx (B, k) i32 into the candidate axis).
    """
    impl = _resolve_impl(impl)
    B, C = packed.shape[:2]
    kk = min(k, C)
    if kk == 0:
        return _empty_topk((B,), k)
    if q_valid is None:
        q_valid = jnp.ones(q.shape[:2], bool)
    if impl == "ref":
        return fused_rerank_batch_ref(q, packed, cids, doc_valid,
                                      cand_mask, centroids,
                                      bucket_weights, nbits, k, q_valid)

    Cp = round_up(C, block_c)
    if Cp != C:
        packed = jnp.pad(packed, ((0, 0), (0, Cp - C), (0, 0), (0, 0)))
        cids = jnp.pad(cids, ((0, 0), (0, Cp - C), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, 0), (0, Cp - C), (0, 0)))
        cand_mask = jnp.pad(cand_mask, ((0, 0), (0, Cp - C)))
    kp = min(round_up(kk, 8), Cp)
    vals, idx = fused_rerank_pallas_batch(
        q.astype(jnp.float32), packed, cids.astype(jnp.int32),
        doc_valid.astype(jnp.int8), cand_mask.astype(jnp.int8),
        q_valid.astype(jnp.int8), centroids.astype(jnp.float32),
        bucket_weights.astype(jnp.float32), nbits=nbits, kp=kp,
        block_c=block_c, gather=gather, interpret=(impl == "interpret"))
    return _pad_topk(vals[:, :kk], idx[:, :kk], k)
