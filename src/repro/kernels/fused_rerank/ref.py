"""Oracle for the fused rerank tail: decompress → MaxSim → stable top-k.

The contract every implementation must honour bitwise:

    masked = where(cand_mask, maxsim(decompress(packed)), -inf)
    top-k by (score desc, candidate index asc)      # lax.top_k ties

i.e. exactly the split path (``decompress_maxsim`` scores, ``-inf`` at
masked candidates, then ``lax.top_k`` / a stable host argsort — both
break score ties toward the lower candidate index). When ``k`` exceeds
the candidate count the tail is padded with ``(-inf, -1)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decompress_maxsim.ref import (
    decompress_maxsim_batch_ref,
    decompress_maxsim_ref,
)


def _pad_topk(vals, idx, k: int):
    kk = vals.shape[-1]
    if kk == k:
        return vals, idx.astype(jnp.int32)
    pad = [(0, 0)] * (vals.ndim - 1) + [(0, k - kk)]
    vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
    idx = jnp.pad(idx.astype(jnp.int32), pad, constant_values=-1)
    return vals, idx


def fused_rerank_ref(q, packed, cids, doc_valid, cand_mask, centroids,
                     bucket_weights, nbits: int, k: int, q_valid=None):
    """q (Lq, d); packed (C, Ld, d·nbits/8) u8; cids/doc_valid (C, Ld);
    cand_mask (C,) bool (False = padded candidate slot) →
    (scores (k,) f32 desc, idx (k,) i32 into the candidate axis)."""
    C = cids.shape[0]
    kk = min(k, C)
    if kk == 0:
        return (jnp.full((k,), -jnp.inf, jnp.float32),
                jnp.full((k,), -1, jnp.int32))
    scores = decompress_maxsim_ref(q, packed, cids, doc_valid, centroids,
                                   bucket_weights, nbits, q_valid)
    masked = jnp.where(cand_mask, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, kk)
    return _pad_topk(vals, idx, k)


def fused_rerank_batch_ref(q, packed, cids, doc_valid, cand_mask, centroids,
                           bucket_weights, nbits: int, k: int, q_valid=None):
    """Leading-batch-dim oracle: q (B, Lq, d); packed (B, C, Ld, pd);
    cids/doc_valid (B, C, Ld); cand_mask (B, C) →
    (scores (B, k), idx (B, k)).

    Scores come from the *same* batched reference the split path runs
    (``decompress_maxsim_batch_ref``), with masking and ``lax.top_k``
    applied at the batch level — the exact computation graph whose
    composition is bitwise-stable against the split dispatches."""
    B, C = cids.shape[:2]
    kk = min(k, C)
    if kk == 0:
        return (jnp.full((B, k), -jnp.inf, jnp.float32),
                jnp.full((B, k), -1, jnp.int32))
    scores = decompress_maxsim_batch_ref(q, packed, cids, doc_valid,
                                         centroids, bucket_weights, nbits,
                                         q_valid)
    masked = jnp.where(cand_mask, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, kk)
    return _pad_topk(vals, idx, k)
