"""Fused IO-aware rerank tail: decompress + MaxSim + per-query top-k
in one tiled dispatch (FLASH-MAXSIM-style; see fused_rerank.py)."""

from repro.kernels.fused_rerank.ops import (  # noqa: F401
    HAVE_PALLAS,
    fused_rerank_topk,
    fused_rerank_topk_batch,
)
