"""Pallas TPU kernel: fused decompress + MaxSim + running per-query
top-k over candidate tiles — the FLASH-MAXSIM-style rerank tail.

The split stage-4 tail runs three dispatches (decompress+MaxSim scores,
score masking, top-k selection) and materialises the full ``(B, C)``
score tensor — and, unfused, the ``(B, C, Lq, Ld)`` similarity tensor —
in HBM between them. This kernel streams packed residual codes through
VMEM one candidate tile at a time, decompresses against the
VMEM-resident centroid table in-register (``_decode_tile``), scores the
tile (``_score_tile``, shared with ``decompress_maxsim`` so the fused
and split paths compute *identical* per-candidate arithmetic), and
folds the tile into a running per-query top-k held in the output block
across grid steps. Nothing wider than one ``(block_c,)`` score slice
ever exists:

  HBM traffic per query:  packed codes + ids + valid   (the tile stream)
                          + 2·k·4 B result             (scores + indices)
  vs. split:              + C·4 B scores write+read + top-k pass

The running top-k merge is *sortless*: each grid step ranks the
``k_pad + block_c`` merged entries by pairwise comparison counts
(rank_j = #{m : (s_m, -i_m) ≻ (s_j, -i_j)}) and gathers entry ``j``
into output slot ``rank_j`` with a masked sum — O(n²) compares on the
VPU with n ≈ 144, no sort lowering required, and the (score desc,
index asc) tie order is exactly ``lax.top_k``'s, so the fused result is
bitwise the split path's. Candidate tiles arrive in ascending index
order and the running entries always carry lower indices than the
incoming tile, which is what makes the incremental merge reproduce the
global stable order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.decompress_maxsim.decompress_maxsim import _score_tile


def _merge_topk(prev_s, prev_i, tile_s, tile_i, kp: int):
    """Rank-selection merge of the running (kp,) state with a scored
    tile: top-``kp`` of the concatenation by (score desc, index asc).
    All indices are distinct, so ranks are a permutation and the masked
    sums gather exactly one entry per output slot (-inf survives the
    where-sum; no -inf·0 NaNs)."""
    ms = jnp.concatenate([prev_s, tile_s])
    mi = jnp.concatenate([prev_i, tile_i])
    beats = (ms[None, :] > ms[:, None]) | (
        (ms[None, :] == ms[:, None]) & (mi[None, :] < mi[:, None]))
    rank = jnp.sum(beats.astype(jnp.int32), axis=1)          # (n,)
    sel = rank[None, :] == jnp.arange(kp, dtype=jnp.int32)[:, None]
    out_s = jnp.sum(jnp.where(sel, ms[None, :], 0.0), axis=1)
    out_i = jnp.sum(jnp.where(sel, mi[None, :], 0), axis=1)
    return out_s, out_i


def _tile_state(step, s, prev_s, prev_i, cmask, kp: int, block_c: int,
                total_c: int):
    """One grid step of the running top-k: mask the tile's scores, give
    each entry its global candidate index, and merge with the carried
    state. ``step == 0`` replaces the (uninitialised) carried state with
    sentinels that lose every comparison: -inf scores with indices past
    ``total_c``, so real candidates — even masked ones, which tie at
    -inf but carry lower indices — always displace them."""
    s = jnp.where(cmask != 0, s, -jnp.inf)
    tile_i = step * block_c + jnp.arange(block_c, dtype=jnp.int32)
    first = step == 0
    prev_s = jnp.where(first, -jnp.inf, prev_s)
    prev_i = jnp.where(first,
                       total_c + jnp.arange(kp, dtype=jnp.int32), prev_i)
    return _merge_topk(prev_s, prev_i, s, tile_i, kp)


def _kernel(q_ref, packed_ref, cids_ref, valid_ref, cmask_ref, qvalid_ref,
            centroids_ref, weights_ref, out_s_ref, out_i_ref, *,
            nbits, gather, kp, block_c):
    i = pl.program_id(0)
    s = _score_tile(q_ref[...], packed_ref[...], cids_ref[...],
                    valid_ref[...], qvalid_ref[...], centroids_ref[...],
                    weights_ref[...], nbits, gather)
    out_s_ref[...], out_i_ref[...] = _tile_state(
        i, s, out_s_ref[...], out_i_ref[...], cmask_ref[...], kp,
        block_c, pl.num_programs(0) * block_c)


def _batch_kernel(q_ref, packed_ref, cids_ref, valid_ref, cmask_ref,
                  qvalid_ref, centroids_ref, weights_ref, out_s_ref,
                  out_i_ref, *, nbits, gather, kp, block_c):
    # grid (B, C//block_c): for a fixed batch row the candidate tiles
    # run consecutively, so the (1, kp) output block stays VMEM-resident
    # as the running top-k state across the whole row
    i = pl.program_id(1)
    s = _score_tile(q_ref[0], packed_ref[0], cids_ref[0], valid_ref[0],
                    qvalid_ref[0], centroids_ref[...], weights_ref[...],
                    nbits, gather)
    out_s_ref[0, :], out_i_ref[0, :] = _tile_state(
        i, s, out_s_ref[0, :], out_i_ref[0, :], cmask_ref[0], kp,
        block_c, pl.num_programs(1) * block_c)


@functools.partial(jax.jit, static_argnames=("nbits", "kp", "block_c",
                                             "gather", "interpret"))
def fused_rerank_pallas(q, packed, cids, valid, cmask, q_valid, centroids,
                        bucket_weights, *, nbits: int, kp: int,
                        block_c: int = 16, gather: str = "take",
                        interpret: bool = False):
    """Single-query fused tail: q (Lq, d); packed (C, Ld, pd) u8;
    cids/valid (C, Ld); cmask (C,) i8 → (scores (kp,), idx (kp,) i32),
    the top-``kp`` of the masked MaxSim scores in (desc, index-asc)
    order. Requires ``C % block_c == 0`` and ``kp <= C``."""
    C, Ld, pd = packed.shape
    Lq, d = q.shape
    K = centroids.shape[0]
    assert C % block_c == 0 and 0 < kp <= C
    grid = (C // block_c,)
    kernel = functools.partial(_kernel, nbits=nbits, gather=gather,
                               kp=kp, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Lq, d), lambda i: (0, 0)),
            pl.BlockSpec((block_c, Ld, pd), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((Lq,), lambda i: (0,)),
            pl.BlockSpec((K, d), lambda i: (0, 0)),      # whole table
            pl.BlockSpec((1 << nbits,), lambda i: (0,)),
        ],
        out_specs=(pl.BlockSpec((kp,), lambda i: (0,)),
                   pl.BlockSpec((kp,), lambda i: (0,))),
        out_shape=(jax.ShapeDtypeStruct((kp,), jnp.float32),
                   jax.ShapeDtypeStruct((kp,), jnp.int32)),
        interpret=interpret,
    )(q, packed, cids, valid, cmask, q_valid, centroids, bucket_weights)


@functools.partial(jax.jit, static_argnames=("nbits", "kp", "block_c",
                                             "gather", "interpret"))
def fused_rerank_pallas_batch(q, packed, cids, valid, cmask, q_valid,
                              centroids, bucket_weights, *, nbits: int,
                              kp: int, block_c: int = 16,
                              gather: str = "take",
                              interpret: bool = False):
    """Batched fused tail: q (B, Lq, d); packed (B, C, Ld, pd);
    cids/valid (B, C, Ld); cmask (B, C) i8; q_valid (B, Lq) →
    (scores (B, kp), idx (B, kp)). One kernel launch reranks the whole
    micro-batch — the single device dispatch of the fused stage."""
    B, C, Ld, pd = packed.shape
    Lq, d = q.shape[1:]
    K = centroids.shape[0]
    assert C % block_c == 0 and 0 < kp <= C
    grid = (B, C // block_c)
    kernel = functools.partial(_batch_kernel, nbits=nbits, gather=gather,
                               kp=kp, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_c, Ld, pd), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, block_c, Ld), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_c, Ld), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_c), lambda b, i: (b, i)),
            pl.BlockSpec((1, Lq), lambda b, i: (b, 0)),
            pl.BlockSpec((K, d), lambda b, i: (0, 0)),   # whole table
            pl.BlockSpec((1 << nbits,), lambda b, i: (0,)),
        ],
        out_specs=(pl.BlockSpec((1, kp), lambda b, i: (b, 0)),
                   pl.BlockSpec((1, kp), lambda b, i: (b, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, kp), jnp.float32),
                   jax.ShapeDtypeStruct((B, kp), jnp.int32)),
        interpret=interpret,
    )(q, packed, cids, valid, cmask, q_valid, centroids, bucket_weights)
