from repro.kernels.maxsim.ops import maxsim_scores
from repro.kernels.maxsim.ref import maxsim_scores_ref

__all__ = ["maxsim_scores", "maxsim_scores_ref"]
