from repro.kernels.maxsim.ops import maxsim_scores, maxsim_scores_batch
from repro.kernels.maxsim.ref import (maxsim_scores_batch_ref,
                                      maxsim_scores_ref)

__all__ = ["maxsim_scores", "maxsim_scores_batch", "maxsim_scores_ref",
           "maxsim_scores_batch_ref"]
