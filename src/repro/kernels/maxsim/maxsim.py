"""Pallas TPU kernel: MaxSim late-interaction scoring.

Tiling: the grid runs over candidate blocks of ``block_c`` docs. Each
step loads Q (Lq, d) — resident in VMEM across the whole grid — plus a
(block_c, Ld, d) doc tile and its validity mask, computes the
(Lq, block_c·Ld) score panel on the MXU, applies the mask, reduces
max-over-doc-tokens then sum-over-query-tokens on the VPU, and writes a
(block_c,) partial of the output.

VMEM budget (defaults, fp32): doc tile 16·32·128·4 = 256 KiB, Q
32·128·4 = 16 KiB, score panel 32·512·4 = 64 KiB — comfortably inside
a v5e core's ~16 MiB VMEM, leaving headroom for double-buffering the
doc-tile stream (the kernel is HBM-bandwidth-bound: ~64 B/doc-token
in, 4 B/doc out, 2·Lq·d FLOPs/doc-token ⇒ AI ≈ Lq ≈ 32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _maxsim_tile(q, docs, valid, qv):
    """Shared kernel body. q (Lq, d); docs (BC, Ld, d); valid (BC, Ld)
    int8; qv (Lq,) int8 → (BC,) f32 partial scores."""
    bc, ld, d = docs.shape
    lq = q.shape[0]

    flat = docs.reshape(bc * ld, d)
    # MXU: (Lq, d) × (d, BC·Ld)
    s = jax.lax.dot_general(q, flat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(lq, bc, ld)
    s = jnp.where(valid[None, :, :] != 0, s, NEG)
    per_q = jnp.max(s, axis=-1)                       # (Lq, BC)
    per_q = jnp.where(per_q <= NEG / 2, 0.0, per_q)   # all-invalid docs
    per_q = per_q * (qv[:, None] != 0).astype(per_q.dtype)
    return jnp.sum(per_q, axis=0)                     # (BC,)


def _maxsim_kernel(q_ref, docs_ref, valid_ref, qvalid_ref, out_ref):
    out_ref[...] = _maxsim_tile(q_ref[...], docs_ref[...], valid_ref[...],
                                qvalid_ref[...])


def _maxsim_batch_kernel(q_ref, docs_ref, valid_ref, qvalid_ref, out_ref):
    # leading grid axis walks the query batch; blocks carry a size-1
    # batch dim that is squeezed before the shared tile body
    out_ref[0, :] = _maxsim_tile(q_ref[0], docs_ref[0], valid_ref[0],
                                 qvalid_ref[0])


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def maxsim_pallas(q, docs, doc_valid, q_valid, *, block_c: int = 16,
                  interpret: bool = False):
    """q: (Lq, d) f32; docs: (C, Ld, d) f32; doc_valid: (C, Ld) int8;
    q_valid: (Lq,) int8 → (C,) f32. C must be a multiple of block_c."""
    C, Ld, d = docs.shape
    Lq = q.shape[0]
    assert C % block_c == 0, (C, block_c)
    grid = (C // block_c,)
    return pl.pallas_call(
        _maxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Lq, d), lambda i: (0, 0)),            # Q resident
            pl.BlockSpec((block_c, Ld, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((Lq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(q, docs, doc_valid, q_valid)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def maxsim_pallas_batch(q, docs, doc_valid, q_valid, *, block_c: int = 16,
                        interpret: bool = False):
    """Batched stage-4 dispatch: q (B, Lq, d) f32; docs (B, C, Ld, d) f32;
    doc_valid (B, C, Ld) int8; q_valid (B, Lq) int8 → (B, C) f32.

    The grid gains a leading batch axis; Q/q_valid blocks are per-batch
    resident so the whole batch is one kernel launch (one dispatch for B
    queries instead of B)."""
    B, C, Ld, d = docs.shape
    Lq = q.shape[1]
    assert C % block_c == 0, (C, block_c)
    grid = (B, C // block_c)
    return pl.pallas_call(
        _maxsim_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_c, Ld, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, block_c, Ld), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Lq), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(q, docs, doc_valid, q_valid)
