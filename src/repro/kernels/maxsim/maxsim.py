"""Pallas TPU kernel: MaxSim late-interaction scoring.

Tiling: the grid runs over candidate blocks of ``block_c`` docs. Each
step loads Q (Lq, d) — resident in VMEM across the whole grid — plus a
(block_c, Ld, d) doc tile and its validity mask, computes the
(Lq, block_c·Ld) score panel on the MXU, applies the mask, reduces
max-over-doc-tokens then sum-over-query-tokens on the VPU, and writes a
(block_c,) partial of the output.

VMEM budget (defaults, fp32): doc tile 16·32·128·4 = 256 KiB, Q
32·128·4 = 16 KiB, score panel 32·512·4 = 64 KiB — comfortably inside
a v5e core's ~16 MiB VMEM, leaving headroom for double-buffering the
doc-tile stream (the kernel is HBM-bandwidth-bound: ~64 B/doc-token
in, 4 B/doc out, 2·Lq·d FLOPs/doc-token ⇒ AI ≈ Lq ≈ 32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _maxsim_kernel(q_ref, docs_ref, valid_ref, qvalid_ref, out_ref):
    q = q_ref[...]                        # (Lq, d)
    docs = docs_ref[...]                  # (BC, Ld, d)
    valid = valid_ref[...]                # (BC, Ld) int8
    qv = qvalid_ref[...]                  # (Lq,) int8  (padded query tokens)
    bc, ld, d = docs.shape
    lq = q.shape[0]

    flat = docs.reshape(bc * ld, d)
    # MXU: (Lq, d) × (d, BC·Ld)
    s = jax.lax.dot_general(q, flat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(lq, bc, ld)
    s = jnp.where(valid[None, :, :] != 0, s, NEG)
    per_q = jnp.max(s, axis=-1)                       # (Lq, BC)
    per_q = jnp.where(per_q <= NEG / 2, 0.0, per_q)   # all-invalid docs
    per_q = per_q * (qv[:, None] != 0).astype(per_q.dtype)
    out_ref[...] = jnp.sum(per_q, axis=0)             # (BC,)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def maxsim_pallas(q, docs, doc_valid, q_valid, *, block_c: int = 16,
                  interpret: bool = False):
    """q: (Lq, d) f32; docs: (C, Ld, d) f32; doc_valid: (C, Ld) int8;
    q_valid: (Lq,) int8 → (C,) f32. C must be a multiple of block_c."""
    C, Ld, d = docs.shape
    Lq = q.shape[0]
    assert C % block_c == 0, (C, block_c)
    grid = (C // block_c,)
    return pl.pallas_call(
        _maxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Lq, d), lambda i: (0, 0)),            # Q resident
            pl.BlockSpec((block_c, Ld, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, Ld), lambda i: (i, 0)),
            pl.BlockSpec((Lq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(q, docs, doc_valid, q_valid)
