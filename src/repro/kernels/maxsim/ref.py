"""Pure-jnp oracle for the MaxSim late-interaction kernel."""

import jax
import jax.numpy as jnp


def maxsim_scores_ref(q, docs, doc_valid, q_valid=None):
    """q: (Lq, d); docs: (C, Ld, d); doc_valid: (C, Ld) bool;
    q_valid: optional (Lq,) bool → scores (C,) float32.

    score_c = Σ_{q tokens} max_{valid doc tokens} <q, d>.
    Fully-invalid docs score 0.
    """
    s = jnp.einsum("qd,cld->cql", q.astype(jnp.float32),
                   docs.astype(jnp.float32))
    s = jnp.where(doc_valid[:, None, :], s, -jnp.inf)
    per_q = jnp.max(s, axis=-1)                       # (C, Lq)
    per_q = jnp.where(jnp.isfinite(per_q), per_q, 0.0)
    if q_valid is not None:
        per_q = per_q * q_valid[None, :].astype(per_q.dtype)
    return jnp.sum(per_q, axis=-1)


def maxsim_scores_batch_ref(q, docs, doc_valid, q_valid=None):
    """Leading-batch-dim oracle: q (B, Lq, d); docs (B, C, Ld, d);
    doc_valid (B, C, Ld); q_valid optional (B, Lq) → (B, C) f32."""
    if q_valid is None:
        return jax.vmap(
            lambda a, b, c: maxsim_scores_ref(a, b, c))(q, docs, doc_valid)
    return jax.vmap(maxsim_scores_ref)(q, docs, doc_valid, q_valid)
