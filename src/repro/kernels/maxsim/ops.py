"""jit'd public wrapper for the MaxSim kernel: pads to tile boundaries,
picks Pallas (TPU) vs interpret (CPU validation) vs pure-jnp fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.utils import round_up
from repro.kernels.maxsim.maxsim import maxsim_pallas, maxsim_pallas_batch
from repro.kernels.maxsim.ref import (maxsim_scores_batch_ref,
                                      maxsim_scores_ref)


@functools.partial(jax.jit, static_argnames=("impl", "block_c"))
def maxsim_scores(q, docs, doc_valid, q_valid=None, *, impl: str = "auto",
                  block_c: int = 16):
    """Late-interaction scores. q: (Lq, d); docs: (C, Ld, d);
    doc_valid: (C, Ld) bool; q_valid: optional (Lq,) bool → (C,) f32.

    impl: 'pallas' (TPU), 'interpret' (kernel body on CPU), 'ref'
    (pure jnp), 'auto' (pallas on TPU backend else ref).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if q_valid is None:
        q_valid = jnp.ones((q.shape[0],), bool)
    if impl == "ref":
        return maxsim_scores_ref(q, docs, doc_valid, q_valid)

    C, Ld, d = docs.shape
    Cp = round_up(max(C, 1), block_c)
    if Cp != C:
        docs = jnp.pad(docs, ((0, Cp - C), (0, 0), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, Cp - C), (0, 0)))
    out = maxsim_pallas(q.astype(jnp.float32), docs.astype(jnp.float32),
                        doc_valid.astype(jnp.int8),
                        q_valid.astype(jnp.int8),
                        block_c=block_c, interpret=(impl == "interpret"))
    return out[:C]


@functools.partial(jax.jit, static_argnames=("impl", "block_c"))
def maxsim_scores_batch(q, docs, doc_valid, q_valid=None, *,
                        impl: str = "auto", block_c: int = 16):
    """Cross-query batched late-interaction scores.

    q: (B, Lq, d); docs: (B, C, Ld, d); doc_valid: (B, C, Ld) bool;
    q_valid: optional (B, Lq) bool (False for padded query tokens of
    ragged-length batches) → (B, C) f32. One dispatch for the batch.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if q_valid is None:
        q_valid = jnp.ones(q.shape[:2], bool)
    if impl == "ref":
        return maxsim_scores_batch_ref(q, docs, doc_valid, q_valid)

    B, C, Ld, d = docs.shape
    Cp = round_up(max(C, 1), block_c)
    if Cp != C:
        docs = jnp.pad(docs, ((0, 0), (0, Cp - C), (0, 0), (0, 0)))
        doc_valid = jnp.pad(doc_valid, ((0, 0), (0, Cp - C), (0, 0)))
    out = maxsim_pallas_batch(q.astype(jnp.float32),
                              docs.astype(jnp.float32),
                              doc_valid.astype(jnp.int8),
                              q_valid.astype(jnp.int8),
                              block_c=block_c,
                              interpret=(impl == "interpret"))
    return out[:, :C]
