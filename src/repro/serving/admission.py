"""SLO-aware admission control for the serving front door.

The controller turns overload into a measured quality trade instead of
a latency collapse.  It predicts what one more request would cost from
the live per-stage EWMAs in ``PipelineStats`` plus the current queue
depth, and walks the degradation ladder:

    full  →  degraded (splade-only plan)  →  shed

A request is degraded when the full plan's predicted latency blows the
SLO but the cheap stage-1-only path still fits; it is shed only when
even the cheap path is predicted to exceed ``shed_factor`` times the
SLO (or its own deadline).  Degraded answers reuse the PR 7
``Result.degraded`` plumbing and now carry a reason code.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Mapping, Optional

from .context import ADMIT_DEGRADED, ADMIT_FULL, ADMIT_SHED


class RequestShed(RuntimeError):
    """Raised to the caller when admission rejects a request outright."""

    def __init__(self, reason: str, predicted_ms: float = 0.0):
        super().__init__(f"request shed by admission control: {reason} "
                         f"(predicted {predicted_ms:.1f}ms)")
        self.reason = reason
        self.predicted_ms = predicted_ms


@dataclass(frozen=True)
class AdmissionDecision:
    admission: str            # ADMIT_FULL | ADMIT_DEGRADED | ADMIT_SHED
    reason: str
    predicted_full_ms: float
    predicted_cheap_ms: float


# Stage-name prefixes that belong to the post-stage-1 tail (gathers,
# rerank scoring, merges).  Everything else — the splade stage-1
# dispatch and its cheap fuse — is the degraded path's cost.
_TAIL_PREFIXES = (
    "host_gather",
    "device_score",
    "fused_rerank",
    "fuse_topk",
    "shard_rpc",
    "plaid_probe",
    "merge_topk:approx",
    "candidates",
    "gather_codes",
)
_STAGE1_PREFIXES = ("splade_stage1", "fuse_splade", "merge_topk")


def _bucket(stage_name: str) -> Optional[str]:
    for p in _TAIL_PREFIXES:
        if stage_name.startswith(p):
            return "tail"
    for p in _STAGE1_PREFIXES:
        if stage_name.startswith(p):
            return "stage1"
    return None


class AdmissionController:
    """Predict-then-decide admission against ``latency_slo_ms``.

    The prediction is deliberately simple and cheap: per-stage EWMA
    milliseconds (one batch's wall per stage) summed into a stage-1
    cost and a rerank-tail cost, plus an estimate of queue wait as
    ``ceil(queue_depth / batch_cap)`` batches of full service ahead of
    us.  Stage EWMAs are global across methods, so on mixed-method
    traffic the prediction is an upper bound — acceptable for a shed
    decision that only needs to be directionally right under overload.
    """

    def __init__(
        self,
        latency_slo_ms: float,
        shed_factor: float = 3.0,
        min_samples: int = 1,
    ):
        if latency_slo_ms <= 0:
            raise ValueError("latency_slo_ms must be positive")
        self.latency_slo_ms = float(latency_slo_ms)
        self.shed_factor = float(shed_factor)
        self.min_samples = int(min_samples)
        self._lock = threading.Lock()
        self.full_admits = 0
        self.degraded_admits = 0
        self.sheds = 0
        self.last: Optional[AdmissionDecision] = None

    # -- cost model ----------------------------------------------------

    @staticmethod
    def stage_costs(stage_snapshot: Mapping[str, Mapping[str, float]]):
        """(stage1_ms, tail_ms, n_samples) from a PipelineStats stage map."""
        stage1 = 0.0
        tail = 0.0
        samples = 0
        for name, rec in stage_snapshot.items():
            bucket = _bucket(name)
            if bucket is None:
                continue
            ewma = float(rec.get("ewma_ms", 0.0))
            samples = max(samples, int(rec.get("dispatches", 0)))
            if bucket == "tail":
                tail += ewma
            else:
                stage1 += ewma
        return stage1, tail, samples

    def decide(
        self,
        method: str,
        degradable: bool,
        stage_snapshot: Mapping[str, Mapping[str, float]],
        queue_depth: int = 0,
        batch_cap: int = 1,
        deadline_ms: Optional[float] = None,
    ) -> AdmissionDecision:
        stage1_ms, tail_ms, samples = self.stage_costs(stage_snapshot)
        full_ms = stage1_ms + tail_ms
        if method == "splade":
            # splade requests already run the cheap plan
            full_ms = stage1_ms
        batches_ahead = 0
        if batch_cap > 0:
            batches_ahead = (int(queue_depth) + batch_cap - 1) // batch_cap
        wait_ms = batches_ahead * full_ms
        predicted_full = wait_ms + full_ms
        predicted_cheap = batches_ahead * stage1_ms + stage1_ms

        budget = self.latency_slo_ms
        if deadline_ms is not None:
            budget = min(budget, float(deadline_ms))

        if samples < self.min_samples:
            # cold start: no signal yet, admit everything at full quality
            d = AdmissionDecision(ADMIT_FULL, "cold_start", predicted_full, predicted_cheap)
        elif predicted_full <= budget:
            d = AdmissionDecision(ADMIT_FULL, "", predicted_full, predicted_cheap)
        elif degradable and predicted_cheap <= budget:
            d = AdmissionDecision(
                ADMIT_DEGRADED, "slo_tail", predicted_full, predicted_cheap
            )
        elif degradable and predicted_cheap <= budget * self.shed_factor:
            # over budget either way, but the cheap path is close enough
            # that serving a degraded answer beats rejecting outright
            d = AdmissionDecision(
                ADMIT_DEGRADED, "slo_overload", predicted_full, predicted_cheap
            )
        elif not degradable and predicted_full <= budget * self.shed_factor:
            d = AdmissionDecision(ADMIT_FULL, "slo_best_effort", predicted_full, predicted_cheap)
        else:
            reason = "deadline" if (
                deadline_ms is not None and budget < self.latency_slo_ms
            ) else "overload"
            d = AdmissionDecision(ADMIT_SHED, reason, predicted_full, predicted_cheap)

        with self._lock:
            self.last = d
            if d.admission == ADMIT_FULL:
                self.full_admits += 1
            elif d.admission == ADMIT_DEGRADED:
                self.degraded_admits += 1
            else:
                self.sheds += 1
        return d

    def stats(self) -> Dict[str, object]:
        with self._lock:
            last = self.last
            return {
                "latency_slo_ms": self.latency_slo_ms,
                "shed_factor": self.shed_factor,
                "full_admits": self.full_admits,
                "degraded_admits": self.degraded_admits,
                "sheds": self.sheds,
                "last_predicted_full_ms": last.predicted_full_ms if last else 0.0,
                "last_predicted_cheap_ms": last.predicted_cheap_ms if last else 0.0,
            }
