"""Channel layer: one seam, two transports.

A channel owns one stream socket and moves whole RPC *messages*; the
codec and framing layers above it never see which transport carries the
tensor bytes:

* :class:`StreamChannel` — portable socketpair path. Control bytes and
  tensor segments travel on the socket as one multi-part frame via a
  ``sendmsg`` gather, so each array is copied at most once in userspace
  (``ascontiguousarray`` for strided sources; the kernel's copy into
  the socket buffer is the floor).
* :class:`ShmChannel` — the socket carries *only* control frames;
  ndarray payloads are written once into a shared-memory ring arena
  and cross as ``("arena", …)`` locators. The receive side maps spans
  directly as read-only views — zero serialize, zero copy.

Both count ``bytes_copied`` (tensor bytes that crossed the socket or
were memcpy'd) vs ``bytes_zero_copy`` (tensor bytes that crossed via
arena mapping), surfaced through ``health()`` so the transport win is
observable, not folklore.

Channels are not internally locked: the client serialises sends under
its send lock and pumps under its recv lock; the worker is
single-threaded. ``pump`` keeps partial frames in a persistent buffer
across slices and paces with ``select`` — never ``sock.settimeout``,
which is socket-wide and would spuriously fail concurrent sends when a
busy worker lets the pipe fill (a blocked send is backpressure, not
death).
"""

from __future__ import annotations

import select
import socket
import time
from typing import Optional

from repro.serving.transport import codec, framing
from repro.serving.transport.errors import ArenaDead
from repro.serving.transport.shm import (RING_C2W, RING_W2C, ArenaSink,
                                         ShmArena)

_LEN = framing.LEN_SIZE


class _FramedChannel:
    """Shared machinery: frame assembly/gather on send, persistent
    partial-frame buffer + select pacing on receive."""

    transport = "?"

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.bytes_sent = 0          # socket bytes out (incl. headers)
        self.bytes_recv = 0          # socket bytes in
        self.bytes_copied = 0        # tensor bytes that were memcpy'd
        self.bytes_zero_copy = 0     # tensor bytes mapped, not copied
        self._rx = bytearray()       # partial-frame receive buffer

    # subclasses override the two transport-specific seams
    def _make_sink(self):
        return None, None            # (sink, seg_sink)

    def _arena_resolver(self, kind, dtype_str, shape, fields):
        raise ValueError(f"no resolver for {kind!r} ndarray locator on "
                         f"a {self.transport} channel")

    # -- send --------------------------------------------------------------
    def send(self, obj) -> int:
        sink, seg_sink = self._make_sink()
        control = codec.encode_control(obj, sink)
        bufs = framing.frame_buffers(control, seg_sink)
        n = framing.sendmsg_gather(self.sock, bufs)
        self.bytes_sent += n
        self.bytes_copied += (0 if seg_sink is None else seg_sink.nbytes)
        if sink is not None and isinstance(sink, ArenaSink):
            self.bytes_zero_copy += sink.arena_bytes
        return n

    # -- receive -----------------------------------------------------------
    def pump(self, slice_timeout: float):
        """Complete at most one frame within ``slice_timeout``; returns
        the decoded message or None. Partially received bytes persist in
        the buffer across slices — a timeout mid-frame must never
        discard them, or the length-prefixed stream desynchronises and
        a healthy worker looks dead."""
        deadline = time.monotonic() + slice_timeout
        while True:
            if len(self._rx) >= _LEN:
                (n,) = framing._LEN.unpack(bytes(self._rx[:_LEN]))
                if len(self._rx) >= _LEN + n:
                    payload = bytes(self._rx[_LEN:_LEN + n])
                    del self._rx[:_LEN + n]
                    return framing.parse_payload(payload,
                                                 self._arena_resolver)
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            readable, _, _ = select.select([self.sock], [], [],
                                           remaining)
            if not readable:
                return None
            chunk = self.sock.recv(1 << 20)   # readable: won't block
            if not chunk:
                raise ConnectionError("RPC peer closed the connection")
            self._rx += chunk
            self.bytes_recv += len(chunk)

    def recv(self, timeout: Optional[float] = None):
        """Blocking single-message receive (worker serve loop and spawn
        handshake); ``timeout`` is the whole-message deadline."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            if deadline is None:
                slice_s = 1.0
            else:
                slice_s = deadline - time.monotonic()
                if slice_s <= 0:
                    raise socket.timeout("RPC recv deadline exceeded")
                slice_s = min(slice_s, 1.0)
            msg = self.pump(slice_s)
            if msg is not None:
                return msg

    def stats(self) -> dict:
        return {"transport": self.transport,
                "bytes_sent": self.bytes_sent,
                "bytes_recv": self.bytes_recv,
                "bytes_copied": self.bytes_copied,
                "bytes_zero_copy": self.bytes_zero_copy}

    def close(self):
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None


class StreamChannel(_FramedChannel):
    """Socketpair stream transport (portable fallback): tensors ride as
    in-frame segments gathered into the ``sendmsg`` iovec."""

    transport = "socket"

    def _make_sink(self):
        seg = framing.SegmentSink()
        return seg, seg


class ShmChannel(_FramedChannel):
    """Shared-memory arena transport: the socket carries control frames
    only; tensor payloads cross via the ring arena.

    ``tx_ring``/``rx_ring`` select direction: the coordinator transmits
    on ring 0 (c→w) and receives on ring 1; the worker is the mirror
    image. ``liveness`` (producer side) turns a dead peer into
    :class:`ArenaDead` instead of an indefinite back-pressure stall.
    """

    transport = "shm"

    def __init__(self, sock: socket.socket, arena: ShmArena, *,
                 tx_ring: int = RING_C2W, rx_ring: int = RING_W2C,
                 liveness=None, alloc_timeout_s: float = 60.0,
                 own_arena: bool = True):
        super().__init__(sock)
        self.arena = arena
        self._tx = arena.ring(tx_ring)
        self._rx_ring = arena.ring(rx_ring)
        self._liveness = liveness
        self._alloc_timeout_s = alloc_timeout_s
        self._own_arena = own_arena

    def _make_sink(self):
        seg = framing.SegmentSink()
        sink = ArenaSink(self._tx, seg, timeout_s=self._alloc_timeout_s,
                         liveness=self._liveness)
        return sink, seg

    def _arena_resolver(self, kind, dtype_str, shape, fields):
        if kind != "arena":
            raise ValueError(f"unexpected {kind!r} ndarray locator")
        gen, start, span, nbytes = fields
        if gen != self.arena.generation:
            raise ArenaDead(
                f"arena locator from generation {gen} but this arena is "
                f"generation {self.arena.generation}")
        view = self._rx_ring.take(start, span, nbytes, dtype_str, shape)
        self.bytes_zero_copy += nbytes
        return view

    def close(self):
        super().close()
        if self._own_arena and self.arena is not None:
            self.arena.close()
