"""Framing layer: length-prefixed frames over a stream socket.

Every frame is ``[8-byte BE payload length][payload]``. Two payload
formats coexist on the same stream, distinguished by the first payload
byte:

* ``\\x00`` / ``\\x01`` — a bare control message (legacy single-part
  frame; the byte is the codec tag)
* ``\\x02`` — a multi-part frame: ``\\x02 [4-byte BE control length]
  [control bytes] [segment bytes…]``. The control message references
  arrays inside the trailing segment region via ``("seg", off, n)``
  locators, and the send path gathers the arrays' own memory into a
  ``sendmsg`` iovec — **no userspace copy, no concatenation** of
  tensor bytes on the stream path (the kernel copies once into the
  socket buffer; that is the floor for a socket).

The framing layer carries *control* messages; bulk tensor bytes either
ride as in-frame segments (stream channel) or bypass the socket
entirely via a shared-memory arena (shm channel) — see
``transport.channel``.
"""

from __future__ import annotations

import socket
import struct
from typing import Optional

import numpy as np

from repro.serving.transport import codec

_LEN = struct.Struct(">Q")
_CTL = struct.Struct(">I")
PARTS_MAGIC = b"\x02"

LEN_SIZE = _LEN.size


class SegmentSink:
    """Collects ndarray payloads as out-of-band frame segments.

    ``put`` registers the array's (contiguous) memory as the next
    segment and returns its ``("seg", off, n)`` locator; the buffers
    are later handed straight to ``sendmsg`` — each array is copied at
    most once (``ascontiguousarray`` when the source is strided), never
    serialized or concatenated. Arrays under ``min_bytes`` are declined
    (inline encoding is cheaper than an iovec entry)."""

    __slots__ = ("bufs", "nbytes", "min_bytes")

    def __init__(self, min_bytes: int = 64):
        self.bufs: list = []
        self.nbytes = 0
        self.min_bytes = min_bytes

    def put(self, arr: np.ndarray) -> Optional[tuple]:
        if arr.nbytes < self.min_bytes:
            return None
        a = np.ascontiguousarray(arr)
        # the memoryview keeps ``a`` alive until the frame is sent
        self.bufs.append(memoryview(a).cast("B"))
        loc = ("seg", self.nbytes, a.nbytes)
        self.nbytes += a.nbytes
        return loc


def frame_buffers(control: bytes, seg_sink: Optional[SegmentSink]) \
        -> list:
    """Assemble the gather list for one frame (header + control +
    segment buffers), ready for :func:`sendmsg_gather`."""
    seg_bytes = 0 if seg_sink is None else seg_sink.nbytes
    if seg_bytes == 0:
        return [_LEN.pack(len(control)), control]
    if len(control) > 0xFFFFFFFF:
        raise ValueError("control message exceeds the 4 GiB limit")
    head = (_LEN.pack(1 + _CTL.size + len(control) + seg_bytes)
            + PARTS_MAGIC + _CTL.pack(len(control)))
    return [head, control, *seg_sink.bufs]


def sendmsg_gather(sock: socket.socket, bufs: list) -> int:
    """writev-style gather send with partial-write handling; returns
    total bytes written."""
    total = sum(len(b) for b in bufs)
    if not hasattr(sock, "sendmsg"):              # pragma: no cover
        sock.sendall(b"".join(bufs))
        return total
    views = [b if isinstance(b, memoryview) else memoryview(b)
             for b in bufs]
    sent = 0
    while views:
        n = sock.sendmsg(views)
        sent += n
        if sent == total:
            break
        # drop fully-sent buffers, trim the partially-sent head
        while views and n >= len(views[0]):
            n -= len(views[0])
            views.pop(0)
        if views and n:
            views[0] = views[0][n:]
    return total


def parse_payload(payload, arena_resolver=None):
    """One frame's payload → decoded message.

    ``("seg", …)`` locators resolve against the frame's own segment
    region (copied out — the recv buffer is transient); any other
    locator kind is delegated to ``arena_resolver``."""
    mv = memoryview(payload)
    if bytes(mv[:1]) != PARTS_MAGIC:
        return codec.decode_control(payload, arena_resolver)
    (clen,) = _CTL.unpack(mv[1:1 + _CTL.size])
    base = 1 + _CTL.size
    control = bytes(mv[base:base + clen])
    segs = mv[base + clen:]

    def resolver(kind, d, s, fields):
        if kind == "seg":
            off, n = fields
            return np.frombuffer(segs[off:off + n],
                                 dtype=np.dtype(d)).reshape(s).copy()
        if arena_resolver is None:
            raise ValueError(f"no resolver for {kind!r} ndarray locator")
        return arena_resolver(kind, d, s, fields)

    return codec.decode_control(control, resolver)


# ---------------------------------------------------------------------------
# legacy blocking helpers (single-part frames, everything inline)
# ---------------------------------------------------------------------------

def send_msg(sock: socket.socket, obj) -> int:
    """Encode inline + length-prefix + sendall. Returns bytes written."""
    payload = codec.encode(obj)
    sock.sendall(_LEN.pack(len(payload)) + payload)
    return _LEN.size + len(payload)


def _recv_exact(sock: socket.socket, n: int,
                deadline: Optional[float]) -> bytes:
    import time

    chunks = []
    got = 0
    while got < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise socket.timeout("RPC recv deadline exceeded")
            sock.settimeout(min(remaining, 1.0))
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except socket.timeout:
            continue                 # re-check the deadline
        if not chunk:
            raise ConnectionError("RPC peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket, timeout: Optional[float] = None):
    """Read one length-prefixed message; ``timeout`` is the whole-message
    deadline (None = block forever)."""
    import time

    deadline = None if timeout is None else time.monotonic() + timeout
    head = _recv_exact(sock, _LEN.size, deadline)
    (n,) = _LEN.unpack(head)
    return parse_payload(_recv_exact(sock, n, deadline))
