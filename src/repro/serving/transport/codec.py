"""Codec layer: RPC message values ⇄ control bytes + ndarray locators.

Two interchangeable codecs cover the same value space (None/bool/int/
float/str/bytes/list/dict/ndarray): msgpack when available, and a
dependency-free fallback with one tag byte per value. Both are lossless
for numpy dtypes — scores travel as raw dtype bytes, which is what
makes process-group results bitwise-identical to the in-process shard
group. The leading control byte selects the codec (``\\x01`` msgpack,
``\\x00`` fallback), so a msgpack coordinator can talk to a fallback
worker and vice versa.

The layering seam is the **ndarray locator**: tensor payloads are
``(dtype, shape, locator)``, where the locator is decided by a pluggable
*sink* at encode time and resolved by a *resolver* at decode time:

* ``None``            — inline: raw bytes embedded in the control
  message (the legacy format; tiny arrays stay here, it is cheaper
  than any indirection)
* ``("seg", off, n)`` — out-of-band segment inside the same frame; the
  framing layer gathers the array's own memory into the ``sendmsg``
  iovec, so the stream path copies tensor bytes at most once
* ``("arena", gen, start, span, n)`` — a span in a shared-memory ring
  arena; neither side serializes tensor bytes, the consumer maps the
  span directly (see ``transport.shm``)

``encode``/``decode`` (no sink) keep the legacy inline wire format for
back-compat and for control-only messages.

Length guard: every 4-byte count/length field raises before encoding a
value over 4 GiB — a silent ``struct`` wrap would desynchronise the
stream, the one corruption a length-prefixed protocol can't recover
from. Arrays dodge the limit via 8-byte raw lengths and locators.
"""

from __future__ import annotations

import json
import struct
from typing import Callable, Optional

import numpy as np

try:
    import msgpack
    HAVE_MSGPACK = True
except ImportError:                                   # pragma: no cover
    msgpack = None
    HAVE_MSGPACK = False

_ND_EXT = 42       # msgpack ExtType: inline ndarray (dtype, shape, raw)
_ND_SEG = 43       # msgpack ExtType: frame-segment locator
_ND_ARENA = 44     # msgpack ExtType: shm-arena locator

_U32_MAX = 0xFFFFFFFF

# resolver(kind, dtype_str, shape, fields) -> ndarray
NdResolver = Callable[[str, str, list, tuple], np.ndarray]


def _check_u32(n: int, what: str) -> int:
    """4-byte length-field guard (the >4 GiB header check)."""
    if n > _U32_MAX:
        raise ValueError(
            f"{what} of {n} bytes exceeds the 4 GiB RPC field limit")
    return n


def _nd_to_wire(arr: np.ndarray) -> tuple:
    a = np.ascontiguousarray(arr)
    # shape from the *original*: ascontiguousarray promotes 0-d to (1,)
    return (a.dtype.str, list(arr.shape), a.tobytes())


def _nd_from_wire(dtype_str: str, shape, raw: bytes) -> np.ndarray:
    # copy: frombuffer views are read-only and may alias the recv buffer
    return np.frombuffer(raw, dtype=np.dtype(dtype_str)) \
        .reshape(shape).copy()


def _locate(arr: np.ndarray, sink) -> Optional[tuple]:
    """Offer ``arr`` to the sink; None means "inline it"."""
    return None if sink is None else sink.put(arr)


# ---------------------------------------------------------------------------
# msgpack codec
# ---------------------------------------------------------------------------

def _msgpack_default(sink):
    def default(obj):
        if isinstance(obj, np.ndarray):
            loc = _locate(obj, sink)
            if loc is None:
                d, s, b = _nd_to_wire(obj)
                return msgpack.ExtType(_ND_EXT, msgpack.packb((d, s, b)))
            kind, fields = loc[0], list(loc[1:])
            code = _ND_SEG if kind == "seg" else _ND_ARENA
            return msgpack.ExtType(code, msgpack.packb(
                (obj.dtype.str, list(obj.shape), fields)))
        if isinstance(obj, np.integer):
            return int(obj)
        if isinstance(obj, np.floating):
            return float(obj)
        if isinstance(obj, np.bool_):
            return bool(obj)
        if isinstance(obj, tuple):
            return list(obj)
        raise TypeError(f"unencodable RPC value: {type(obj)!r}")
    return default


def _msgpack_ext_hook(resolver):
    def hook(code, data):
        if code == _ND_EXT:
            d, s, b = msgpack.unpackb(data)
            return _nd_from_wire(d, s, b)
        if code in (_ND_SEG, _ND_ARENA):
            d, s, fields = msgpack.unpackb(data)
            kind = "seg" if code == _ND_SEG else "arena"
            if resolver is None:
                raise ValueError(
                    f"message carries a {kind!r} ndarray locator but "
                    f"this decoder has no resolver for it")
            return resolver(kind, d, s, tuple(fields))
        return msgpack.ExtType(code, data)          # pragma: no cover
    return hook


# ---------------------------------------------------------------------------
# fallback codec (no msgpack on the image)
# ---------------------------------------------------------------------------
# One tag byte per value; ints are 8-byte signed, floats are doubles,
# containers carry a 4-byte count. Locator tags G (frame segment) and
# H (arena span) carry a json header [dtype, shape, fields].

def _enc_py(obj, out: list, sink=None):
    if obj is None:
        out.append(b"N")
    elif isinstance(obj, (bool, np.bool_)):
        out.append(b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        out.append(b"I" + struct.pack(">q", int(obj)))
    elif isinstance(obj, (float, np.floating)):
        out.append(b"D" + struct.pack(">d", float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode()
        out.append(b"S" + struct.pack(
            ">I", _check_u32(len(raw), "str")) + raw)
    elif isinstance(obj, bytes):
        out.append(b"B" + struct.pack(
            ">I", _check_u32(len(obj), "bytes")) + obj)
    elif isinstance(obj, np.ndarray):
        loc = _locate(obj, sink)
        if loc is None:
            d, s, raw = _nd_to_wire(obj)
            head = json.dumps([d, s]).encode()
            out.append(b"A" + struct.pack(
                ">I", _check_u32(len(head), "ndarray header")) + head
                + struct.pack(">Q", len(raw)) + raw)
        else:
            kind, fields = loc[0], list(loc[1:])
            head = json.dumps([obj.dtype.str, list(obj.shape),
                               fields]).encode()
            out.append((b"G" if kind == "seg" else b"H")
                       + struct.pack(">I", _check_u32(
                           len(head), "ndarray header")) + head)
    elif isinstance(obj, (list, tuple)):
        out.append(b"L" + struct.pack(
            ">I", _check_u32(len(obj), "list")))
        for x in obj:
            _enc_py(x, out, sink)
    elif isinstance(obj, dict):
        out.append(b"M" + struct.pack(
            ">I", _check_u32(len(obj), "dict")))
        for k, v in obj.items():
            _enc_py(str(k), out, sink)
            _enc_py(v, out, sink)
    else:
        raise TypeError(f"unencodable RPC value: {type(obj)!r}")


def _dec_py(buf: memoryview, pos: int, resolver=None):
    tag = bytes(buf[pos:pos + 1])
    pos += 1
    if tag == b"N":
        return None, pos
    if tag == b"T":
        return True, pos
    if tag == b"F":
        return False, pos
    if tag == b"I":
        return struct.unpack(">q", buf[pos:pos + 8])[0], pos + 8
    if tag == b"D":
        return struct.unpack(">d", buf[pos:pos + 8])[0], pos + 8
    if tag in (b"S", b"B"):
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        raw = bytes(buf[pos + 4:pos + 4 + n])
        return (raw.decode() if tag == b"S" else raw), pos + 4 + n
    if tag == b"A":
        hn = struct.unpack(">I", buf[pos:pos + 4])[0]
        d, s = json.loads(bytes(buf[pos + 4:pos + 4 + hn]).decode())
        pos += 4 + hn
        rn = struct.unpack(">Q", buf[pos:pos + 8])[0]
        arr = _nd_from_wire(d, s, bytes(buf[pos + 8:pos + 8 + rn]))
        return arr, pos + 8 + rn
    if tag in (b"G", b"H"):
        hn = struct.unpack(">I", buf[pos:pos + 4])[0]
        d, s, fields = json.loads(bytes(buf[pos + 4:pos + 4 + hn])
                                  .decode())
        kind = "seg" if tag == b"G" else "arena"
        if resolver is None:
            raise ValueError(
                f"message carries a {kind!r} ndarray locator but this "
                f"decoder has no resolver for it")
        return resolver(kind, d, s, tuple(fields)), pos + 4 + hn
    if tag == b"L":
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        pos += 4
        out = []
        for _ in range(n):
            v, pos = _dec_py(buf, pos, resolver)
            out.append(v)
        return out, pos
    if tag == b"M":
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        pos += 4
        out = {}
        for _ in range(n):
            k, pos = _dec_py(buf, pos, resolver)
            v, pos = _dec_py(buf, pos, resolver)
            out[k] = v
        return out, pos
    raise ValueError(f"bad RPC tag {tag!r}")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def encode_control(obj, sink=None, *, force_fallback: bool = False) \
        -> bytes:
    """Message → control bytes; every ndarray is first offered to
    ``sink.put(arr)`` (a locator tuple replaces its bytes in the
    control message; None inlines it)."""
    if HAVE_MSGPACK and not force_fallback:
        return b"\x01" + msgpack.packb(obj, default=_msgpack_default(sink),
                                       use_bin_type=True)
    out: list = []
    _enc_py(obj, out, sink)
    return b"\x00" + b"".join(out)


def decode_control(raw, resolver: Optional[NdResolver] = None):
    """Control bytes → message; locator-typed ndarrays are resolved via
    ``resolver(kind, dtype_str, shape, fields)``."""
    raw = bytes(raw) if not isinstance(raw, (bytes, bytearray)) else raw
    if raw[:1] == b"\x01":
        if not HAVE_MSGPACK:
            raise RuntimeError("peer sent msgpack but msgpack is not "
                               "installed here")
        return msgpack.unpackb(raw[1:],
                               ext_hook=_msgpack_ext_hook(resolver),
                               raw=False, strict_map_key=False)
    val, pos = _dec_py(memoryview(raw), 1, resolver)
    if pos != len(raw):
        raise ValueError(f"trailing RPC bytes ({len(raw) - pos})")
    return val


def encode(obj, *, force_fallback: bool = False) -> bytes:
    """Message → wire bytes, everything inline (legacy format)."""
    return encode_control(obj, None, force_fallback=force_fallback)


def decode(raw: bytes):
    """Wire bytes → message (codec chosen by the leading byte)."""
    return decode_control(raw, None)
