"""Fault injection at the channel seam.

:class:`FaultyChannel` wraps any transport channel and perturbs its
*send* path by a seeded schedule, so the failover machinery above it
(per-op deadlines, replica retry, degraded merge) is exercised
deterministically in CI rather than only when real hardware misbehaves:

* **drop** — the frame is silently discarded. The worker never sees the
  request, so nothing answers: the op's deadline fires and the router
  retries on a sibling replica.
* **delay** — the frame is sent after a fixed sleep (straggler
  simulation; what hedging is for).
* **truncate** — a partial frame is written and the write side is shut
  down: the peer desyncs mid-frame and both directions die, the way a
  worker OOM-killed mid-``sendmsg`` looks from the coordinator.
* **corrupt** — a well-framed garbage payload replaces the real frame:
  the peer's codec rejects it and tears the connection down.

The schedule is a :class:`FaultSpec` — a seeded ``random.Random`` plus
per-fault probabilities — parsed from a compact string
(``"seed=42,drop=0.05,delay=20:0.1,truncate=0.02,corrupt=0.02"``) so a
chaos run is reproducible from its CLI flag alone. Receive-side state
is untouched: a channel that injected nothing behaves bitwise like the
wrapped channel, which keeps the parity contract intact for prob-0
specs.
"""

from __future__ import annotations

import random
import socket
import struct
import time
from typing import Optional

__all__ = ["FaultSpec", "FaultyChannel"]


class FaultSpec:
    """Seeded fault schedule: independent per-send probabilities for
    each fault kind, evaluated in a fixed order (drop, truncate,
    corrupt, delay) so a given seed always yields the same fault
    sequence for the same send sequence."""

    __slots__ = ("seed", "drop", "delay_ms", "delay_p", "truncate",
                 "corrupt")

    def __init__(self, seed: int = 0, drop: float = 0.0,
                 delay_ms: float = 0.0, delay_p: float = 0.0,
                 truncate: float = 0.0, corrupt: float = 0.0):
        self.seed = int(seed)
        self.drop = float(drop)
        self.delay_ms = float(delay_ms)
        self.delay_p = float(delay_p)
        self.truncate = float(truncate)
        self.corrupt = float(corrupt)

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """``"seed=42,drop=0.05,delay=20:0.1,truncate=0.02"`` →
        :class:`FaultSpec`. ``delay`` takes ``<ms>:<probability>``;
        every field is optional."""
        kw: dict = {}
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            val = val.strip()
            if key == "seed":
                kw["seed"] = int(val)
            elif key == "delay":
                ms, _, p = val.partition(":")
                kw["delay_ms"] = float(ms)
                kw["delay_p"] = float(p) if p else 1.0
            elif key in ("drop", "truncate", "corrupt"):
                kw[key] = float(val)
            else:
                raise ValueError(f"unknown fault field {key!r} in "
                                 f"{text!r}")
        return cls(**kw)

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"FaultSpec(seed={self.seed}, drop={self.drop}, "
                f"delay={self.delay_ms}:{self.delay_p}, "
                f"truncate={self.truncate}, corrupt={self.corrupt})")


_LEN = struct.Struct(">Q")


class FaultyChannel:
    """Channel proxy injecting :class:`FaultSpec` faults on the send
    path. Everything else (pump/recv/stats/byte counters) delegates to
    the wrapped channel, so the client above cannot tell the
    difference until a fault lands."""

    def __init__(self, inner, spec: FaultSpec):
        self._inner = inner
        self._spec = spec
        self._rng = random.Random(spec.seed)
        self.faults = {"drop": 0, "delay": 0, "truncate": 0,
                       "corrupt": 0}

    # -- fault roll ---------------------------------------------------

    def _roll(self) -> Optional[str]:
        s = self._spec
        # one draw per fault kind, fixed order: the fault sequence is a
        # pure function of (seed, send index)
        draws = [self._rng.random() for _ in range(4)]
        if draws[0] < s.drop:
            return "drop"
        if draws[1] < s.truncate:
            return "truncate"
        if draws[2] < s.corrupt:
            return "corrupt"
        if draws[3] < s.delay_p and s.delay_ms > 0:
            return "delay"
        return None

    # -- channel interface --------------------------------------------

    def send(self, obj) -> int:
        fault = self._roll()
        if fault is None:
            return self._inner.send(obj)
        self.faults[fault] += 1
        if fault == "drop":
            return 0
        if fault == "delay":
            time.sleep(self._spec.delay_ms / 1e3)
            return self._inner.send(obj)
        sock = self._inner.sock
        if fault == "truncate":
            # claim an 8-byte payload, deliver half of it, then close
            # the write side: the peer blocks mid-frame and then sees
            # EOF — a worker killed mid-send, as observed on the wire
            try:
                sock.sendall(_LEN.pack(8) + b"\xde\xad\xbe\xef")
                sock.shutdown(socket.SHUT_WR)
            except OSError:
                pass
            raise ConnectionError("injected fault: truncated frame")
        # corrupt: a complete frame whose payload no codec accepts —
        # the peer decodes garbage and tears the connection down
        junk = b"\x7f" + self._rng.randbytes(16)
        try:
            sock.sendall(_LEN.pack(len(junk)) + junk)
        except OSError:
            pass
        raise ConnectionError("injected fault: corrupted frame")

    def pump(self, slice_timeout: float = 1.0):
        return self._inner.pump(slice_timeout)

    def recv(self, timeout: Optional[float] = None):
        return self._inner.recv(timeout)

    def stats(self) -> dict:
        st = self._inner.stats()
        st["faults_injected"] = dict(self.faults)
        return st

    def close(self):
        self._inner.close()

    # byte counters, ``sock``, ``transport``, arena handles … all live
    # on the wrapped channel
    def __getattr__(self, name):
        return getattr(self._inner, name)
