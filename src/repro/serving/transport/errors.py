"""Shard-transport error taxonomy.

Two failure classes cross the RPC seam, and the distinction is
load-bearing for the heal logic in ``ProcessShardGroup``:

* :class:`ShardWorkerDied` — the *transport* failed (EOF, reset,
  timeout, arena peer gone, nonzero exit). The worker process behind
  the shard is unusable; the group heals by respawning it on next use.
* :class:`ShardWorkerError` — a stage op raised *inside* a healthy
  worker (or a soft deadline expired while it was merely busy). The
  worker keeps serving; nothing is respawned.
"""

from __future__ import annotations


class ShardWorkerDied(RuntimeError):
    """The worker process behind a shard is gone (EOF, reset, timeout,
    or a nonzero exit) — the current batch has no answer for that
    shard. The group heals by respawning the worker on next use."""


class ShardWorkerError(RuntimeError):
    """A stage op raised *inside* a healthy worker; the worker keeps
    serving. Carries the remote traceback text."""


class ArenaDead(ConnectionError):
    """A shared-memory arena operation cannot complete because the peer
    is gone (or the ring stayed full past its deadline). Subclasses
    ConnectionError so every transport-death path maps to
    :class:`ShardWorkerDied` in the client."""
