"""Shard-transport error taxonomy.

Two failure classes cross the RPC seam, and the distinction is
load-bearing for the heal logic in ``ProcessShardGroup``:

* :class:`ShardWorkerDied` — the *transport* failed (EOF, reset,
  timeout, arena peer gone, nonzero exit). The worker process behind
  the shard is unusable; the group heals by respawning it on next use.
* :class:`ShardWorkerError` — a stage op raised *inside* a healthy
  worker (or a soft deadline expired while it was merely busy). The
  worker keeps serving; nothing is respawned.
"""

from __future__ import annotations


class ShardWorkerDied(RuntimeError):
    """The worker process behind a shard is gone (EOF, reset, timeout,
    or a nonzero exit) — the current batch has no answer for that
    shard. The group heals by respawning the worker on next use."""


class ShardWorkerError(RuntimeError):
    """A stage op raised *inside* a healthy worker; the worker keeps
    serving. Carries the remote traceback text."""


class ArenaDead(ConnectionError):
    """A shared-memory arena operation cannot complete because the peer
    is gone (or the ring stayed full past its deadline). Subclasses
    ConnectionError so every transport-death path maps to
    :class:`ShardWorkerDied` in the client."""


class DeadlineExceeded(ConnectionError):
    """A per-op deadline (``timeout_ms``) expired before the worker
    answered. Subclasses ConnectionError: a worker that blows an
    explicit deadline is indistinguishable from a hung transport, so
    the replica router treats it as a failover trigger. The connection
    is torn down (replies behind the expired one would desequence the
    FIFO otherwise)."""


class ShardUnavailable(ShardWorkerDied):
    """Every replica of a shard is dead or quarantined — there is no
    sibling left to fail over to. Subclasses :class:`ShardWorkerDied`
    so existing broad handlers keep working; carries the shard index
    and the last per-replica error for diagnostics."""

    def __init__(self, message: str, *, shard: int = -1,
                 last_error: BaseException | None = None):
        super().__init__(message)
        self.shard = shard
        self.last_error = last_error
