"""Coordinator-side worker handle over a pluggable channel.

:class:`ShardWorkerClient` spawns one shard worker process and talks
to it through a :class:`~repro.serving.transport.channel.StreamChannel`
(socketpair, portable) or :class:`ShmChannel` (shared-memory arena,
zero-copy) — selected per worker via ``transport=``. The request/
response discipline is unchanged from the monolithic rpc module:

* requests are **pipelined** (``call_async`` sends immediately and
  returns a handle; replies are FIFO per connection, so an abandoned
  handle's reply is still consumed by the next waiter and the stream
  can never desynchronise),
* liveness is exact (worker death is socket EOF, not a guessed
  timeout; on shm, a producer blocked on ring back-pressure polls a
  liveness callback so a dead peer raises instead of wedging),
* soft deadlines (``kill_on_timeout=False``) never kill a merely busy
  worker,
* all transport failures mark the client dead and fail every
  outstanding handle with :class:`ShardWorkerDied`.

Arena lifecycle: the coordinator creates the arena file (in
``/dev/shm`` when present), passes its path to the child, and unlinks
it right after the first ping — by then both sides have it mapped, so
the name is unnecessary and a crashed pair can never leak a file. Each
respawn gets a fresh arena at a bumped generation; locators from an
old generation are rejected by construction.
"""

from __future__ import annotations

import collections
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Optional

from repro.serving.transport.channel import ShmChannel, StreamChannel
from repro.serving.transport.errors import (DeadlineExceeded,
                                            ShardWorkerDied,
                                            ShardWorkerError)
from repro.serving.transport.faults import FaultSpec, FaultyChannel
from repro.serving.transport.shm import ShmArena, arena_path

DEFAULT_ARENA_BYTES = 64 << 20     # per direction, per worker


class _Reply:
    """One outstanding pipelined request's reply slot."""

    __slots__ = ("event", "value", "error", "deadline")

    def __init__(self):
        self.event = threading.Event()
        self.value = None
        self.error: Optional[BaseException] = None
        # absolute monotonic per-op deadline (None = only the waiter's
        # own timeout applies)
        self.deadline: Optional[float] = None

    def resolve(self, value=None, error: Optional[BaseException] = None):
        self.value = value
        self.error = error
        self.event.set()


def _src_pythonpath() -> str:
    """PYTHONPATH entry that makes ``repro`` importable in the child."""
    import repro

    # repro may be a namespace package (__file__ is None) — __path__
    # always carries the package directory
    pkg_dir = (pathlib.Path(repro.__file__).parent if repro.__file__
               else pathlib.Path(next(iter(repro.__path__))))
    src = str(pkg_dir.resolve().parent)
    existing = os.environ.get("PYTHONPATH", "")
    return src if not existing else f"{src}{os.pathsep}{existing}"


class ShardWorkerClient:
    """Spawn and talk to one shard worker process over a channel."""

    def __init__(self, shard_index: int, shard_dir, *, mode: str = "mmap",
                 plaid_params: Optional[dict] = None,
                 ms_params: Optional[dict] = None,
                 env: Optional[dict] = None,
                 spawn_timeout_s: float = 180.0,
                 call_timeout_s: float = 300.0,
                 transport: str = "shm",
                 arena_bytes: int = DEFAULT_ARENA_BYTES,
                 arena_dir: Optional[str] = None,
                 generation: int = 1,
                 endpoint: Optional[str] = None,
                 fault_spec: Optional[FaultSpec] = None):
        if transport not in ("shm", "socket"):
            raise ValueError(f"unknown shard transport {transport!r}")
        if endpoint is not None:
            # a remote worker is the StreamChannel over TCP — shm rings
            # only exist between processes sharing /dev/shm
            transport = "socket"
        self.endpoint = endpoint
        self.fault_spec = fault_spec
        self.shard_index = shard_index
        self.shard_dir = str(shard_dir)
        self.mode = mode
        self.plaid_params = plaid_params or {}
        self.ms_params = ms_params or {}
        self.env = env
        self.spawn_timeout_s = spawn_timeout_s
        self.call_timeout_s = call_timeout_s
        self.transport = transport
        self.arena_bytes = arena_bytes
        self.arena_dir = arena_dir
        self.generation = generation
        self.proc: Optional[subprocess.Popen] = None
        self.channel = None
        self.dead = False
        # RLock: a send failure marks the client dead from *inside* the
        # send critical section (_mark_dead re-enters to fail pending)
        self._send_lock = threading.RLock()
        self._recv_lock = threading.Lock()
        self._pending: collections.deque[_Reply] = collections.deque()

    # -- channel plumbing ------------------------------------------------
    @property
    def sock(self) -> Optional[socket.socket]:
        return None if self.channel is None else self.channel.sock

    @sock.setter
    def sock(self, s: Optional[socket.socket]):
        # legacy seam (tests drive a bare socketpair end through the
        # client): wrapping in a stream channel preserves it
        self.channel = None if s is None else StreamChannel(s)

    @property
    def bytes_sent(self) -> int:
        return 0 if self.channel is None else self.channel.bytes_sent

    @property
    def bytes_recv(self) -> int:
        return 0 if self.channel is None else self.channel.bytes_recv

    @property
    def arena_generation(self) -> Optional[int]:
        # getattr, not isinstance: a FaultyChannel wrapper delegates
        # ``arena`` to the shm channel it wraps
        arena = getattr(self.channel, "arena", None)
        return arena.generation if arena is not None else None

    def transport_stats(self) -> dict:
        if self.channel is None:
            return {"transport": self.transport, "bytes_sent": 0,
                    "bytes_recv": 0, "bytes_copied": 0,
                    "bytes_zero_copy": 0}
        return self.channel.stats()

    def outstanding(self) -> int:
        return len(self._pending)

    def _peer_gone(self) -> Optional[str]:
        """Liveness callback for arena back-pressure waits."""
        if self.dead:
            return "client marked dead"
        if self.proc is not None:
            code = self.proc.poll()
            if code is not None:
                return f"worker exited with code {code}"
        return None

    # -- lifecycle -------------------------------------------------------
    def _wrap_faults(self, channel):
        return (channel if self.fault_spec is None
                else FaultyChannel(channel, self.fault_spec))

    def _connect_remote(self):
        """Attach to a standalone worker at ``host:port`` (the worker's
        ``--port`` / ``RPC_PORT=`` mode). Connect honours the spawn
        timeout; read deadlines ride the normal ``wait`` machinery. The
        first ping is the readiness barrier exactly as for a spawned
        child."""
        host, _, port = self.endpoint.rpartition(":")
        try:
            sock = socket.create_connection(
                (host or "127.0.0.1", int(port)),
                timeout=min(10.0, self.spawn_timeout_s))
        except OSError as e:
            self.dead = True
            raise ShardWorkerDied(
                f"shard {self.shard_index} worker (endpoint "
                f"{self.endpoint}) refused the connection ({e})") from e
        sock.settimeout(None)
        self.channel = self._wrap_faults(StreamChannel(sock))
        self.dead = False
        try:
            return self.call("ping", {}, timeout=self.spawn_timeout_s)
        except BaseException:
            self.dead = True
            ch, self.channel = self.channel, None
            if ch is not None:
                ch.close()
            raise

    def spawn(self):
        if self.endpoint is not None:
            return self._connect_remote()
        arena = None
        if self.transport == "shm":
            path = arena_path(self.shard_index, self.generation,
                              self.arena_dir)
            try:
                arena = ShmArena.create(path, self.arena_bytes,
                                        self.generation)
            except OSError:
                # no usable shm/tmp space — the stream path always works
                self.transport = "socket"
        parent, child = socket.socketpair()
        cmd = [sys.executable, "-m", "repro.serving.worker",
               "--shard-dir", self.shard_dir,
               "--shard-index", str(self.shard_index),
               "--mode", self.mode,
               "--fd", str(child.fileno()),
               "--transport", self.transport,
               "--plaid-json", json.dumps(self.plaid_params),
               "--ms-json", json.dumps(self.ms_params)]
        if arena is not None:
            cmd += ["--arena", arena.path]
        env = dict(os.environ if self.env is None else self.env)
        env["PYTHONPATH"] = _src_pythonpath()
        self.proc = subprocess.Popen(cmd, pass_fds=(child.fileno(),),
                                     env=env, stdin=subprocess.DEVNULL)
        child.close()
        if arena is not None:
            self.channel = self._wrap_faults(ShmChannel(
                parent, arena, liveness=self._peer_gone,
                alloc_timeout_s=min(60.0, self.call_timeout_s)))
        else:
            self.channel = self._wrap_faults(StreamChannel(parent))
        self.dead = False
        try:
            # first ping doubles as the readiness barrier: the worker
            # replies only after importing jax and mapping its subtree
            result = self.call("ping", {}, timeout=self.spawn_timeout_s)
        except BaseException:
            # a worker that hung or died during startup must be reaped
            # here — the caller has no client slot for it yet, so an
            # unreaped child would be a permanent orphan
            try:
                self.proc.kill()
            except OSError:
                pass
            self.proc.wait()
            self.dead = True
            if arena is not None:
                arena.unlink()
            raise
        if arena is not None:
            # both sides have the arena mapped now; dropping the name
            # means a crashed pair can never leak a /dev/shm file
            arena.unlink()
        return result

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else None

    def alive(self) -> bool:
        if self.endpoint is not None:
            # no child to poll — liveness is the connection itself
            return not self.dead and self.channel is not None
        return (not self.dead and self.proc is not None
                and self.proc.poll() is None)

    # -- request/response ------------------------------------------------
    def call_async(self, op: str, payload: Any,
                   timeout_ms: Optional[float] = None) -> _Reply:
        rep = _Reply()
        if timeout_ms is not None:
            rep.deadline = time.monotonic() + timeout_ms / 1e3
        with self._send_lock:
            if self.dead or self.channel is None:
                raise self._died_error("is not running")
            try:
                self.channel.send({"op": op, "payload": payload})
            except OSError as e:
                # includes ArenaDead (ConnectionError): the ring filled
                # past its deadline or the peer vanished mid-alloc
                self._mark_dead()
                raise self._died_error(f"send failed ({e})") from e
            self._pending.append(rep)
        return rep

    def wait(self, rep: _Reply, timeout: Optional[float] = None,
             kill_on_timeout: bool = True):
        """Wait for one handle; any waiter pumps the shared channel, and
        frames resolve pending handles strictly in FIFO order.

        ``kill_on_timeout=False`` makes the deadline *soft*: expiry
        raises :class:`ShardWorkerError` without marking the worker
        dead — the discipline for health/heartbeat polls, which queue
        FIFO behind real work and must never kill a worker that is
        merely busy (a first-shape compile easily exceeds a monitor's
        patience). The abandoned reply stays pending and is consumed,
        in order, by the next waiter."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.call_timeout_s)
        while not rep.event.is_set():
            if not self._recv_lock.acquire(timeout=0.02):
                continue
            try:
                if rep.event.is_set():
                    break
                now = time.monotonic()
                if rep.deadline is not None and rep.deadline <= now \
                        and rep.deadline <= deadline:
                    # explicit per-op deadline: the worker is hung (or
                    # the request was lost on the wire). Tear the
                    # connection down — replies queued behind the
                    # expired one would desequence the FIFO — and let
                    # the router fail over to a sibling replica.
                    self._mark_dead()
                    raise DeadlineExceeded(
                        f"shard {self.shard_index} per-op deadline "
                        f"exceeded")
                remaining = deadline - now
                if rep.deadline is not None:
                    remaining = min(remaining, rep.deadline - now)
                if remaining <= 0:
                    if not kill_on_timeout:
                        raise ShardWorkerError(
                            f"shard {self.shard_index} soft RPC "
                            f"deadline expired (worker busy)")
                    self._mark_dead()
                    raise self._died_error("RPC timed out")
                ch = self.channel
                if ch is None:
                    # a concurrent _mark_dead (send failure on another
                    # thread) dropped the channel between our deadline
                    # check and this pump; the pending replies are
                    # already resolved with errors
                    raise self._died_error(
                        "died while a reply was pending")
                try:
                    msg = ch.pump(min(remaining, 1.0))
                except (OSError, ConnectionError, ValueError,
                        RuntimeError) as e:
                    self._mark_dead()
                    raise self._died_error(f"recv failed ({e})") from e
                if msg is None:
                    continue               # slice expired; frame intact
                try:
                    head = self._pending.popleft()
                except IndexError:
                    # a concurrent _mark_dead (send failure on another
                    # thread) drained the deque between our pump and
                    # this pop — the client is dead, not corrupted
                    raise self._died_error(
                        "reply arrived after the client was marked "
                        "dead")
                head.resolve(value=msg)
            finally:
                self._recv_lock.release()
        if rep.error is not None:
            raise rep.error
        msg = rep.value
        if not msg.get("ok", False):
            raise ShardWorkerError(
                f"shard {self.shard_index} op failed:\n{msg.get('error')}")
        return msg.get("result")

    def call(self, op: str, payload: Any,
             timeout: Optional[float] = None,
             kill_on_timeout: bool = True,
             timeout_ms: Optional[float] = None):
        return self.wait(self.call_async(op, payload,
                                         timeout_ms=timeout_ms),
                         timeout=timeout,
                         kill_on_timeout=kill_on_timeout)

    # -- failure / shutdown ----------------------------------------------
    def _mark_dead(self):
        # dead=True first: an arena producer blocked on ring space polls
        # the liveness callback and bails out on this flag
        self.dead = True
        # wake any sender blocked in a socket send on a full pipe
        # *before* taking the send lock it holds — shutdown errors the
        # send out
        sock = self.sock
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        err = self._died_error("died mid-conversation")
        with self._send_lock:
            while self._pending:
                self._pending.popleft().resolve(error=err)

    def _died_error(self, why: str) -> ShardWorkerDied:
        if self.endpoint is not None:
            return ShardWorkerDied(
                f"shard {self.shard_index} worker (endpoint "
                f"{self.endpoint}) {why}")
        code = self.proc.poll() if self.proc is not None else None
        tail = "" if code is None else f"; exit code {code}"
        return ShardWorkerDied(
            f"shard {self.shard_index} worker (pid {self.pid}) {why}"
            f"{tail}")

    def terminate(self, grace_s: float = 5.0) -> Optional[int]:
        """Graceful shutdown escalation: ``shutdown`` RPC → SIGTERM →
        SIGKILL. Always reaps; returns the exit code."""
        if self.endpoint is not None:
            # a remote worker outlives its coordinators: detaching just
            # closes the connection (the worker's accept loop serves
            # the next one). Killing shared fleet infrastructure from a
            # client would be a layering violation.
            self.dead = True
            if self.channel is not None:
                self.channel.close()
                self.channel = None
            return None
        if self.proc is None:
            return None
        if self.proc.poll() is None and not self.dead:
            try:
                self.call("shutdown", {}, timeout=grace_s)
            except (ShardWorkerDied, ShardWorkerError):
                pass
        try:
            self.proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            try:
                self.proc.send_signal(signal.SIGTERM)
                self.proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        self.dead = True
        if self.channel is not None:
            self.channel.close()
            self.channel = None
        return self.proc.returncode
