"""Layered shard transport: codec ⇄ framing ⇄ channel ⇄ client.

* :mod:`~repro.serving.transport.codec` — message values ⇄ control
  bytes, ndarrays as ``(dtype, shape, locator)`` with pluggable
  sink/resolver seams (msgpack + dependency-free fallback).
* :mod:`~repro.serving.transport.framing` — length-prefixed frames;
  multi-part frames gather tensor segments into a ``sendmsg`` iovec.
* :mod:`~repro.serving.transport.shm` — shared-memory ring arenas
  (zero-copy tensor transport with back-pressure and crash-safe
  generations).
* :mod:`~repro.serving.transport.channel` — :class:`StreamChannel`
  (portable socketpair) and :class:`ShmChannel` (arena-backed).
* :mod:`~repro.serving.transport.client` —
  :class:`ShardWorkerClient`, the coordinator-side worker handle.
"""

from repro.serving.transport.channel import (ShmChannel, StreamChannel,
                                             _FramedChannel)
from repro.serving.transport.client import (DEFAULT_ARENA_BYTES,
                                            ShardWorkerClient, _Reply,
                                            _src_pythonpath)
from repro.serving.transport.codec import (HAVE_MSGPACK, decode,
                                           decode_control, encode,
                                           encode_control)
from repro.serving.transport.errors import (ArenaDead, DeadlineExceeded,
                                            ShardUnavailable,
                                            ShardWorkerDied,
                                            ShardWorkerError)
from repro.serving.transport.faults import FaultSpec, FaultyChannel
from repro.serving.transport.framing import (SegmentSink, frame_buffers,
                                             parse_payload, recv_msg,
                                             send_msg, sendmsg_gather)
from repro.serving.transport.shm import (RING_C2W, RING_W2C, ArenaSink,
                                         ShmArena, arena_path,
                                         default_arena_dir)

__all__ = [
    "ArenaDead", "ArenaSink", "DEFAULT_ARENA_BYTES", "DeadlineExceeded",
    "FaultSpec", "FaultyChannel", "HAVE_MSGPACK",
    "RING_C2W", "RING_W2C", "SegmentSink", "ShardUnavailable",
    "ShardWorkerClient",
    "ShardWorkerDied", "ShardWorkerError", "ShmArena", "ShmChannel",
    "StreamChannel", "_FramedChannel", "_Reply", "_src_pythonpath",
    "arena_path", "decode", "decode_control", "default_arena_dir",
    "encode", "encode_control", "frame_buffers", "parse_payload",
    "recv_msg", "send_msg", "sendmsg_gather",
]
