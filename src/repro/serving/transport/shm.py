"""Shared-memory ring arenas for zero-copy tensor transport.

One mmap'd file per worker (created in ``/dev/shm`` when present)
carries two single-producer/single-consumer byte rings: ring 0 is
coordinator→worker (request tensors), ring 1 is worker→coordinator
(reply scores). The producer writes each ndarray **once** into its TX
ring and ships only an ``("arena", generation, start, span, nbytes)``
locator inside the control frame; the consumer maps the span directly
as a read-only numpy view — neither side serializes or memcpy's tensor
bytes a second time, and nothing bulk crosses the socket.

Ring protocol (crash-safe by construction):

* ``head``/``tail`` are *monotonic* byte counters in the ring header —
  the producer owns ``head``, the consumer owns ``tail``, each counter
  has exactly one writer, and aligned 8-byte loads/stores make the
  pair safe without cross-process locks. Free space is
  ``capacity - (head - tail)``; a span that would straddle the ring
  end pads to the start (the pad belongs to the span, so release
  accounting never needs to know about it).
* **Back-pressure**: ``put`` blocks (polling) while the ring is full,
  checking a liveness callback every few ms — a dead peer surfaces as
  :class:`~repro.serving.transport.errors.ArenaDead` (→
  ``ShardWorkerDied``), never a hang. A bounded ring is the memory
  cap: in-flight tensor bytes per worker never exceed 2×``ring_bytes``.
* **Consumer release**: decoded views carry a ``weakref.finalize`` that
  returns their span when the last view dies; out-of-order releases
  are held in a local heap and ``tail`` advances only through the
  contiguous frontier, so lifetimes need no discipline from callers.
* **Epoch/generation header**: the arena file records the generation
  the coordinator created it with (bumped per respawn); every locator
  embeds it and the consumer rejects mismatches. A dead worker can
  never wedge the coordinator — its arena is simply abandoned (views
  into it stay valid while referenced; the file itself is unlinked at
  spawn time once both sides have mapped it) and the respawned worker
  gets a fresh arena at the next generation.
"""

from __future__ import annotations

import heapq
import mmap
import os
import struct
import tempfile
import threading
import time
import uuid
import weakref
from typing import Callable, Optional

import numpy as np

from repro.serving.transport.errors import ArenaDead

_MAGIC = 0x434F4C53484D4131        # "COLSHMA1"
_VERSION = 1
_GHDR = 64                         # global header bytes
_RHDR = 64                         # per-ring header bytes
_ALIGN = 64                        # span alignment (cache line)
_U64 = struct.Struct("<Q")

RING_C2W = 0                       # coordinator → worker
RING_W2C = 1                       # worker → coordinator

# a single array larger than this fraction of the ring falls back to
# an in-frame socket segment instead of wedging on back-pressure
OVERSIZE_FRACTION = 0.5


def _align(n: int) -> int:
    return max(_ALIGN, (n + _ALIGN - 1) & ~(_ALIGN - 1))


def default_arena_dir() -> str:
    """tmpfs when the platform has it (zero disk traffic), else the
    regular tempdir (still page-cache backed)."""
    shm = "/dev/shm"
    if os.path.isdir(shm) and os.access(shm, os.W_OK):
        return shm
    return tempfile.gettempdir()


def arena_path(shard_index: int, generation: int,
               base_dir: Optional[str] = None) -> str:
    return os.path.join(
        base_dir or default_arena_dir(),
        f"repro-shard{shard_index}-g{generation}-{os.getpid()}-"
        f"{uuid.uuid4().hex[:8]}.arena")


class _Ring:
    """One SPSC byte ring inside the arena mapping."""

    def __init__(self, mm: mmap.mmap, hdr_off: int, data_off: int,
                 cap: int, generation: int):
        self._mm = mm
        self._hdr = hdr_off
        self._data = memoryview(mm)[data_off:data_off + cap]
        self.cap = cap
        self.generation = generation
        self._alloc_lock = threading.Lock()
        self._rel_lock = threading.Lock()
        self._released: list = []          # (start, span) min-heap

    # -- shared counters (single writer each; aligned 8-byte access) --
    def _head(self) -> int:
        return _U64.unpack_from(self._mm, self._hdr)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self._mm, self._hdr + 8)[0]

    def _set_head(self, v: int):
        _U64.pack_into(self._mm, self._hdr, v)

    def _set_tail(self, v: int):
        _U64.pack_into(self._mm, self._hdr + 8, v)

    def used_bytes(self) -> int:
        return self._head() - self._tail()

    # -- producer ------------------------------------------------------
    def put(self, arr: np.ndarray, *, timeout_s: float = 60.0,
            liveness: Optional[Callable[[], Optional[str]]] = None) \
            -> tuple:
        """Write ``arr`` once into the ring; returns its
        ``("arena", generation, start, span, nbytes)`` locator.
        Blocks under back-pressure; raises :class:`ArenaDead` when the
        peer dies or the deadline passes."""
        need = _align(arr.nbytes)
        deadline = time.monotonic() + timeout_s
        with self._alloc_lock:
            while True:
                head = self._head()
                pos = head % self.cap
                pad = self.cap - pos if pos + need > self.cap else 0
                span = pad + need
                if self.used_bytes() + span <= self.cap:
                    break
                if liveness is not None:
                    why = liveness()
                    if why:
                        raise ArenaDead(
                            f"arena peer gone while waiting for ring "
                            f"space ({why})")
                if time.monotonic() > deadline:
                    raise ArenaDead(
                        f"timed out after {timeout_s:.0f}s waiting for "
                        f"{need} free arena bytes (capacity {self.cap}; "
                        f"raise arena_bytes or lower pipeline depth)")
                time.sleep(0.002)
            data_pos = (head + pad) % self.cap
            if arr.nbytes:
                dst = np.frombuffer(self._data, dtype=arr.dtype,
                                    count=arr.size, offset=data_pos)
                # single copy, handles strided sources, preserves bits
                np.copyto(dst.reshape(arr.shape), arr, casting="no")
            self._set_head(head + span)
        return ("arena", self.generation, head, span, arr.nbytes)

    # -- consumer ------------------------------------------------------
    def take(self, start: int, span: int, nbytes: int, dtype_str: str,
             shape) -> np.ndarray:
        """Map a produced span as a read-only ndarray view. The span is
        released back to the producer when the last view dies (weakref
        finalizer) — no copy, no explicit free."""
        dt = np.dtype(dtype_str)
        pad = span - _align(nbytes)
        data_pos = (start + pad) % self.cap
        base = np.frombuffer(self._data[data_pos:data_pos + nbytes],
                             dtype=dt)
        base.flags.writeable = False     # shared bytes: no mutation
        weakref.finalize(base, self.release, start, span)
        return base.reshape(shape)

    def release(self, start: int, span: int):
        """Return a span; ``tail`` advances through the contiguous
        released frontier (out-of-order releases wait in a heap)."""
        try:
            with self._rel_lock:
                heapq.heappush(self._released, (start, span))
                tail = self._tail()
                while self._released and self._released[0][0] == tail:
                    s, sp = heapq.heappop(self._released)
                    tail = s + sp
                self._set_tail(tail)
        except ValueError:               # arena unmapped at shutdown
            pass


class ShmArena:
    """Two rings in one mmap'd file (layout: global header, ring 0
    header+data, ring 1 header+data)."""

    def __init__(self, path: str, mm: mmap.mmap, generation: int,
                 ring_bytes: int):
        self.path = path
        self._mm = mm
        self.generation = generation
        self.ring_bytes = ring_bytes
        self._rings = (
            _Ring(mm, _GHDR, _GHDR + _RHDR, ring_bytes, generation),
            _Ring(mm, _GHDR + _RHDR + ring_bytes,
                  _GHDR + 2 * _RHDR + ring_bytes, ring_bytes,
                  generation),
        )

    @staticmethod
    def _total(ring_bytes: int) -> int:
        return _GHDR + 2 * (_RHDR + ring_bytes)

    @classmethod
    def create(cls, path: str, ring_bytes: int,
               generation: int) -> "ShmArena":
        ring_bytes = max(1 << 20, (ring_bytes + 4095) & ~4095)
        fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_RDWR, 0o600)
        try:
            os.ftruncate(fd, cls._total(ring_bytes))
            mm = mmap.mmap(fd, cls._total(ring_bytes))
        finally:
            os.close(fd)
        struct.pack_into("<QIIQQ", mm, 0, _MAGIC, _VERSION, 0,
                         generation, ring_bytes)
        return cls(path, mm, generation, ring_bytes)

    @classmethod
    def open(cls, path: str) -> "ShmArena":
        fd = os.open(path, os.O_RDWR)
        try:
            size = os.fstat(fd).st_size
            mm = mmap.mmap(fd, size)
        finally:
            os.close(fd)
        magic, version, _, generation, ring_bytes = struct.unpack_from(
            "<QIIQQ", mm, 0)
        if magic != _MAGIC or version != _VERSION:
            mm.close()
            raise ValueError(f"{path}: not a shard arena "
                             f"(magic {magic:#x} v{version})")
        if size != cls._total(ring_bytes):
            mm.close()
            raise ValueError(f"{path}: truncated arena ({size} bytes)")
        return cls(path, mm, generation, ring_bytes)

    def ring(self, idx: int) -> _Ring:
        return self._rings[idx]

    def unlink(self):
        try:
            os.unlink(self.path)
        except OSError:
            pass

    def close(self):
        """Best-effort unmap. Live views keep the buffer exported —
        mmap.close then raises BufferError and the mapping stays until
        the views die (their finalizers hold the ring)."""
        try:
            self._mm.close()
        except (BufferError, ValueError):
            pass


#: Arrays below this ride inline in the control frame instead of the
#: ring. A ring span has a fixed cost (aligned alloc, mapped view,
#: finalizer, frontier release) of ~20-30us per array that only pays
#: for itself once the saved memcpy is big enough — measured crossover
#: on CPU is ~100KB, so small per-query vectors inline and only the
#: fat candidate/score tensors take the zero-copy path.
ARENA_MIN_BYTES = 64 << 10


class ArenaSink:
    """Encode-time ndarray sink for the shm channel: big tensors go
    into the TX ring (one write, zero serialization); small arrays
    inline in the control frame (span bookkeeping costs more than a
    small memcpy saves); arrays too large for the ring fall back to an
    in-frame socket segment (never wedge on impossible back-pressure)."""

    __slots__ = ("ring", "seg", "timeout_s", "liveness", "min_bytes",
                 "arena_bytes")

    def __init__(self, ring: _Ring, seg_sink, *, timeout_s: float = 60.0,
                 liveness=None, min_bytes: int = ARENA_MIN_BYTES):
        self.ring = ring
        self.seg = seg_sink
        self.timeout_s = timeout_s
        self.liveness = liveness
        self.min_bytes = min_bytes
        self.arena_bytes = 0

    def put(self, arr: np.ndarray) -> Optional[tuple]:
        n = arr.nbytes
        if n < self.min_bytes:
            return None
        if _align(n) > self.ring.cap * OVERSIZE_FRACTION:
            return self.seg.put(arr)
        loc = self.ring.put(arr, timeout_s=self.timeout_s,
                            liveness=self.liveness)
        self.arena_bytes += n
        return loc
