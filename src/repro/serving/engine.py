"""Scoring engine: the per-request work unit behind the server.

The paper's concurrency fix was releasing the GIL around ColBERT's C++
extensions; in this stack the same property holds natively — JAX device
dispatch releases the GIL, so a thread pool scales until the backend
saturates. The engine is stateless per request and thread-safe: all
mutable state (page-cache stats) is guarded or append-only.

With ``pipeline_depth >= 2`` the engine executes micro-batches through
the stage-graph pipeline (`repro.serving.pipeline`): each method's
compiled :class:`StagePlan` runs on per-stage workers connected by
bounded queues, so micro-batch N+1's host mmap gather overlaps
micro-batch N's device dispatch. ``process_batch_async`` feeds the
pipeline head and returns a Future resolved at the tail;
``pipeline_depth=1`` (default) keeps the synchronous path.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.core.multistage import MultiStageRetriever
from repro.serving.context import (
    ADMIT_DEGRADED,
    ADMIT_FULL,
    CacheHierarchy,
    RequestContext,
    exact_cache_key,
    freeze,
    query_digest,
    stage1_cache_key,
)
from repro.serving.pipeline import (
    PipelineExecutor,
    PipelineStopped,
    gather_futures,
)


@dataclasses.dataclass
class Request:
    qid: int
    method: str                      # colbert | splade | rerank | hybrid
    q_emb: Optional[np.ndarray] = None
    term_ids: Optional[np.ndarray] = None
    term_weights: Optional[np.ndarray] = None
    k: int = 100
    alpha: Optional[float] = None
    t_arrival: float = 0.0
    deadline_ms: Optional[float] = None   # per-request latency budget
    trace_id: Optional[int] = None        # load-trace identity (repeats)
    # typed lifecycle record (cache keys, admission class); built by
    # the engine/server on demand, carried with the request after that
    ctx: Optional[RequestContext] = None


@dataclasses.dataclass
class Result:
    qid: int
    pids: np.ndarray
    scores: np.ndarray
    t_arrival: float
    t_start: float
    t_done: float
    # degraded answers: shard groups missing replicas, or admission
    # control downgrading the request to the splade-only plan; the
    # reason code says which
    degraded: bool = False
    missing_shards: tuple = ()
    degrade_reason: str = ""
    cache_hit: bool = False

    @property
    def latency(self) -> float:
        """Client-observed latency (includes queueing) — what the paper
        reports at p95."""
        return self.t_done - self.t_arrival

    @property
    def service_time(self) -> float:
        return self.t_done - self.t_start


class ServeEngine:
    def __init__(self, retriever: MultiStageRetriever,
                 splade_backend: Optional[str] = None,
                 pipeline_depth: int = 1,
                 pipeline_workers: str = "single",
                 own_retriever: bool = False,
                 caches: Optional[CacheHierarchy] = None):
        """``splade_backend`` (host | jax | pallas) switches the
        retriever's stage-1 scorer at construction time — a convenience
        for retrievers built elsewhere, NOT a per-engine scope: the
        retriever owns the setting, so a later ``set_splade_backend``
        (or another engine constructed over the same retriever) wins.
        jax/pallas also pre-materialise the padded-postings device cache
        so the first request doesn't pay the transfer.

        ``pipeline_depth``: 1 = synchronous batches (classic path);
        >= 2 = stage-graph pipelining with that many batches in flight
        (2 = double-buffered). ``pipeline_workers``: executor scheduling
        mode — ``"single"`` (software pipelining; default) or ``"kind"``
        (host/device worker threads; see ``PipelineExecutor``).
        Pipelining needs a retriever that can ``compile_plan``; others
        silently stay synchronous.

        ``own_retriever=True`` transfers the retriever's lifecycle to
        this engine: ``close()`` also calls ``retriever.close()`` when
        it has one. Launchers set it so a process-shard group's worker
        processes are reaped on every exit path (no orphans); leave it
        False when the retriever is shared across engines.

        ``caches``: optional :class:`CacheHierarchy`. The exact result
        cache is consulted/filled by the engine itself; the stage-1
        cache is attached to the retriever, whose plans consult it via
        the per-request contexts threaded through ``build_batch``."""
        self.retriever = retriever
        self._own_retriever = own_retriever
        self.caches = caches
        if caches is not None and hasattr(retriever, "attach_caches"):
            retriever.attach_caches(caches)
        if splade_backend is not None:
            retriever.set_splade_backend(splade_backend)
            if splade_backend != "host":
                retriever.splade_device_cache()
        self.pipeline_depth = max(1, pipeline_depth)
        self.pipeline_workers = pipeline_workers
        self._pipelines: dict = {}
        self._plock = threading.Lock()
        self._closed = False
        self._lock = threading.Lock()
        self.served = 0

    # -- pipelining ------------------------------------------------------
    @property
    def pipelined(self) -> bool:
        # a live (mutable) retriever must stay synchronous: pipelined
        # executors hold compiled plans across batches, and a mutation
        # or compaction swap mid-flight would race the stage graph
        return (self.pipeline_depth > 1
                and hasattr(self.retriever, "compile_plan")
                and getattr(self.retriever, "live", None) is None)

    def _pipeline(self, method: str) -> PipelineExecutor:
        """Per-method executor over the method's compiled plan, built
        lazily and rebuilt if the plan changed (e.g. stage-1 backend
        switch recompiles the plan). The stale executor is stopped
        OUTSIDE the registry lock — stop() joins worker threads, and
        holding ``_plock`` across that would stall health() and every
        concurrent dispatch."""
        plan = self.retriever.compile_plan(method)   # validates method
        stale = None
        try:
            with self._plock:
                if self._closed:
                    raise PipelineStopped("engine closed")
                px = self._pipelines.get(method)
                if px is not None and (px.plan is not plan
                                       or not px.running):
                    stale, px = px, None
                if px is None:
                    px = PipelineExecutor(
                        plan, depth=self.pipeline_depth,
                        stats=self.retriever.pipeline_stats,
                        workers=self.pipeline_workers)
                    self._pipelines[method] = px
                return px
        finally:
            if stale is not None and stale.running:
                stale.stop()

    def drain_pipelines(self, timeout: Optional[float] = None):
        for px in list(self._pipelines.values()):
            px.drain(timeout)

    def stop_pipelines(self):
        """Stop the stage workers; in-flight micro-batches resolve or
        fail their futures (PipelineStopped). The engine stays usable —
        the next pipelined batch lazily rebuilds its executor — so a
        server can stop()/start() (or a new server can reuse the
        engine) without being wedged."""
        with self._plock:
            pipes = list(self._pipelines.values())
            self._pipelines.clear()
        for px in pipes:
            px.stop()

    def close(self):
        """stop_pipelines() + refuse to build new executors. Terminal.
        An engine that owns its retriever shuts it down too (a process
        shard group terminates and reaps its worker processes here)."""
        with self._plock:
            self._closed = True
        self.stop_pipelines()
        if self._own_retriever and hasattr(self.retriever, "close"):
            self.retriever.close()

    def pipeline_health(self) -> dict:
        """Executor-specific vitals: queue depths per stage, per method.
        (Per-stage timing/pages/overlap live in the retriever's
        ``pipeline_stats`` snapshot, which ``RetrievalServer.health``
        reports — not duplicated here.)"""
        with self._plock:            # _pipeline() inserts concurrently
            pipes = dict(self._pipelines)
        return {"depth": self.pipeline_depth,
                "queues": {m: px.queue_depths()
                           for m, px in pipes.items()}}

    # -- live index ------------------------------------------------------
    def live_upsert(self, doc_emb, term_ids, term_weights,
                    doc_len=None) -> int:
        """Append one document to the retriever's delta segment; returns
        the new global pid. Requires ``enable_live()`` on the
        retriever."""
        return self.retriever.live_upsert(doc_emb, term_ids, term_weights,
                                          doc_len)

    def live_delete(self, pid: int) -> bool:
        return self.retriever.live_delete(pid)

    def live_compact(self):
        return self.retriever.compact_live()

    def live_stats(self):
        live = getattr(self.retriever, "live", None)
        if live is None:
            return None
        return self.retriever.live_stats()

    # -- request context & caching ---------------------------------------
    def context_for(self, req: Request) -> RequestContext:
        """Resolve a request into its typed lifecycle record.

        Cache keys are built from exact byte digests of the request's
        tensors plus the retriever's config salt; they stay ``None``
        when the engine has no caches (or the retriever can't salt
        them), which disables every cache path for that request."""
        retr = self.retriever
        alpha = req.alpha
        if alpha is None:
            alpha = getattr(getattr(retr, "params", None), "alpha", None)
        cache_key = stage1_key = None
        salts = getattr(retr, "cache_salts", None)
        if (salts is not None and self.caches is not None
                and self.caches.enabled):
            exact_salt, stage1_salt = salts(req.method)
            digest = query_digest(req.q_emb, req.term_ids,
                                  req.term_weights)
            cache_key = exact_cache_key(digest, req.method, req.k,
                                        alpha, exact_salt)
            if req.method == "colbert":
                s1_digest = query_digest(req.q_emb, None, None)
            else:
                s1_digest = query_digest(None, req.term_ids,
                                         req.term_weights)
            stage1_key = stage1_cache_key(s1_digest, stage1_salt)
        return RequestContext(
            qid=req.qid, method=req.method, k=req.k, alpha=alpha,
            t_arrival=req.t_arrival, deadline_ms=req.deadline_ms,
            cache_key=cache_key, stage1_key=stage1_key)

    def _ensure_ctxs(self, reqs: list[Request]) -> None:
        if self.caches is None or not self.caches.enabled:
            return
        for r in reqs:
            if r.ctx is None:
                r.ctx = self.context_for(r)

    def _counter(self, name: str, delta: int = 1) -> None:
        ps = getattr(self.retriever, "pipeline_stats", None)
        if ps is not None and hasattr(ps, "counter"):
            ps.counter(name, delta)

    def cache_lookup(self, req: Request,
                     count_miss: bool = True) -> Optional[Result]:
        """Exact-cache probe; a hit IS the answer (bitwise the cold
        result) and counts as served. ``count_miss=False`` for
        advisory probes (the server's submit fast path) so the
        process-time probe stays the authoritative miss count."""
        caches = self.caches
        if caches is None or caches.exact.capacity <= 0:
            return None
        if req.ctx is None:
            req.ctx = self.context_for(req)
        hit = caches.exact.get(req.ctx.cache_key, count_miss=count_miss)
        if hit is None:
            return None
        pids, scores = hit
        self._counter("cache_exact_hits")
        now = time.perf_counter()
        with self._lock:
            self.served += 1
        return Result(qid=req.qid, pids=pids, scores=scores,
                      t_arrival=req.t_arrival, t_start=now, t_done=now,
                      cache_hit=True)

    def _cache_store(self, req: Request, res: Result) -> None:
        """Fill the exact cache from a full-quality answer. Degraded
        answers (missing shards or admission downgrade) are never
        stored — a later healthy run of the same query must not be
        served yesterday's partial result."""
        caches = self.caches
        ctx = req.ctx
        if (caches is None or caches.exact.capacity <= 0
                or ctx is None or ctx.cache_key is None
                or res.degraded or res.cache_hit
                or ctx.admission != ADMIT_FULL):
            return
        caches.exact.put(ctx.cache_key, freeze(res.pids, res.scores),
                         getattr(self.retriever, "index_generation", 0))
        self._counter("cache_exact_stores")

    @staticmethod
    def _effective_method(req: Request) -> str:
        """Admission-degraded hybrid/rerank requests run the cheap
        splade-only plan; everything else keeps its own method."""
        ctx = req.ctx
        if (ctx is not None and ctx.admission == ADMIT_DEGRADED
                and req.method in ("hybrid", "rerank")
                and req.term_ids is not None and len(req.term_ids) > 0):
            return "splade"
        return req.method

    @staticmethod
    def _degrade_info(req: Request, missing: tuple) -> tuple:
        ctx = req.ctx
        adm = ctx is not None and ctx.admission == ADMIT_DEGRADED
        degraded = bool(missing) or adm
        reason = (ctx.admit_reason if adm
                  else ("missing_shards" if missing else ""))
        return degraded, reason

    # -- request execution -----------------------------------------------
    def _missing_shards(self) -> tuple:
        """Missing-shard note of the search this thread just ran
        (degraded shard groups only; () everywhere else)."""
        last = getattr(self.retriever, "last_missing_shards", None)
        return tuple(last()) if last is not None else ()

    def process(self, req: Request) -> Result:
        hit = self.cache_lookup(req)
        if hit is not None:
            return hit
        t_start = time.perf_counter()
        method = self._effective_method(req)
        pids, scores = self.retriever.search(
            method, q_emb=req.q_emb, term_ids=req.term_ids,
            term_weights=req.term_weights, alpha=req.alpha, k=req.k)
        missing = self._missing_shards()
        t_done = time.perf_counter()
        with self._lock:
            self.served += 1
        degraded, reason = self._degrade_info(req, missing)
        res = Result(qid=req.qid, pids=pids, scores=scores,
                     t_arrival=req.t_arrival, t_start=t_start,
                     t_done=t_done, degraded=degraded,
                     missing_shards=missing, degrade_reason=reason)
        self._cache_store(req, res)
        return res

    def process_batch(self, reqs: list[Request]) -> list[Result]:
        """Score a micro-batch in one batched retriever call per method
        group. Per-request results are identical (within fp tolerance) to
        :meth:`process`; requests keep their own ``k``/``alpha``.

        Cache hits are peeled off first; only the misses run the
        retriever. Falls back to sequential processing when the
        retriever has no ``search_batch`` (e.g. test doubles)."""
        if len(reqs) == 1 or not hasattr(self.retriever, "search_batch"):
            return [self.process(r) for r in reqs]

        self._ensure_ctxs(reqs)
        results: list = [None] * len(reqs)
        miss_idx = []
        for i, r in enumerate(reqs):
            hit = self.cache_lookup(r)
            if hit is not None:
                results[i] = hit
            else:
                miss_idx.append(i)
        if not miss_idx:
            return results
        miss = [reqs[i] for i in miss_idx]
        if len(miss) == 1:
            results[miss_idx[0]] = self.process(miss[0])
            return results

        t_start = time.perf_counter()
        methods = [self._effective_method(r) for r in miss]
        k_max = max(r.k for r in miss)
        alphas = [r.alpha for r in miss]
        kwargs = dict(
            q_embs=[r.q_emb for r in miss],
            term_ids=[r.term_ids for r in miss],
            term_weights=[r.term_weights for r in miss],
            alpha=None if all(a is None for a in alphas) else alphas,
            k=k_max)
        if hasattr(self.retriever, "search_batch_ctx"):
            pids, scores, outcome = self.retriever.search_batch_ctx(
                methods, ctxs=[r.ctx for r in miss], **kwargs)
            missing = outcome.missing_shards
        else:
            pids, scores = self.retriever.search_batch(methods, **kwargs)
            missing = self._missing_shards()
        t_done = time.perf_counter()
        with self._lock:
            self.served += len(miss)
        for j, r in enumerate(miss):
            degraded, reason = self._degrade_info(r, missing)
            res = Result(qid=r.qid, pids=pids[j][:r.k],
                         scores=scores[j][:r.k], t_arrival=r.t_arrival,
                         t_start=t_start, t_done=t_done,
                         degraded=degraded, missing_shards=missing,
                         degrade_reason=reason)
            self._cache_store(r, res)
            results[miss_idx[j]] = res
        return results

    def process_batch_async(self, reqs: list[Request]) -> Future:
        """Feed a micro-batch to the stage pipeline; the returned Future
        resolves with the ``list[Result]`` at the pipeline tail.

        Per-request results match :meth:`process_batch` exactly: a
        single-method batch runs its plan as one CandidateBatch; a
        mixed batch is grouped per method, each group submitted to its
        method's executor, and results scattered back into request
        order with the same prefix/padding semantics as the synchronous
        mixed path. ``submit`` blocks while the head queue is full, so
        callers are backpressured by ``pipeline_depth``."""
        if not self.pipelined:
            out: Future = Future()
            out.set_running_or_notify_cancel()
            try:
                out.set_result(self.process_batch(reqs))
            except Exception as e:
                out.set_exception(e)
            return out

        self._ensure_ctxs(reqs)
        hits: list = [None] * len(reqs)
        miss_idx = []
        for i, r in enumerate(reqs):
            hit = self.cache_lookup(r)
            if hit is not None:
                hits[i] = hit
            else:
                miss_idx.append(i)
        if not miss_idx:
            out = Future()
            out.set_running_or_notify_cancel()
            out.set_result(hits)
            return out
        miss = [reqs[i] for i in miss_idx]

        t_start = time.perf_counter()
        n = len(miss)
        k_max = max(r.k for r in miss)
        retr = self.retriever
        methods = [self._effective_method(r) for r in miss]
        raw_alphas = [r.alpha for r in miss]
        alphas = retr._alpha_array(
            None if all(a is None for a in raw_alphas) else raw_alphas, n)

        groups = []                      # (method, idx, CandidateBatch)
        for m in dict.fromkeys(methods):
            idx = [i for i, mi in enumerate(methods) if mi == m]
            cb = retr.build_batch(
                m,
                q_embs=[miss[i].q_emb for i in idx],
                term_ids=[miss[i].term_ids for i in idx],
                term_weights=[miss[i].term_weights for i in idx],
                alphas=alphas[idx], k=k_max,
                ctxs=[miss[i].ctx for i in idx])
            groups.append((m, idx, cb))

        out: Future = Future()
        out.set_running_or_notify_cancel()
        futs = []
        try:
            # resolve every group's executor BEFORE submitting any work:
            # an unknown method then fails the batch without first
            # running (and throwing away) the valid groups' retrieval
            pipes = [self._pipeline(m) for m, _, _ in groups]
            for px, (_, _, cb) in zip(pipes, groups):
                futs.append(px.submit(cb))
        except Exception as e:
            # submit-time failure (unknown method, stopped pipeline):
            # fail the whole batch; the server retries request-by-request
            out.set_exception(e)
            return out

        agg = gather_futures(futs)

        def finish(f: Future):
            e = f.exception()
            if e is not None:
                out.set_exception(e)
                return
            try:
                assembled = self._assemble(miss, groups, f.result(),
                                           n, k_max, t_start)
                full = hits
                for j, res in enumerate(assembled):
                    full[miss_idx[j]] = res
                out.set_result(full)
            except Exception as err:
                out.set_exception(err)

        agg.add_done_callback(finish)
        return out

    def _assemble(self, reqs, groups, cbs, n, k_max, t_start):
        missing: set = set()
        for cb in cbs:
            missing.update(cb.state.get("missing_shards", ()))
        missing = tuple(sorted(missing))
        if len(groups) == 1:
            pids, scores = cbs[0].pids, cbs[0].scores
        else:
            pids = np.full((n, k_max), -1, np.int64)
            scores = np.full((n, k_max), -np.inf, np.float32)
            for (_, idx, _), cb in zip(groups, cbs):
                MultiStageRetriever.scatter_group(pids, scores, idx,
                                                  cb.pids, cb.scores)
        t_done = time.perf_counter()
        with self._lock:
            self.served += n
        out = []
        for i, r in enumerate(reqs):
            degraded, reason = self._degrade_info(r, missing)
            res = Result(qid=r.qid, pids=pids[i][:r.k],
                         scores=scores[i][:r.k], t_arrival=r.t_arrival,
                         t_start=t_start, t_done=t_done,
                         degraded=degraded, missing_shards=missing,
                         degrade_reason=reason)
            self._cache_store(r, res)
            out.append(res)
        return out
