"""Scoring engine: the per-request work unit behind the server.

The paper's concurrency fix was releasing the GIL around ColBERT's C++
extensions; in this stack the same property holds natively — JAX device
dispatch releases the GIL, so a thread pool scales until the backend
saturates. The engine is stateless per request and thread-safe: all
mutable state (page-cache stats) is guarded or append-only.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core.multistage import MultiStageRetriever


@dataclasses.dataclass
class Request:
    qid: int
    method: str                      # colbert | splade | rerank | hybrid
    q_emb: Optional[np.ndarray] = None
    term_ids: Optional[np.ndarray] = None
    term_weights: Optional[np.ndarray] = None
    k: int = 100
    alpha: Optional[float] = None
    t_arrival: float = 0.0


@dataclasses.dataclass
class Result:
    qid: int
    pids: np.ndarray
    scores: np.ndarray
    t_arrival: float
    t_start: float
    t_done: float

    @property
    def latency(self) -> float:
        """Client-observed latency (includes queueing) — what the paper
        reports at p95."""
        return self.t_done - self.t_arrival

    @property
    def service_time(self) -> float:
        return self.t_done - self.t_start


class ServeEngine:
    def __init__(self, retriever: MultiStageRetriever,
                 splade_backend: Optional[str] = None):
        """``splade_backend`` (host | jax | pallas) switches the
        retriever's stage-1 scorer at construction time — a convenience
        for retrievers built elsewhere, NOT a per-engine scope: the
        retriever owns the setting, so a later ``set_splade_backend``
        (or another engine constructed over the same retriever) wins.
        jax/pallas also pre-materialise the padded-postings device cache
        so the first request doesn't pay the transfer."""
        self.retriever = retriever
        if splade_backend is not None:
            retriever.set_splade_backend(splade_backend)
            if splade_backend != "host":
                retriever.splade_device_cache()
        self._lock = threading.Lock()
        self.served = 0

    def process(self, req: Request) -> Result:
        t_start = time.perf_counter()
        pids, scores = self.retriever.search(
            req.method, q_emb=req.q_emb, term_ids=req.term_ids,
            term_weights=req.term_weights, alpha=req.alpha, k=req.k)
        t_done = time.perf_counter()
        with self._lock:
            self.served += 1
        return Result(qid=req.qid, pids=pids, scores=scores,
                      t_arrival=req.t_arrival, t_start=t_start,
                      t_done=t_done)

    def process_batch(self, reqs: list[Request]) -> list[Result]:
        """Score a micro-batch in one batched retriever call per method
        group. Per-request results are identical (within fp tolerance) to
        :meth:`process`; requests keep their own ``k``/``alpha``.

        Falls back to sequential processing when the retriever has no
        ``search_batch`` (e.g. test doubles)."""
        if len(reqs) == 1 or not hasattr(self.retriever, "search_batch"):
            return [self.process(r) for r in reqs]

        t_start = time.perf_counter()
        methods = [r.method for r in reqs]
        k_max = max(r.k for r in reqs)
        alphas = [r.alpha for r in reqs]
        pids, scores = self.retriever.search_batch(
            methods,
            q_embs=[r.q_emb for r in reqs],
            term_ids=[r.term_ids for r in reqs],
            term_weights=[r.term_weights for r in reqs],
            alpha=None if all(a is None for a in alphas) else alphas,
            k=k_max)
        t_done = time.perf_counter()
        with self._lock:
            self.served += len(reqs)
        return [Result(qid=r.qid, pids=pids[i][:r.k], scores=scores[i][:r.k],
                       t_arrival=r.t_arrival, t_start=t_start, t_done=t_done)
                for i, r in enumerate(reqs)]
