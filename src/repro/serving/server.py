"""Concurrent serving: bounded queue + worker pool, plus a TCP front.

Mirrors the paper's server-client architecture: clients submit queries
that are queued and served by ``n_threads`` workers (the paper tunes
this and lands on 1 under load — we keep it a knob and reproduce that
finding in benchmarks/bench_latency.py). Latency is measured from
arrival (enqueue) to completion, so queueing delay is included.

Fault tolerance: ``drain()`` completes in-flight work; a worker that
dies on an exception marks the request failed and the pool replaces
it; ``health()`` reports queue depth and served counts for external
monitors.
"""

from __future__ import annotations

import json
import queue
import socket
import socketserver
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.serving.engine import Request, Result, ServeEngine


class RetrievalServer:
    def __init__(self, engine: ServeEngine, n_threads: int = 1,
                 max_queue: int = 4096):
        self.engine = engine
        self.n_threads = n_threads
        self.queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self.workers: list[threading.Thread] = []
        self.running = False
        self.failed = 0
        self._lock = threading.Lock()

    # -- lifecycle -------------------------------------------------------
    def start(self):
        self.running = True
        for i in range(self.n_threads):
            t = threading.Thread(target=self._worker, name=f"worker-{i}",
                                 daemon=True)
            t.start()
            self.workers.append(t)

    def _worker(self):
        while self.running:
            try:
                item = self.queue.get(timeout=0.1)
            except queue.Empty:
                continue
            req, fut = item
            try:
                fut.set_result(self.engine.process(req))
            except Exception as e:  # replace-on-failure semantics
                with self._lock:
                    self.failed += 1
                fut.set_exception(e)
            finally:
                self.queue.task_done()

    def stop(self):
        self.running = False
        for t in self.workers:
            t.join(timeout=2.0)
        self.workers.clear()

    def drain(self):
        """Complete all queued work (graceful shutdown step 1)."""
        self.queue.join()

    # -- client API -------------------------------------------------------
    def submit(self, req: Request) -> Future:
        req.t_arrival = time.perf_counter()
        fut: Future = Future()
        self.queue.put((req, fut))
        return fut

    def health(self) -> dict:
        return {"queue_depth": self.queue.qsize(),
                "served": self.engine.served,
                "failed": self.failed,
                "workers": sum(t.is_alive() for t in self.workers)}


# ---------------------------------------------------------------------------
# Minimal TCP front (newline-delimited JSON) for the runnable example.
# ---------------------------------------------------------------------------

class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        for line in self.rfile:
            try:
                msg = json.loads(line)
                req = Request(
                    qid=msg["qid"], method=msg.get("method", "hybrid"),
                    q_emb=np.asarray(msg["q_emb"], np.float32)
                    if "q_emb" in msg else None,
                    term_ids=np.asarray(msg.get("term_ids", []), np.int32),
                    term_weights=np.asarray(msg.get("term_weights", []),
                                            np.float32),
                    k=msg.get("k", 10))
                res = self.server.retrieval.submit(req).result(timeout=60)
                out = {"qid": res.qid, "pids": res.pids.tolist(),
                       "scores": [float(s) for s in res.scores],
                       "latency": res.latency}
            except Exception as e:
                out = {"error": str(e)}
            self.wfile.write((json.dumps(out) + "\n").encode())
            self.wfile.flush()


class TCPRetrievalServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, retrieval_server: RetrievalServer):
        super().__init__(addr, _Handler)
        self.retrieval = retrieval_server


def tcp_query(host: str, port: int, payload: dict) -> dict:
    with socket.create_connection((host, port), timeout=60) as s:
        s.sendall((json.dumps(payload) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(65536)
            if not chunk:
                break
            buf += chunk
    return json.loads(buf)
