"""Concurrent serving: bounded queue + worker pool with cross-query
micro-batching, plus a TCP front.

Mirrors the paper's server-client architecture: clients submit queries
that are queued and served by ``n_threads`` workers (the paper tunes
this and lands on 1 under load — we keep it a knob and reproduce that
finding in benchmarks/bench_latency.py). Latency is measured from
arrival (enqueue) to completion, so queueing delay is included.

Micro-batching: with ``max_batch > 1`` a worker that pops a request
keeps collecting queued requests for up to ``batch_timeout_ms`` (or
until ``max_batch``) and serves the group through
``ServeEngine.process_batch`` — one batched device dispatch per stage
and deduplicated mmap gathers across co-batched queries. ``max_batch=1``
preserves strict request-at-a-time behaviour. With ``latency_slo_ms``
set, the effective batch cap adapts: an EWMA of batch service time
shrinks it under SLO pressure and grows it back when there is headroom.

Pipelining: when the engine has ``pipeline_depth >= 2`` the worker no
longer owns a batch end-to-end — it feeds the stage-graph pipeline
(`repro.serving.pipeline`) and moves straight on to collecting the next
micro-batch while the executor resolves futures at the tail, so batch
N+1's host mmap gather overlaps batch N's device scoring.

Fault tolerance: ``drain()`` completes in-flight work; a failing batch
is retried request-by-request so one poisoned query cannot fail its
co-batched neighbours; ``stop()`` fails still-queued futures instead of
leaving clients waiting forever; ``health()`` reports queue depth,
served counts, per-stage EWMA service times / queue depths, and the
measured overlap fraction for external monitors.
"""

from __future__ import annotations

import json
import queue
import signal
import socket
import socketserver
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.serving.admission import AdmissionController, RequestShed
from repro.serving.context import ADMIT_DEGRADED, ADMIT_SHED
from repro.serving.engine import Request, Result, ServeEngine
from repro.serving.pipeline import PipelineStopped


class RetrievalServer:
    def __init__(self, engine: ServeEngine, n_threads: int = 1,
                 max_queue: int = 4096, max_batch: int = 1,
                 batch_timeout_ms: float = 2.0,
                 latency_slo_ms: Optional[float] = None,
                 slo_ewma_alpha: float = 0.25, grow_patience: int = 3,
                 admission: Optional[AdmissionController] = None):
        """``latency_slo_ms`` switches on adaptive micro-batch sizing:
        the effective batch cap shrinks (halves, floor 1) when the EWMA
        of batch service time exceeds the SLO and grows back
        (doubles, ceiling ``max_batch``) after ``grow_patience``
        consecutive under-threshold (< ~70% SLO) observations from
        batches that *fill* the current cap — growth needs evidence at
        the current operating point, not cheap small-batch samples, or
        the cap hunts between sizes and periodically blows the SLO.
        ``max_batch`` stays the hard ceiling; ``None`` keeps the cap
        fixed (PR-1 behaviour).

        ``admission``: optional :class:`AdmissionController`. Each
        ``submit`` is classified against the live per-stage EWMAs: full
        quality, degraded to the splade-only plan, or shed outright
        (the future fails with :class:`RequestShed` before the request
        ever enters the queue)."""
        self.engine = engine
        self.admission = admission
        self.sheds = 0
        self.n_threads = n_threads
        self.max_batch = max(1, max_batch)
        self.batch_timeout_ms = batch_timeout_ms
        self.latency_slo_ms = latency_slo_ms
        self.slo_ewma_alpha = slo_ewma_alpha
        self.grow_patience = max(1, grow_patience)
        self.ewma_latency_ms: Optional[float] = None
        self.batch_cap = self.max_batch      # effective (adaptive) cap
        self._grow_streak = 0
        self.queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self.workers: list[threading.Thread] = []
        self.running = False
        self.failed = 0
        self._lock = threading.Lock()
        self._retry_cond = threading.Condition()
        self._retries = 0                # pipelined failure retries live
        self.tcp: Optional["TCPRetrievalServer"] = None
        self.tcp_port: Optional[int] = None
        self._shutdown_once = threading.Lock()
        self._shut_down = False

    # -- lifecycle -------------------------------------------------------
    def start(self):
        self.running = True
        for i in range(self.n_threads):
            t = threading.Thread(target=self._worker, name=f"worker-{i}",
                                 daemon=True)
            t.start()
            self.workers.append(t)

    def _collect_batch(self, first):
        """Coalesce queued requests behind ``first`` until the current
        (possibly adapted) batch cap or ``batch_timeout_ms`` elapses."""
        batch = [first]
        # one locked read: _observe_latency resizes batch_cap under
        # self._lock from whichever thread served the last batch, and a
        # torn/stale read here could collect against a cap that no
        # longer exists
        with self._lock:
            cap = self.batch_cap
        deadline = time.perf_counter() + self.batch_timeout_ms / 1e3
        while len(batch) < cap:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _observe_latency(self, results):
        """Adaptive micro-batch sizing: feed the served group's service
        time into an EWMA and resize the effective cap against
        ``latency_slo_ms`` (shrink fast, grow cautiously).

        Service time — not client-observed latency — on purpose: queueing
        delay rises exactly when the server is saturated, i.e. when
        *larger* batches are needed; feeding it back into the shrink
        decision would pin the cap at 1 under overload (positive
        feedback). Service time measures what batching actually costs a
        co-batched request."""
        if self.latency_slo_ms is None or not results:
            return
        obs_ms = max(r.service_time for r in results) * 1e3
        with self._lock:
            a = self.slo_ewma_alpha
            self.ewma_latency_ms = (obs_ms if self.ewma_latency_ms is None
                                    else a * obs_ms
                                    + (1 - a) * self.ewma_latency_ms)
            if self.ewma_latency_ms > self.latency_slo_ms:
                self.batch_cap = max(1, self.batch_cap // 2)
                self._grow_streak = 0
            elif (self.ewma_latency_ms < 0.7 * self.latency_slo_ms
                  and len(results) >= self.batch_cap
                  and self.batch_cap < self.max_batch):
                self._grow_streak += 1
                if self._grow_streak >= self.grow_patience:
                    self.batch_cap = min(self.max_batch,
                                         self.batch_cap * 2)
                    self._grow_streak = 0
            else:
                # dead band, or a batch that didn't fill the cap: no
                # evidence about the current operating point
                self._grow_streak = 0

    def _worker(self):
        while self.running:
            try:
                item = self.queue.get(timeout=0.1)
            except queue.Empty:
                continue
            batch = (self._collect_batch(item) if self.max_batch > 1
                     else [item])
            # re-read per iteration, not once at thread start: an engine
            # whose pipeline is rebuilt at runtime (stage-1 backend
            # switch, depth change) must move new batches to the new
            # dispatch path, not keep the one captured at start()
            pipelined = getattr(self.engine, "pipelined", False)
            try:
                if pipelined:
                    # feed the stage pipeline and move on: the tail
                    # resolves the futures while this worker collects
                    # the next micro-batch (gather/score overlap)
                    self._dispatch_pipelined(batch)
                elif len(batch) == 1:
                    self._serve_one(*batch[0])
                else:
                    self._serve_batch(batch)
            finally:
                for _ in batch:
                    self.queue.task_done()

    def _serve_one(self, req, fut, claimed: bool = False):
        # claim the future before any work: once RUNNING, a concurrent
        # client cancel() can no longer race our set_result/set_exception
        if not claimed and not fut.set_running_or_notify_cancel():
            return                       # cancelled while queued
        try:
            res = self.engine.process(req)
        except Exception as e:  # replace-on-failure semantics
            with self._lock:
                self.failed += 1
            fut.set_exception(e)
            return
        fut.set_result(res)
        self._observe_latency([res])

    def _serve_batch(self, batch):
        claimed = [(req, fut) for req, fut in batch
                   if fut.set_running_or_notify_cancel()]
        if not claimed:
            return
        try:
            results = self.engine.process_batch([req for req, _ in claimed])
        except Exception:
            # isolate the poisoned request: retry individually so one bad
            # query cannot fail its co-batched neighbours
            for req, fut in claimed:
                self._serve_one(req, fut, claimed=True)
            return
        for (_, fut), res in zip(claimed, results):
            fut.set_result(res)
        self._observe_latency(results)

    def _dispatch_pipelined(self, batch):
        """Feed the claimed micro-batch to the engine's stage pipeline.
        Blocks only on backpressure (head queue full); completion is
        handled at the pipeline tail by :meth:`_resolve_pipelined`."""
        claimed = [(req, fut) for req, fut in batch
                   if fut.set_running_or_notify_cancel()]
        if not claimed:
            return
        try:
            agg = self.engine.process_batch_async(
                [req for req, _ in claimed])
        except Exception as e:
            for _, fut in claimed:
                fut.set_exception(e)
            with self._lock:
                self.failed += len(claimed)
            return
        agg.add_done_callback(
            lambda f: self._resolve_pipelined(claimed, f))

    def _resolve_pipelined(self, claimed, agg):
        """Tail of the pipeline (runs on a stage worker thread): set
        per-request futures, or — keeping the synchronous path's
        isolation semantics — retry a failed batch request-by-request so
        one poisoned query cannot fail its co-batched neighbours."""
        exc = agg.exception()
        if exc is None:
            # bind once: result() re-derives the list on every call, and
            # the latency observer must see exactly the results the
            # clients got
            results = agg.result()
            for (_, fut), res in zip(claimed, results):
                fut.set_result(res)
            self._observe_latency(results)
            return
        if isinstance(exc, PipelineStopped) and not self.running:
            # server shutdown: fail fast instead of re-serving inline.
            # (A PipelineStopped while the server is alive — e.g. an
            # executor rebuilt by a stage-1 backend switch — falls
            # through to the retry path below instead.)
            with self._lock:
                self.failed += len(claimed)
            for req, fut in claimed:
                fut.set_exception(RuntimeError(
                    f"server stopped mid-flight for qid={req.qid}"))
            return
        # retry on a separate thread: this callback runs on a pipeline
        # stage worker, and a batch of synchronous per-request retrievals
        # here would stall every in-flight batch behind it. Tracked by a
        # counter so drain() waits for retries, not just the pipeline.
        with self._retry_cond:
            self._retries += 1

        def retry():
            try:
                for req, fut in claimed:
                    self._serve_one(req, fut, claimed=True)
            finally:
                with self._retry_cond:
                    self._retries -= 1
                    self._retry_cond.notify_all()

        threading.Thread(target=retry, name="pipeline-retry",
                         daemon=True).start()

    def stop(self):
        self.running = False
        for t in self.workers:
            t.join(timeout=2.0)
        self.workers.clear()
        # stop the stage pipeline: in-flight micro-batches resolve or
        # fail their futures (never hang) before queued ones are failed.
        # stop_pipelines (not close): the engine is caller-owned and must
        # survive a stop()/start() restart
        if hasattr(self.engine, "stop_pipelines"):
            self.engine.stop_pipelines()
        # fail whatever never got served — clients must not hang forever
        # on futures nobody will complete
        while True:
            try:
                req, fut = self.queue.get_nowait()
            except queue.Empty:
                break
            if fut.set_running_or_notify_cancel():
                fut.set_exception(
                    RuntimeError(f"server stopped before serving "
                                 f"qid={req.qid}"))
            self.queue.task_done()

    def drain(self):
        """Complete all queued work (graceful shutdown step 1). With
        pipelining, also waits for in-flight micro-batches to clear the
        stage pipeline (queue.join() returns once they are *fed*) and
        for any failure-path retries still re-serving requests."""
        self.queue.join()
        if getattr(self.engine, "pipelined", False):
            self.engine.drain_pipelines()
        with self._retry_cond:
            self._retry_cond.wait_for(lambda: self._retries == 0)

    # -- TCP front / graceful shutdown ------------------------------------
    def serve_tcp(self, host: str = "0.0.0.0", port: int = 0
                  ) -> "TCPRetrievalServer":
        """Attach the TCP front. ``port=0`` binds an ephemeral port —
        the kernel picks a free one, so CI smokes can never clash — and
        the *real* port is reported in :attr:`tcp_port`, ``health()``,
        and on stdout. The caller runs ``.serve_forever()`` (or puts it
        on a thread)."""
        self.tcp = TCPRetrievalServer((host, port), self)
        self.tcp_port = self.tcp.server_address[1]
        print(f"RETRIEVAL_PORT={self.tcp_port}", flush=True)
        return self.tcp

    def shutdown_gracefully(self):
        """Drain, then stop — the SIGTERM path. Stops accepting new TCP
        connections first, completes everything queued (including
        in-flight pipeline batches and failure retries), then stops the
        workers. Idempotent, and the lock is held for the *whole*
        drain: a second caller (the launcher's exit path racing the
        SIGTERM handler thread) blocks until the drain completes
        instead of returning early and tearing the engine down under
        in-flight batches."""
        with self._shutdown_once:
            if self._shut_down:
                return
            if self.tcp is not None:
                self.tcp.shutdown()
            self.drain()
            self.stop()
            self._shut_down = True

    def install_sigterm_handler(self):
        """Route SIGTERM to :meth:`shutdown_gracefully` on a separate
        thread (``TCPServer.shutdown`` deadlocks if called from the
        thread running ``serve_forever``, which is where the signal
        lands). Returns the previous handler. Main thread only — signal
        registration is a CPython restriction."""
        def handler(signum, frame):
            threading.Thread(target=self.shutdown_gracefully,
                             name="sigterm-drain", daemon=True).start()

        return signal.signal(signal.SIGTERM, handler)

    # -- client API -------------------------------------------------------
    def submit(self, req: Request) -> Future:
        """Front door: exact-cache fast path → admission → queue.

        A cache hit resolves the future immediately without touching
        the queue (bitwise the cold answer, near-zero latency). The
        admission controller then classifies the request against the
        live per-stage EWMAs: a shed fails the future with
        :class:`RequestShed`; a degrade stamps the request's context so
        the engine runs the splade-only plan."""
        req.t_arrival = time.perf_counter()
        fut: Future = Future()
        engine = self.engine
        if (req.ctx is None and hasattr(engine, "context_for")
                and (self.admission is not None
                     or getattr(engine, "caches", None) is not None)):
            req.ctx = engine.context_for(req)
        hit = (engine.cache_lookup(req, count_miss=False)
               if hasattr(engine, "cache_lookup") else None)
        if hit is not None:
            fut.set_running_or_notify_cancel()
            fut.set_result(hit)
            return fut
        if self.admission is not None:
            retr = getattr(engine, "retriever", None)
            stats = getattr(retr, "pipeline_stats", None)
            snap = stats.snapshot()["stages"] if stats is not None else {}
            degradable = (req.method in ("hybrid", "rerank")
                          and req.term_ids is not None
                          and len(req.term_ids) > 0)
            with self._lock:
                cap = self.batch_cap
            d = self.admission.decide(
                req.method, degradable, snap,
                queue_depth=self.queue.qsize(), batch_cap=cap,
                deadline_ms=req.deadline_ms)
            if d.admission == ADMIT_SHED:
                with self._lock:
                    self.sheds += 1
                if stats is not None and hasattr(stats, "counter"):
                    stats.counter("admission_sheds")
                fut.set_running_or_notify_cancel()
                fut.set_exception(RequestShed(d.reason,
                                              d.predicted_full_ms))
                return fut
            if d.admission == ADMIT_DEGRADED and req.ctx is not None:
                req.ctx = req.ctx.degraded(d.reason)
                if stats is not None and hasattr(stats, "counter"):
                    stats.counter("admission_degraded")
        self.queue.put((req, fut))
        return fut

    def health(self) -> dict:
        """Server vitals. Beyond the batch-level EWMA, reports the
        per-stage instrumentation (EWMA service time, wall, queue wait,
        mmap pages) whenever the retriever keeps one, and — under
        pipelining — per-stage queue depths and the measured
        host/device overlap fraction, so the adaptive ``latency_slo_ms``
        controller can be debugged per stage."""
        h = {"queue_depth": self.queue.qsize(),
             "served": self.engine.served,
             "failed": self.failed,
             "sheds": self.sheds,
             "workers": sum(t.is_alive() for t in self.workers),
             "batch_cap": self.batch_cap,
             "ewma_latency_ms": self.ewma_latency_ms,
             "port": self.tcp_port,
             "n_shards": getattr(getattr(self.engine, "retriever", None),
                                 "n_shards", 1)}
        retr = getattr(self.engine, "retriever", None)
        if hasattr(retr, "worker_health"):
            # process-group backend: per-shard worker vitals (pid, RSS,
            # mmap segment bytes, restarts, replica health) for
            # external monitors
            h["shard_workers"] = retr.worker_health()
        if hasattr(retr, "degraded_shards"):
            h["degraded_shards"] = retr.degraded_shards()
            h["allow_degraded"] = getattr(retr, "allow_degraded", False)
        stats = getattr(getattr(self.engine, "retriever", None),
                        "pipeline_stats", None)
        if stats is not None:
            snap = stats.snapshot()
            h["stages"] = {
                name: {"ewma_ms": r["ewma_ms"], "wall_s": r["wall_s"],
                       "dispatches": r["dispatches"],
                       "device_dispatches": r["device_dispatches"],
                       "queue_wait_s": r["queue_wait_s"],
                       "pages_touched": r["pages_touched"]}
                for name, r in snap["stages"].items()}
            h["overlap_fraction"] = snap["overlap_fraction"]
            h["counters"] = dict(snap.get("counters", {}))
        live = getattr(retr, "live", None)
        if live is not None:
            h["live"] = (retr.live_stats() if hasattr(retr, "live_stats")
                         else live.stats())
            h["index_generation"] = getattr(retr, "index_generation", 0)
        if self.admission is not None:
            h["admission"] = self.admission.stats()
        caches = getattr(self.engine, "caches", None)
        if caches is not None:
            h["caches"] = caches.stats()
        if getattr(self.engine, "pipelined", False):
            h["pipeline"] = self.engine.pipeline_health()
        return h


# ---------------------------------------------------------------------------
# Minimal TCP front (newline-delimited JSON) for the runnable example.
# ---------------------------------------------------------------------------

class _Handler(socketserver.StreamRequestHandler):
    def _admin(self, msg, op):
        """Control-plane ops share the query socket, dispatched on an
        explicit ``op`` key so plain query lines stay wire-compatible:
        live mutations (upsert/delete), compaction, and health/stats.
        Mutations go through the engine pass-throughs, so they require
        a live-enabled retriever (``--live``) and fail cleanly — as an
        ``error`` reply, not a dropped connection — on a frozen one."""
        rs = self.server.retrieval
        engine = rs.engine
        if op == "upsert":
            pid = engine.live_upsert(
                np.asarray(msg["doc_emb"], np.float32),
                np.asarray(msg.get("term_ids", []), np.int32),
                np.asarray(msg.get("term_weights", []), np.float32),
                msg.get("doc_len"))
            return {"ok": True, "pid": int(pid)}
        if op == "delete":
            return {"ok": bool(engine.live_delete(int(msg["pid"])))}
        if op == "compact":
            out = engine.live_compact()
            return {"ok": True,
                    "compacted": 0 if not out else int(out["compacted"])}
        if op == "live_stats":
            return {"ok": True, "live": engine.live_stats()}
        if op == "health":
            return {"ok": True, "health": rs.health()}
        raise ValueError(f"unknown op {op!r}")

    def handle(self):
        for line in self.rfile:
            qid = None
            try:
                msg = json.loads(line)
                qid = msg.get("qid")
                op = msg.get("op")
                if op is not None:
                    out = self._admin(msg, op)
                    self.wfile.write((json.dumps(out) + "\n").encode())
                    self.wfile.flush()
                    continue
                req = Request(
                    qid=msg["qid"], method=msg.get("method", "hybrid"),
                    q_emb=np.asarray(msg["q_emb"], np.float32)
                    if "q_emb" in msg else None,
                    term_ids=np.asarray(msg.get("term_ids", []), np.int32),
                    term_weights=np.asarray(msg.get("term_weights", []),
                                            np.float32),
                    k=msg.get("k", 10))
                res = self.server.retrieval.submit(req).result(timeout=60)
                out = {"qid": res.qid, "pids": res.pids.tolist(),
                       "scores": [float(s) for s in res.scores],
                       "latency": res.latency}
                if res.cache_hit:
                    out["cache_hit"] = True
                if res.degraded:
                    # partial or downgraded answer: the reason code says
                    # whether shards were missing or admission control
                    # ran the cheap plan
                    out["degraded"] = True
                    out["degrade_reason"] = res.degrade_reason
                    out["missing_shards"] = list(res.missing_shards)
            except RequestShed as e:
                out = {"error": str(e), "shed": True, "reason": e.reason}
                if qid is not None:
                    out["qid"] = qid
            except Exception as e:
                out = {"error": str(e)}
                if qid is not None:
                    out["qid"] = qid
            self.wfile.write((json.dumps(out) + "\n").encode())
            self.wfile.flush()


class TCPRetrievalServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, retrieval_server: RetrievalServer):
        super().__init__(addr, _Handler)
        self.retrieval = retrieval_server


def tcp_query(host: str, port: int, payload: dict) -> dict:
    with socket.create_connection((host, port), timeout=60) as s:
        s.sendall((json.dumps(payload) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(65536)
            if not chunk:
                break
            buf += chunk
    return json.loads(buf)
