"""Replica sets: health-aware routing over N workers per shard.

One shard of the index can be served by several interchangeable
workers — local child processes and/or remote standalone workers
(``python -m repro.serving.worker --port …``) — because every shard
stage is a pure function of the request. This module owns the
*replica axis* of that fabric:

* :class:`_Replica` — one worker slot: lifecycle (spawn / connect /
  reap), restart and quarantine budgets split by failure kind
  (spawn-failure vs serve-failure), an EWMA of observed service time,
  and a circuit breaker with exponential cooldown.
* :class:`ReplicaSet` — the per-shard collection: routes
  fastest-healthy-first (closed breakers ordered by EWMA, cooling
  breakers last as half-open probes), records successes/failures, and
  computes the hedge budget for straggler detection.

Policy split, deliberately asymmetric:

* **Local replicas** (``endpoint is None``) are our own children. Two
  consecutive serve deaths — or two consecutive spawn failures —
  quarantine the replica permanently (``not respawning``): a crash
  looping child burns CPU and disk on every respawn, and nothing
  external will fix it.
* **Remote replicas** never quarantine permanently: the process is
  managed elsewhere (an operator, an init system) and a reconnect is
  one cheap TCP dial, so the breaker's exponential cooldown is the
  only pacing. A successful reconnect proves a live worker and resets
  the consecutive-failure counters.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

from repro.serving.transport import ShardUnavailable, ShardWorkerDied

__all__ = ["ReplicaSet", "_Replica", "_Straggler"]


class _Straggler(Exception):
    """Internal control flow: a hedged wait expired with the reply
    still outstanding (the worker is merely slow, not dead). The
    dispatcher catches this and re-sends the op on a sibling."""


class _Replica:
    """One worker behind a shard — a local child process when
    ``endpoint`` is None, else a remote standalone worker reached over
    TCP. Owns the client handle plus all per-replica health state; the
    factory builds an unspawned ``ShardWorkerClient`` for a given
    arena generation."""

    def __init__(self, shard_index: int, rid: int,
                 factory: Callable[[int], object],
                 endpoint: Optional[str] = None):
        self.shard_index = shard_index
        self.rid = rid
        self.factory = factory
        self.endpoint = endpoint
        self.client = None
        self.lock = threading.RLock()
        self.restarts = 0
        # budgets split by failure kind (a worker that dies while
        # serving and one that cannot even come up are different
        # pathologies; conflating them hid spawn storms behind the
        # serve-restart budget)
        self.consec_serve_failures = 0
        self.consec_spawn_failures = 0
        self.serve_failures = 0          # total, surfaced in health
        self.spawn_failures = 0          # total, surfaced in health
        self.ewma_ms: Optional[float] = None
        self.breaker_open_until = 0.0
        self.breaker_level = 0

    # -- health probes -------------------------------------------------

    def is_alive(self) -> bool:
        cli = self.client
        return cli is not None and cli.alive()

    def quarantined(self) -> bool:
        if self.endpoint is not None:
            return False                 # remote: breaker paces retries
        return (self.consec_serve_failures > 1
                or self.consec_spawn_failures > 1)

    # -- lifecycle -----------------------------------------------------

    def ensure(self, fail_fast: bool):
        """Return a live client, spawning/connecting as needed.

        ``fail_fast=True`` is the legacy single-replica contract: a
        corpse is reaped and the call *raises* ("healing on next use")
        so the serving batch fails promptly instead of absorbing a
        multi-second respawn; the next call respawns. With
        ``fail_fast=False`` (siblings, the healer thread, failover) a
        corpse is reaped and respawned in the same call.
        """
        with self.lock:
            cli = self.client
            if cli is not None and cli.alive():
                return cli
            if cli is not None:
                pid = cli.pid
                code = cli.terminate(grace_s=0.5)
                self.client = None
                self.restarts += 1
                self.consec_serve_failures += 1
                self.serve_failures += 1
                if fail_fast:
                    who = (f"endpoint {self.endpoint}"
                           if self.endpoint is not None else f"pid {pid}")
                    raise ShardWorkerDied(
                        f"shard {self.shard_index} worker ({who}) died"
                        + (f" (exit code {code})" if code is not None
                           else "")
                        + "; healing on next use")
            if self.endpoint is None and self.consec_serve_failures > 1:
                raise ShardWorkerDied(
                    f"shard {self.shard_index} worker died again "
                    "immediately after a restart — not respawning "
                    "(investigate the worker, then rebuild the group)")
            if self.endpoint is None and self.consec_spawn_failures > 1:
                raise ShardWorkerDied(
                    f"shard {self.shard_index} worker failed to spawn "
                    "twice in a row — not respawning (investigate the "
                    "worker, then rebuild the group)")
            cli = self.factory(self.restarts + 1)
            try:
                cli.spawn()
            except BaseException:
                self.spawn_failures += 1
                self.consec_spawn_failures += 1
                raise
            self.client = cli
            if self.endpoint is not None:
                # the readiness ping inside spawn() proved a live
                # worker — an externally restarted process wipes the
                # failure streak
                self.consec_serve_failures = 0
                self.consec_spawn_failures = 0
            return cli

    def terminate(self, grace_s: float = 5.0):
        with self.lock:
            cli, self.client = self.client, None
            if cli is not None:
                cli.terminate(grace_s=grace_s)

    def health(self) -> dict:
        cli = self.client
        return {
            "rid": self.rid,
            "endpoint": self.endpoint,
            "pid": cli.pid if cli is not None else None,
            "alive": self.is_alive(),
            "restarts": self.restarts,
            "spawn_failures": self.spawn_failures,
            "serve_failures": self.serve_failures,
            "quarantined": self.quarantined(),
            "ewma_ms": self.ewma_ms,
            "breaker_open": self.breaker_open_until > time.monotonic(),
        }


class ReplicaSet:
    """The replicas serving one shard, plus the routing policy over
    them. ``replicas[0]`` is the *primary* — the slot legacy
    single-replica semantics (``_ensure_worker``, ``restarts``,
    ``_clients``) bind to."""

    def __init__(self, shard_index: int, replicas: List[_Replica], *,
                 hedge_factor: float = 0.0, hedge_floor_ms: float = 50.0,
                 breaker_base_ms: float = 200.0,
                 breaker_max_ms: float = 5000.0):
        if not replicas:
            raise ValueError(f"shard {shard_index}: empty replica set")
        self.i = shard_index
        self.replicas = list(replicas)
        self.hedge_factor = float(hedge_factor)
        self.hedge_floor_ms = float(hedge_floor_ms)
        self.breaker_base_ms = float(breaker_base_ms)
        self.breaker_max_ms = float(breaker_max_ms)

    @property
    def total(self) -> int:
        return len(self.replicas)

    @property
    def primary(self) -> _Replica:
        return self.replicas[0]

    def alive_count(self) -> int:
        return sum(1 for r in self.replicas if r.is_alive())

    # -- routing -------------------------------------------------------

    def route_order(self, exclude: Optional[_Replica] = None):
        """Candidates in preference order: live replicas with closed
        breakers first (fastest EWMA wins), then dead-but-spawnable
        ones, then cooling breakers as half-open probes (soonest to
        expire first). Quarantined replicas never route."""
        now = time.monotonic()
        ready, cooling = [], []
        for r in self.replicas:
            if r is exclude or r.quarantined():
                continue
            (ready if r.breaker_open_until <= now else cooling).append(r)
        ready.sort(key=lambda r: (not r.is_alive(),
                                  r.ewma_ms if r.ewma_ms is not None
                                  else 0.0))
        cooling.sort(key=lambda r: r.breaker_open_until)
        return ready + cooling

    def acquire(self, exclude: Optional[_Replica] = None):
        """Return ``(replica, live client)`` for the best available
        replica, reviving dead ones inline if that is what it takes.
        Raises :class:`ShardUnavailable` when every replica is out."""
        last: Optional[BaseException] = None
        order = self.route_order(exclude)
        if not order and exclude is not None:
            order = self.route_order(None)
        for r in order:
            try:
                return r, r.ensure(fail_fast=False)
            except ShardWorkerDied as e:
                self.record_failure(r)
                last = e
        raise ShardUnavailable(
            f"shard {self.i}: all {self.total} replica(s) unavailable"
            + (f" (last error: {last})" if last is not None else ""),
            shard=self.i, last_error=last)

    # -- health bookkeeping --------------------------------------------

    def record_success(self, r: _Replica,
                       elapsed_ms: Optional[float] = None):
        r.consec_serve_failures = 0
        r.consec_spawn_failures = 0
        r.breaker_level = 0
        r.breaker_open_until = 0.0
        if elapsed_ms is not None:
            r.ewma_ms = (elapsed_ms if r.ewma_ms is None
                         else 0.8 * r.ewma_ms + 0.2 * elapsed_ms)

    def record_failure(self, r: _Replica):
        r.breaker_level = min(r.breaker_level + 1, 16)
        cool_ms = min(self.breaker_base_ms * (2 ** (r.breaker_level - 1)),
                      self.breaker_max_ms)
        r.breaker_open_until = time.monotonic() + cool_ms / 1e3

    def hedge_budget_ms(self, r: _Replica) -> Optional[float]:
        """Soft wait budget before hedging this replica's in-flight op
        on a sibling; None disables (no siblings, hedging off, or no
        latency history yet)."""
        if self.hedge_factor <= 0.0 or self.total < 2:
            return None
        if r is None or r.ewma_ms is None:
            return None
        if not any(s.is_alive() for s in self.replicas if s is not r):
            return None
        return max(self.hedge_floor_ms, self.hedge_factor * r.ewma_ms)
