"""Typed per-request context and the coordinator cache hierarchy.

``RequestContext`` is the single carrier for everything the serving
layers need to know about one request beyond its tensors: identity,
deadline, admission class (full / degraded / shed), the cache keys it
resolves to, and how far down the degradation ladder it may be pushed.
It replaces the loose ``(queries, k, alpha)`` tuples and thread-local
degraded notes that previously leaked between layers.

Cache keys are built from exact byte digests of the query tensors —
no canonicalisation or term reordering — so a cache hit is *bitwise*
the answer the same request would have computed cold.  Both caches are
bounded LRUs with hit/miss/eviction counters and are invalidated by
index generation: every entry records the generation it was computed
under and ``purge_below()`` drops stale ones when the index advances.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

# Admission classes, in degradation-ladder order.
ADMIT_FULL = "full"          # serve the request's own method, full quality
ADMIT_DEGRADED = "degraded"  # serve the cheap splade-only plan instead
ADMIT_SHED = "shed"          # reject before it enters the queue


@dataclass(frozen=True)
class RequestContext:
    """Immutable per-request lifecycle record threaded through the stack."""

    qid: int
    method: str
    k: int
    alpha: Optional[float] = None
    t_arrival: float = 0.0
    deadline_ms: Optional[float] = None
    admission: str = ADMIT_FULL
    admit_reason: str = ""
    cache_key: Optional[str] = None   # exact result cache key
    stage1_key: Optional[str] = None  # stage-1/candidate cache key
    degrade_budget: int = 1           # how many ladder steps remain

    def degraded(self, reason: str) -> "RequestContext":
        return replace(
            self,
            admission=ADMIT_DEGRADED,
            admit_reason=reason,
            degrade_budget=max(0, self.degrade_budget - 1),
        )

    def shed(self, reason: str) -> "RequestContext":
        return replace(self, admission=ADMIT_SHED, admit_reason=reason)


@dataclass(frozen=True)
class BatchOutcome:
    """Typed result metadata for one retriever batch.

    Replaces the thread-local ``_note_degraded`` side channel on the
    batched path: the retriever returns what happened alongside the
    scores instead of stashing it for the caller to fish out later.
    """

    missing_shards: Tuple[int, ...] = ()

    def merge(self, other: "BatchOutcome") -> "BatchOutcome":
        if not other.missing_shards:
            return self
        merged = tuple(sorted(set(self.missing_shards) | set(other.missing_shards)))
        return BatchOutcome(missing_shards=merged)


def _digest(*parts: Optional[np.ndarray]) -> str:
    """blake2b over the exact bytes of the given arrays.

    The arrays are digested as-is (dtype tag + raw bytes, no sorting or
    dedup) so two requests share a key only when their tensors are
    byte-identical — the precondition for the bitwise-hit guarantee.
    """
    h = hashlib.blake2b(digest_size=16)
    for a in parts:
        if a is None:
            h.update(b"\x00none")
            continue
        arr = np.ascontiguousarray(a)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def query_digest(
    q_emb: Optional[np.ndarray],
    term_ids: Optional[np.ndarray],
    term_weights: Optional[np.ndarray],
) -> str:
    """Digest of one query's tensors (dense embedding + sparse terms)."""
    return _digest(q_emb, term_ids, term_weights)


def exact_cache_key(
    digest: str, method: str, k: int, alpha: Optional[float], salt: str
) -> str:
    """Key for the exact result cache.

    ``salt`` carries every retriever-config component that changes the
    answer (backends, first_k, normalizer, index generation) so config
    or index changes can never alias onto a stale entry.
    """
    return f"x|{digest}|m={method}|k={k}|a={alpha!r}|{salt}"


def stage1_cache_key(digest: str, salt: str) -> str:
    """Key for the stage-1/candidate cache.

    Method-independent for splade-first methods: a splade request warms
    the same stage-1 entry a later hybrid/rerank request reuses.
    """
    return f"s1|{digest}|{salt}"


class LRUCache:
    """Thread-safe bounded LRU with generation-scoped invalidation.

    Capacity is counted in entries; ``capacity <= 0`` disables the
    cache entirely (gets return None without counting, puts no-op).
    Values are stored as given — callers store read-only arrays so a
    hit can be served without a defensive copy.
    """

    def __init__(self, capacity: int, name: str = "lru"):
        self.capacity = int(capacity)
        self.name = name
        self._lock = threading.Lock()
        self._data: "OrderedDict[str, Tuple[int, Any]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def get(self, key: Optional[str], count_miss: bool = True) -> Optional[Any]:
        """``count_miss=False`` makes a miss free: the server's
        submit-time probe uses it so a request probed again at process
        time doesn't count the same miss twice."""
        if self.capacity <= 0 or key is None:
            return None
        with self._lock:
            ent = self._data.get(key)
            if ent is None:
                if count_miss:
                    self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return ent[1]

    def put(self, key: Optional[str], value: Any, generation: int = 0) -> None:
        if self.capacity <= 0 or key is None:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = (generation, value)
                return
            self._data[key] = (generation, value)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge_below(self, generation: int) -> int:
        """Drop entries computed under an older index generation."""
        with self._lock:
            stale = [k for k, (g, _) in self._data.items() if g < generation]
            for k in stale:
                del self._data[k]
            self.invalidations += len(stale)
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "size": len(self._data),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
            }


def freeze(*arrays: np.ndarray) -> Tuple[np.ndarray, ...]:
    """Read-only copies safe to share between the cache and callers."""
    out = []
    for a in arrays:
        c = np.array(a, copy=True)
        c.setflags(write=False)
        out.append(c)
    return tuple(out)


class CacheHierarchy:
    """The coordinator's two-level cache: exact results + stage-1 rows.

    * ``exact`` — full (pids, scores) answers keyed on the exact query
      bytes + method + k + alpha + retriever salt.  A hit is bitwise
      the cold answer.
    * ``stage1`` — per-query stage-1 rows: merged SPLADE candidate
      unions ``(pids_b_row, s_scores_row)`` for splade-first methods,
      or PLAID candidate sets ``(final_pids_row, n_real)`` for colbert.
      Reused across methods that share the same stage-1.
    """

    def __init__(self, exact_entries: int = 0, stage1_entries: int = 0):
        self.exact = LRUCache(exact_entries, name="exact")
        self.stage1 = LRUCache(stage1_entries, name="stage1")

    @property
    def enabled(self) -> bool:
        return self.exact.capacity > 0 or self.stage1.capacity > 0

    def purge_stale(self, current_generation: int) -> int:
        return self.exact.purge_below(current_generation) + self.stage1.purge_below(
            current_generation
        )

    def clear(self) -> None:
        self.exact.clear()
        self.stage1.clear()

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"exact": self.exact.stats(), "stage1": self.stage1.stats()}
