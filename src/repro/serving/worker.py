"""Shard worker process: one shard's stage plan behind a socket RPC.

    python -m repro.serving.worker --shard-dir <base>/shards/0 \
        [--fd N | --port 0] [--mode mmap] [--shard-index 0] \
        [--plaid-json '{...}'] [--ms-json '{...}']

Each worker is a **shared-nothing** serving process: it loads only its
own ``shards/<i>/{colbert,splade}`` subtree — its own mmap
:class:`PagedStore` segment (independent page cache working set), its
own SPLADE postings slice (and device cache when a device backend is
selected), and its own Python interpreter (independent GIL). The
coordinator (:class:`repro.core.sharded.ProcessShardGroup`) ships
shard slices of the batch over ``repro.serving.rpc`` and merges the
returned scores with the same ``merge_topk`` the in-process shard
group uses, so process-group results are bitwise-identical to thread
workers (and therefore to ``shards=1``).

Exposed ops (each mirrors one per-shard stage of the sharded plans;
inputs and the underlying stage functions are exactly the in-process
ones, which is the parity argument):

* ``ping`` / ``health``          — readiness + vitals (pid, RSS, mmap
  segment bytes, served count)
* ``warm {backend}``             — pre-materialise the SPLADE device
  cache for a device stage-1 backend
* ``splade``                     — shard-local stage-1 top-k
* ``score_tokens``               — compacted-candidate residual gather
  + exact MaxSim (rerank/hybrid stage 3–4)
* ``colbert_candidates``         — IVF candidate gen + codes gather +
  approximate scoring (PLAID stages 2–3)
* ``colbert_exact``              — survivor residual gather + exact
  scoring (PLAID stage 4)
* ``shutdown``                   — reply, then exit 0

Lifecycle: SIGTERM requests a **graceful drain** — the op in flight
finishes and its reply is sent before the process exits 0, so a batch
never loses a shard's answer to a routine redeploy; SIGKILL (crash) is
detected by the coordinator as EOF and surfaces as ``ShardWorkerDied``.
The worker serves one request at a time; concurrency comes from the
coordinator running one worker per shard (and pipelining at most one
outstanding request per in-flight micro-batch).
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import sys
import time

import numpy as np

# jax imports are deferred to main() on purpose: the coordinator treats
# the first ping reply as the readiness barrier, and everything heavy
# (jax init, index mmap) must happen before that reply, not lazily
# inside the first scoring op.


class _WorkerState:
    def __init__(self, retriever, shard_index: int):
        self.retr = retriever
        self.shard = shard_index
        self.served = 0
        self.t_start = time.monotonic()
        self.draining = False


def _rss_bytes() -> int:
    from repro.core.store import rss_bytes
    return rss_bytes()


def _handle(state: _WorkerState, op: str, payload: dict):
    import jax.numpy as jnp

    from repro.common.utils import next_pow2
    from repro.core.plaid import (
        stage2_candidates_batch,
        stage3_approx_score_batch,
    )

    retr = state.retr
    sr = retr.searcher

    if op == "ping":
        return {"pid": os.getpid(), "shard": state.shard,
                "ready": True}

    if op == "health":
        return {"pid": os.getpid(), "shard": state.shard,
                "rss_bytes": _rss_bytes(),
                "pool_bytes": sr.index.store.total_bytes(),
                "n_docs": retr.splade.n_docs,
                "served": state.served,
                "uptime_s": time.monotonic() - state.t_start,
                "access": sr.index.store.stats.snapshot()}

    if op == "warm":
        backend = payload.get("backend", "host")
        retr.set_splade_backend(backend)
        if backend != "host":
            retr.splade_device_cache()
        return {"warmed": backend}

    if op == "splade":
        # identical call to the thread-mode group stage: shard-local
        # postings, shard-local top-k; the coordinator remaps to global
        # pids and merge_topk's the group
        pids, scores = retr.run_splade_batch(
            list(payload["term_ids"]), list(payload["term_weights"]),
            int(payload["k"]), backend=payload.get("backend"),
            _record=False)
        return {"pids": pids, "scores": scores}

    if op == "score_tokens":
        # rerank/hybrid stages 3-4 for this shard's compacted slice:
        # mmap residual gather + exact MaxSim, synced before the reply
        # (no lazy device values cross a process boundary)
        sel = payload["sel"]
        codes, packed, valid = sr._dedup_gather(sel, codes_only=False)
        scores = np.asarray(sr.score_gathered_lazy(
            jnp.asarray(payload["q"]), jnp.asarray(payload["q_valid"]),
            jnp.asarray(codes), jnp.asarray(packed), jnp.asarray(valid),
            sel))
        return {"scores": scores}

    if op == "colbert_candidates":
        # PLAID stages 2-3 over this shard's IVF slice; the candidate
        # matrix narrows to the densest row's pow2 bucket exactly like
        # the in-process fanout stage, and raw approx scores go back
        # unsorted — survivor selection stays global on the coordinator
        cand = stage2_candidates_batch(
            sr.ivf_padded, jnp.asarray(payload["cids"]),
            sr.params.candidate_cap)
        cand_np = np.asarray(cand)
        n_real = (cand_np >= 0).sum(axis=1)
        W = min(next_pow2(max(int(n_real.max()), 8)), cand_np.shape[1])
        cand, cand_np = cand[:, :W], cand_np[:, :W]
        codes, _, valid = sr._dedup_gather(cand_np, codes_only=True)
        approx = stage3_approx_score_batch(
            jnp.asarray(payload["scores_c"]), jnp.asarray(codes),
            jnp.asarray(valid), jnp.asarray(payload["q_valid"]))
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        return {"cand": cand_np, "approx": np.asarray(approx),
                "n_real": n_real}

    if op == "colbert_exact":
        sel = payload["sel"]
        codes, packed, valid = sr._dedup_gather(sel, codes_only=False)
        exact = sr.exact_score_gathered(
            jnp.asarray(payload["q"]), jnp.asarray(payload["q_valid"]),
            jnp.asarray(codes), jnp.asarray(packed), jnp.asarray(valid),
            jnp.asarray(sel))
        return {"scores": np.asarray(exact)}

    raise ValueError(f"unknown RPC op {op!r}")


def serve_connection(sock: socket.socket, state: _WorkerState):
    """Request loop: one op at a time, FIFO replies, per-op errors
    reported (never fatal), SIGTERM drained between ops."""
    import select

    from repro.serving import rpc

    sock.setblocking(True)
    while not state.draining:
        # select (not a socket timeout) polls the drain flag: a recv
        # timeout could fire mid-frame and lose bytes, desyncing the
        # stream; select only gates the *start* of a message
        readable, _, _ = select.select([sock], [], [], 0.5)
        if not readable:
            continue
        try:
            msg = rpc.recv_msg(sock, timeout=None)
        except (ConnectionError, OSError):
            return                       # coordinator went away
        op = msg.get("op", "")
        try:
            result = _handle(state, op, msg.get("payload") or {})
            reply = {"ok": True, "result": result}
            state.served += 1
        except Exception:                # compute error ≠ worker death
            import traceback
            reply = {"ok": False, "error": traceback.format_exc()}
        try:
            rpc.send_msg(sock, reply)
        except (ConnectionError, OSError):
            return
        if op == "shutdown":
            return


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shard-dir", required=True,
                    help="this shard's subtree: <dir>/{colbert,splade}")
    ap.add_argument("--shard-index", type=int, default=0)
    ap.add_argument("--mode", default="mmap", choices=["mmap", "ram"])
    ap.add_argument("--fd", type=int, default=None,
                    help="inherited socketpair fd (coordinator-spawned)")
    ap.add_argument("--port", type=int, default=None,
                    help="standalone mode: listen on 127.0.0.1:PORT "
                         "(0 = ephemeral; prints RPC_PORT=<n>)")
    ap.add_argument("--plaid-json", default="{}")
    ap.add_argument("--ms-json", default="{}")
    args = ap.parse_args(argv)
    if (args.fd is None) == (args.port is None):
        ap.error("exactly one of --fd / --port is required")

    # heavy imports after arg validation; the parent's first ping blocks
    # until this completes
    import pathlib

    from repro.core.multistage import MultiStageParams, MultiStageRetriever
    from repro.core.plaid import PLAIDSearcher, PlaidParams
    from repro.index.builder import ColBERTIndex
    from repro.index.splade_index import SpladeIndex

    d = pathlib.Path(args.shard_dir)
    index = ColBERTIndex(d / "colbert", mode=args.mode)
    sidx = SpladeIndex.load(d / "splade", mmap=(args.mode == "mmap"))
    retr = MultiStageRetriever(
        sidx, PLAIDSearcher(index, PlaidParams(**json.loads(args.plaid_json))),
        MultiStageParams(**json.loads(args.ms_json)))
    state = _WorkerState(retr, args.shard_index)

    def on_sigterm(signum, frame):
        # graceful drain: finish (and answer) the op in flight, then
        # exit — the loop checks the flag between requests
        state.draining = True

    signal.signal(signal.SIGTERM, on_sigterm)

    if args.fd is not None:
        sock = socket.socket(fileno=args.fd)
        try:
            serve_connection(sock, state)
        finally:
            try:
                sock.close()
            except OSError:
                pass
        return 0

    srv = socket.create_server(("127.0.0.1", args.port))
    srv.settimeout(0.5)
    print(f"RPC_PORT={srv.getsockname()[1]}", flush=True)
    try:
        while not state.draining:
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            with conn:
                serve_connection(conn, state)
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
