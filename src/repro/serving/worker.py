"""Shard worker process: one shard's stage plan behind a socket RPC.

    python -m repro.serving.worker --shard-dir <base>/shards/0 \
        [--fd N | --port 0] [--mode mmap] [--shard-index 0] \
        [--transport socket|shm] [--arena /dev/shm/….arena] \
        [--plaid-json '{...}'] [--ms-json '{...}']

Each worker is a **shared-nothing** serving process: it loads only its
own ``shards/<i>/{colbert,splade}`` subtree — its own mmap
:class:`PagedStore` segment (independent page cache working set), its
own SPLADE postings slice (and device cache when a device backend is
selected), and its own Python interpreter (independent GIL). The
coordinator (:class:`repro.core.sharded.ProcessShardGroup`) ships
shard slices of the batch over ``repro.serving.rpc`` and merges the
returned scores with the same ``merge_topk`` the in-process shard
group uses, so process-group results are bitwise-identical to thread
workers (and therefore to ``shards=1``).

Exposed ops (each mirrors one per-shard stage of the sharded plans;
inputs and the underlying stage functions are exactly the in-process
ones, which is the parity argument):

* ``ping`` / ``health``          — readiness + vitals (pid, RSS, mmap
  segment bytes, served count)
* ``warm {backend}``             — pre-materialise the SPLADE device
  cache for a device stage-1 backend
* ``splade``                     — shard-local stage-1 top-k
* ``score_tokens``               — compacted-candidate residual gather
  + exact MaxSim (rerank/hybrid stage 3–4)
* ``colbert_candidates``         — IVF candidate gen + codes gather +
  approximate scoring (PLAID stages 2–3)
* ``colbert_exact``              — survivor residual gather + exact
  scoring (PLAID stage 4)
* ``multi {ops: […]}``           — coalesced sub-ops (one dispatch per
  worker per stage); one reply with a per-op ok/error slot each
* ``shutdown``                   — reply, then exit 0

Lifecycle: SIGTERM requests a **graceful drain** — the op in flight
finishes and its reply is sent before the process exits 0, so a batch
never loses a shard's answer to a routine redeploy; SIGKILL (crash) is
detected by the coordinator as EOF and surfaces as ``ShardWorkerDied``.
The worker serves one request at a time; concurrency comes from the
coordinator running one worker per shard (and pipelining at most one
outstanding request per in-flight micro-batch).
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import sys
import time

import numpy as np

# jax imports are deferred to main() on purpose: the coordinator treats
# the first ping reply as the readiness barrier, and everything heavy
# (jax init, index mmap) must happen before that reply, not lazily
# inside the first scoring op.


class _WorkerState:
    def __init__(self, retriever, shard_index: int):
        self.retr = retriever
        self.shard = shard_index
        self.served = 0
        self.t_start = time.monotonic()
        self.draining = False
        self.channel = None            # set by serve_connection


def _rss_bytes() -> int:
    from repro.core.store import rss_bytes
    return rss_bytes()


def _handle(state: _WorkerState, op: str, payload: dict):
    import jax.numpy as jnp

    from repro.common.utils import next_pow2
    from repro.core.plaid import (
        stage2_candidates_batch,
        stage3_approx_score_batch,
    )

    retr = state.retr
    sr = retr.searcher

    if op == "ping":
        return {"pid": os.getpid(), "shard": state.shard,
                "ready": True}

    if op == "health":
        h = {"pid": os.getpid(), "shard": state.shard,
             "rss_bytes": _rss_bytes(),
             "pool_bytes": sr.index.store.total_bytes(),
             "n_docs": retr.splade.n_docs,
             "served": state.served,
             "uptime_s": time.monotonic() - state.t_start,
             "access": sr.index.store.stats.snapshot()}
        if retr.live is not None:
            h["live"] = retr.live.stats()
            h["generation"] = retr.index_generation
        if state.channel is not None:
            # worker-side view of the same channel (its bytes_sent is
            # the coordinator's bytes_recv); keyed distinctly so it
            # never clobbers the coordinator's transport fields
            h["worker_transport"] = state.channel.stats()
        return h

    if op == "warm":
        backend = payload.get("backend", "host")
        retr.set_splade_backend(backend)
        if backend != "host":
            retr.splade_device_cache()
        return {"warmed": backend}

    if op == "splade":
        # identical call to the thread-mode group stage: shard-local
        # postings, shard-local top-k; the coordinator remaps to global
        # pids and merge_topk's the group
        pids, scores = retr.run_splade_batch(
            list(payload["term_ids"]), list(payload["term_weights"]),
            int(payload["k"]), backend=payload.get("backend"),
            _record=False)
        return {"pids": pids, "scores": scores}

    if op == "score_tokens":
        # rerank/hybrid stages 3-4 for this shard's compacted slice:
        # mmap residual gather + exact MaxSim, synced before the reply
        # (no lazy device values cross a process boundary)
        sel = payload["sel"]
        codes, packed, valid = sr._dedup_gather(sel, codes_only=False)
        scores = np.asarray(sr.score_gathered_lazy(
            jnp.asarray(payload["q"]), jnp.asarray(payload["q_valid"]),
            jnp.asarray(codes), jnp.asarray(packed), jnp.asarray(valid),
            sel))
        return {"scores": scores}

    if op == "colbert_candidates":
        # PLAID stages 2-3 over this shard's IVF slice; the candidate
        # matrix narrows to the densest row's pow2 bucket exactly like
        # the in-process fanout stage, and raw approx scores go back
        # unsorted — survivor selection stays global on the coordinator
        cand = stage2_candidates_batch(
            sr.ivf_padded, jnp.asarray(payload["cids"]),
            sr.params.candidate_cap)
        cand_np = np.asarray(cand)
        n_real = (cand_np >= 0).sum(axis=1)
        W = min(next_pow2(max(int(n_real.max()), 8)), cand_np.shape[1])
        cand, cand_np = cand[:, :W], cand_np[:, :W]
        codes, _, valid = sr._dedup_gather(cand_np, codes_only=True)
        approx = stage3_approx_score_batch(
            jnp.asarray(payload["scores_c"]), jnp.asarray(codes),
            jnp.asarray(valid), jnp.asarray(payload["q_valid"]))
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        return {"cand": cand_np, "approx": np.asarray(approx),
                "n_real": n_real}

    if op == "colbert_exact":
        sel = payload["sel"]
        codes, packed, valid = sr._dedup_gather(sel, codes_only=False)
        exact = sr.exact_score_gathered(
            jnp.asarray(payload["q"]), jnp.asarray(payload["q_valid"]),
            jnp.asarray(codes), jnp.asarray(packed), jnp.asarray(valid),
            jnp.asarray(sel))
        return {"scores": np.asarray(exact)}

    if op == "live_sync":
        # full-state tombstone replication (idempotent): the worker's
        # SPLADE stage excludes these local pids pre-top-k, exactly
        # like the in-process thread shards' LiveViews
        from repro.index.live import LiveView

        if retr.live is None:
            retr.live = LiveView()
        retr.live.update(payload.get("tombstones"),
                         generation=payload.get("generation"))
        retr.index_generation = int(payload.get("generation") or 0)
        return {"tombstones": int(retr.live.tombstones.size),
                "generation": retr.live.generation}

    if op == "live_reload":
        # compaction swap: rebuild index/searcher handles from the new
        # generation's directories and reset the tombstone view to the
        # shard's (grown) range
        import pathlib

        from repro.core.plaid import PLAIDSearcher
        from repro.index.builder import ColBERTIndex
        from repro.index.live import LiveView
        from repro.index.splade_index import SpladeIndex

        mode = sr.index.store.mode
        index = ColBERTIndex(pathlib.Path(payload["colbert_dir"]),
                             mode=mode)
        sidx = SpladeIndex.load(pathlib.Path(payload["splade_dir"]),
                                mmap=(mode == "mmap"))
        retr.splade = sidx
        retr.searcher = PLAIDSearcher(index, sr.params)
        with retr._lock:
            retr._plans.clear()
            retr._splade_device = None
        retr.live = LiveView(payload.get("tombstones"),
                             generation=payload.get("generation") or 0)
        retr.index_generation = int(payload.get("generation") or 0)
        return {"n_docs": int(sidx.n_docs),
                "generation": retr.index_generation}

    raise ValueError(f"unknown RPC op {op!r}")


def _run_op(state: _WorkerState, op: str, payload) -> dict:
    """One op → one ``{"ok": …}`` reply dict; compute errors are
    reported, never fatal."""
    try:
        result = _handle(state, op, payload or {})
        state.served += 1
        return {"ok": True, "result": result}
    except Exception:                    # compute error ≠ worker death
        import traceback
        return {"ok": False, "error": traceback.format_exc()}


def serve_connection(channel, state: _WorkerState):
    """Request loop: one op at a time, FIFO replies, per-op errors
    reported (never fatal), SIGTERM drained between ops.

    A ``multi`` op carries a list of coalesced sub-ops (one coordinator
    dispatch per worker per stage); each sub-op gets its own ok/error
    slot in the single reply, so one bad micro-batch never poisons its
    co-batched neighbours."""
    state.channel = channel
    channel.sock.setblocking(True)
    while not state.draining:
        try:
            # the channel's pump (not a socket timeout) paces the drain
            # poll: partial frames persist in its buffer across slices,
            # and frames already buffered decode without touching the
            # socket — a select-gated loop would strand them
            msg = channel.pump(0.5)
        except (ConnectionError, OSError):
            return                       # coordinator went away
        except ValueError:
            # undecodable frame: the stream is desynced (a corrupted or
            # torn write on the client side). That is the *connection's*
            # problem, never the worker's — drop the connection and let
            # the accept loop serve the next one; a standalone fleet
            # worker must survive any bytes a client throws at it
            return
        if msg is None:
            continue
        op = msg.get("op", "")
        if op == "multi":
            ops = (msg.get("payload") or {}).get("ops") or []
            reply = {"ok": True, "result": {
                "replies": [_run_op(state, sub.get("op", ""),
                                    sub.get("payload")) for sub in ops]}}
        else:
            reply = _run_op(state, op, msg.get("payload"))
        try:
            channel.send(reply)
        except (ConnectionError, OSError):
            return
        if op == "shutdown":
            return


def spawn_standalone(shard_dir, shard_index: int = 0, *,
                     mode: str = "mmap", port: int = 0,
                     plaid_params=None, ms_params=None,
                     timeout_s: float = 180.0):
    """Spawn a standalone worker subprocess (``--port`` mode) and wait
    for its ``RPC_PORT=<n>`` readiness line; returns ``(proc, port)``.

    The fleet harness behind remote-replica tests, the chaos smoke and
    ``bench_latency.py --chaos-sweep``: each call stands up one
    independently killable/restartable worker a coordinator attaches
    to via ``replica_endpoints=…``. ``port=0`` binds an ephemeral
    port; pass the old port back in to restart a killed worker at the
    same endpoint (the listener sets SO_REUSEADDR)."""
    import subprocess

    from repro.serving.transport.client import _src_pythonpath

    cmd = [sys.executable, "-m", "repro.serving.worker",
           "--shard-dir", str(shard_dir),
           "--shard-index", str(shard_index),
           "--mode", mode, "--port", str(port),
           "--plaid-json", json.dumps(plaid_params or {}),
           "--ms-json", json.dumps(ms_params or {})]
    env = dict(os.environ)
    env["PYTHONPATH"] = _src_pythonpath()
    proc = subprocess.Popen(cmd, env=env, stdin=subprocess.DEVNULL,
                            stdout=subprocess.PIPE, text=True)
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break                       # EOF: the worker died
        if line.startswith("RPC_PORT="):
            return proc, int(line.strip().split("=", 1)[1])
    proc.kill()
    proc.wait(timeout=10)
    raise RuntimeError(
        f"standalone worker for shard {shard_index} ({shard_dir}) "
        f"never reported RPC_PORT= (exit code {proc.returncode})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shard-dir", required=True,
                    help="this shard's subtree: <dir>/{colbert,splade}")
    ap.add_argument("--shard-index", type=int, default=0)
    ap.add_argument("--mode", default="mmap", choices=["mmap", "ram"])
    ap.add_argument("--fd", type=int, default=None,
                    help="inherited socketpair fd (coordinator-spawned)")
    ap.add_argument("--port", type=int, default=None,
                    help="standalone mode: listen on 127.0.0.1:PORT "
                         "(0 = ephemeral; prints RPC_PORT=<n>)")
    ap.add_argument("--transport", default="socket",
                    choices=["socket", "shm"],
                    help="tensor transport: in-frame socket segments "
                         "or a shared-memory ring arena")
    ap.add_argument("--arena", default=None,
                    help="arena file created by the coordinator "
                         "(required for --transport shm)")
    ap.add_argument("--plaid-json", default="{}")
    ap.add_argument("--ms-json", default="{}")
    args = ap.parse_args(argv)
    if (args.fd is None) == (args.port is None):
        ap.error("exactly one of --fd / --port is required")
    if args.transport == "shm" and args.arena is None:
        ap.error("--transport shm requires --arena")
    if args.transport == "shm" and args.port is not None:
        ap.error("--transport shm requires --fd (coordinator-spawned)")

    # heavy imports after arg validation; the parent's first ping blocks
    # until this completes
    import pathlib

    from repro.core.multistage import MultiStageParams, MultiStageRetriever
    from repro.core.plaid import PLAIDSearcher, PlaidParams
    from repro.index.builder import ColBERTIndex
    from repro.index.splade_index import SpladeIndex

    d = pathlib.Path(args.shard_dir)
    index = ColBERTIndex(d / "colbert", mode=args.mode)
    sidx = SpladeIndex.load(d / "splade", mmap=(args.mode == "mmap"))
    retr = MultiStageRetriever(
        sidx, PLAIDSearcher(index, PlaidParams(**json.loads(args.plaid_json))),
        MultiStageParams(**json.loads(args.ms_json)))
    state = _WorkerState(retr, args.shard_index)

    def on_sigterm(signum, frame):
        # graceful drain: finish (and answer) the op in flight, then
        # exit — the loop checks the flag between requests
        state.draining = True

    signal.signal(signal.SIGTERM, on_sigterm)

    from repro.serving.transport import (RING_C2W, RING_W2C, ShmArena,
                                         ShmChannel, StreamChannel)

    if args.fd is not None:
        sock = socket.socket(fileno=args.fd)
        if args.transport == "shm":
            arena = ShmArena.open(args.arena)

            def coordinator_gone():
                # producer-side liveness while blocked on reply-ring
                # space: a closed socket (EOF visible via MSG_PEEK)
                # means the coordinator is gone — bail, don't wedge
                try:
                    data = sock.recv(1, socket.MSG_PEEK
                                     | socket.MSG_DONTWAIT)
                except (BlockingIOError, InterruptedError):
                    return None
                except OSError as e:
                    return f"socket error ({e})"
                return (None if data
                        else "coordinator closed the connection")

            channel = ShmChannel(sock, arena, tx_ring=RING_W2C,
                                 rx_ring=RING_C2W,
                                 liveness=coordinator_gone)
        else:
            channel = StreamChannel(sock)
        try:
            serve_connection(channel, state)
        finally:
            channel.close()
        return 0

    srv = socket.create_server(("127.0.0.1", args.port))
    srv.settimeout(0.5)
    print(f"RPC_PORT={srv.getsockname()[1]}", flush=True)
    try:
        while not state.draining:
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            with conn:
                serve_connection(StreamChannel(conn), state)
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
