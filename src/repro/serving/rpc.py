"""Compact length-prefixed RPC for shard worker processes.

Multi-process scatter-gather serving needs shard-local stage work to
cross an OS-process boundary: the coordinator ships ``CandidateBatch``
shard slices (query tensors, compacted candidate lists) to a worker
that owns one shard's mmap segment, and gets back synced numpy scores.
This module is the wire layer:

* **codec** — msgpack when available (ndarrays as an ExtType carrying
  ``(dtype, shape, raw bytes)``), with a dependency-free fallback codec
  covering the same value space (None/bool/int/float/str/bytes/
  list/dict/ndarray). Both are lossless for numpy dtypes, which is what
  makes process-group results bitwise-identical to the in-process
  shard group: scores are computed from byte-identical inputs by the
  same jitted programs and travel back as raw dtype bytes.
* **framing** — 8-byte big-endian length prefix per message over a
  stream socket (the coordinator spawns each worker with one end of a
  ``socketpair``, so there is no port management and worker death is an
  unambiguous EOF).
* :class:`ShardWorkerClient` — coordinator-side handle: spawn, ping,
  **pipelined** request/response (requests may be sent before earlier
  replies are read; replies are FIFO per connection, so the pipelined
  executor can keep one RPC in flight per in-flight micro-batch —
  backpressure across the process boundary is the executor's admission
  semaphore), crash detection (:class:`ShardWorkerDied` on EOF/reset/
  timeout, with the worker's exit code when it already died), and
  graceful shutdown (RPC ``shutdown`` → SIGTERM → kill escalation).

Remote *compute* errors (a stage op raising inside a healthy worker)
are :class:`ShardWorkerError` — the worker survives and keeps serving;
only transport-level failures are :class:`ShardWorkerDied`.
"""

from __future__ import annotations

import collections
import json
import os
import pathlib
import select
import signal
import socket
import struct
import subprocess
import sys
import threading
import time
from typing import Any, Optional

import numpy as np

try:
    import msgpack
    HAVE_MSGPACK = True
except ImportError:                                   # pragma: no cover
    msgpack = None
    HAVE_MSGPACK = False


class ShardWorkerDied(RuntimeError):
    """The worker process behind a shard is gone (EOF, reset, timeout,
    or a nonzero exit) — the current batch has no answer for that
    shard. The group heals by respawning the worker on next use."""


class ShardWorkerError(RuntimeError):
    """A stage op raised *inside* a healthy worker; the worker keeps
    serving. Carries the remote traceback text."""


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

_ND_EXT = 42          # msgpack ExtType code for ndarrays


def _nd_to_wire(arr: np.ndarray) -> tuple:
    a = np.ascontiguousarray(arr)
    return (a.dtype.str, list(a.shape), a.tobytes())


def _nd_from_wire(dtype_str: str, shape, raw: bytes) -> np.ndarray:
    # copy: frombuffer views are read-only and may alias the recv buffer
    return np.frombuffer(raw, dtype=np.dtype(dtype_str)) \
        .reshape(shape).copy()


def _msgpack_default(obj):
    if isinstance(obj, np.ndarray):
        d, s, b = _nd_to_wire(obj)
        return msgpack.ExtType(_ND_EXT, msgpack.packb((d, s, b)))
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, tuple):
        return list(obj)
    raise TypeError(f"unencodable RPC value: {type(obj)!r}")


def _msgpack_ext_hook(code, data):
    if code == _ND_EXT:
        d, s, b = msgpack.unpackb(data)
        return _nd_from_wire(d, s, b)
    return msgpack.ExtType(code, data)              # pragma: no cover


# -- fallback codec (no msgpack on the image) -------------------------------
# One tag byte per value; ints are 8-byte signed, floats are doubles,
# containers carry a 4-byte count. Covers exactly the RPC value space.

def _enc_py(obj, out: list):
    if obj is None:
        out.append(b"N")
    elif isinstance(obj, (bool, np.bool_)):
        out.append(b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        out.append(b"I" + struct.pack(">q", int(obj)))
    elif isinstance(obj, (float, np.floating)):
        out.append(b"D" + struct.pack(">d", float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode()
        out.append(b"S" + struct.pack(">I", len(raw)) + raw)
    elif isinstance(obj, bytes):
        out.append(b"B" + struct.pack(">I", len(obj)) + obj)
    elif isinstance(obj, np.ndarray):
        d, s, raw = _nd_to_wire(obj)
        head = json.dumps([d, s]).encode()
        out.append(b"A" + struct.pack(">I", len(head)) + head
                   + struct.pack(">Q", len(raw)) + raw)
    elif isinstance(obj, (list, tuple)):
        out.append(b"L" + struct.pack(">I", len(obj)))
        for x in obj:
            _enc_py(x, out)
    elif isinstance(obj, dict):
        out.append(b"M" + struct.pack(">I", len(obj)))
        for k, v in obj.items():
            _enc_py(str(k), out)
            _enc_py(v, out)
    else:
        raise TypeError(f"unencodable RPC value: {type(obj)!r}")


def _dec_py(buf: memoryview, pos: int):
    tag = bytes(buf[pos:pos + 1])
    pos += 1
    if tag == b"N":
        return None, pos
    if tag == b"T":
        return True, pos
    if tag == b"F":
        return False, pos
    if tag == b"I":
        return struct.unpack(">q", buf[pos:pos + 8])[0], pos + 8
    if tag == b"D":
        return struct.unpack(">d", buf[pos:pos + 8])[0], pos + 8
    if tag in (b"S", b"B"):
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        raw = bytes(buf[pos + 4:pos + 4 + n])
        return (raw.decode() if tag == b"S" else raw), pos + 4 + n
    if tag == b"A":
        hn = struct.unpack(">I", buf[pos:pos + 4])[0]
        d, s = json.loads(bytes(buf[pos + 4:pos + 4 + hn]).decode())
        pos += 4 + hn
        rn = struct.unpack(">Q", buf[pos:pos + 8])[0]
        arr = _nd_from_wire(d, s, bytes(buf[pos + 8:pos + 8 + rn]))
        return arr, pos + 8 + rn
    if tag == b"L":
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        pos += 4
        out = []
        for _ in range(n):
            v, pos = _dec_py(buf, pos)
            out.append(v)
        return out, pos
    if tag == b"M":
        n = struct.unpack(">I", buf[pos:pos + 4])[0]
        pos += 4
        out = {}
        for _ in range(n):
            k, pos = _dec_py(buf, pos)
            v, pos = _dec_py(buf, pos)
            out[k] = v
        return out, pos
    raise ValueError(f"bad RPC tag {tag!r}")


def encode(obj, *, force_fallback: bool = False) -> bytes:
    """Message → wire bytes (msgpack when available)."""
    if HAVE_MSGPACK and not force_fallback:
        return b"\x01" + msgpack.packb(obj, default=_msgpack_default,
                                       use_bin_type=True)
    out: list = []
    _enc_py(obj, out)
    return b"\x00" + b"".join(out)


def decode(raw: bytes):
    """Wire bytes → message (codec chosen by the leading byte, so a
    msgpack coordinator can talk to a fallback worker and vice versa)."""
    if raw[:1] == b"\x01":
        if not HAVE_MSGPACK:
            raise RuntimeError("peer sent msgpack but msgpack is not "
                               "installed here")
        return msgpack.unpackb(raw[1:], ext_hook=_msgpack_ext_hook,
                               raw=False, strict_map_key=False)
    val, pos = _dec_py(memoryview(raw), 1)
    if pos != len(raw):
        raise ValueError(f"trailing RPC bytes ({len(raw) - pos})")
    return val


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

_LEN = struct.Struct(">Q")


def send_msg(sock: socket.socket, obj) -> int:
    """Encode + length-prefix + sendall. Returns bytes written."""
    payload = encode(obj)
    sock.sendall(_LEN.pack(len(payload)) + payload)
    return _LEN.size + len(payload)


def _recv_exact(sock: socket.socket, n: int,
                deadline: Optional[float]) -> bytes:
    chunks = []
    got = 0
    while got < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise socket.timeout("RPC recv deadline exceeded")
            sock.settimeout(min(remaining, 1.0))
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except socket.timeout:
            continue                 # re-check the deadline
        if not chunk:
            raise ConnectionError("RPC peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket, timeout: Optional[float] = None):
    """Read one length-prefixed message; ``timeout`` is the whole-message
    deadline (None = block forever)."""
    deadline = None if timeout is None else time.monotonic() + timeout
    head = _recv_exact(sock, _LEN.size, deadline)
    (n,) = _LEN.unpack(head)
    return decode(_recv_exact(sock, n, deadline))


# ---------------------------------------------------------------------------
# coordinator-side worker handle
# ---------------------------------------------------------------------------

class _Reply:
    """One outstanding pipelined request's reply slot."""

    __slots__ = ("event", "value", "error")

    def __init__(self):
        self.event = threading.Event()
        self.value = None
        self.error: Optional[BaseException] = None

    def resolve(self, value=None, error: Optional[BaseException] = None):
        self.value = value
        self.error = error
        self.event.set()


def _src_pythonpath() -> str:
    """PYTHONPATH entry that makes ``repro`` importable in the child."""
    import repro

    # repro may be a namespace package (__file__ is None) — __path__
    # always carries the package directory
    pkg_dir = (pathlib.Path(repro.__file__).parent if repro.__file__
               else pathlib.Path(next(iter(repro.__path__))))
    src = str(pkg_dir.resolve().parent)
    existing = os.environ.get("PYTHONPATH", "")
    return src if not existing else f"{src}{os.pathsep}{existing}"


class ShardWorkerClient:
    """Spawn and talk to one shard worker process.

    The connection is a ``socketpair`` end inherited by the child, so
    liveness is exact: worker death is EOF, not a guessed timeout.
    Requests are **pipelined**: ``call_async`` sends immediately and
    returns a handle; replies are read strictly in request order (the
    worker serves one request at a time), so an abandoned handle's
    reply is still consumed by the next waiter and the stream can never
    desynchronise. All transport failures mark the client dead and fail
    every outstanding handle with :class:`ShardWorkerDied`.
    """

    def __init__(self, shard_index: int, shard_dir, *, mode: str = "mmap",
                 plaid_params: Optional[dict] = None,
                 ms_params: Optional[dict] = None,
                 env: Optional[dict] = None,
                 spawn_timeout_s: float = 180.0,
                 call_timeout_s: float = 300.0):
        self.shard_index = shard_index
        self.shard_dir = str(shard_dir)
        self.mode = mode
        self.plaid_params = plaid_params or {}
        self.ms_params = ms_params or {}
        self.env = env
        self.spawn_timeout_s = spawn_timeout_s
        self.call_timeout_s = call_timeout_s
        self.proc: Optional[subprocess.Popen] = None
        self.sock: Optional[socket.socket] = None
        self.dead = False
        self.bytes_sent = 0
        self.bytes_recv = 0
        # RLock: a send failure marks the client dead from *inside* the
        # send critical section (_mark_dead re-enters to fail pending)
        self._send_lock = threading.RLock()
        self._recv_lock = threading.Lock()
        self._rx = bytearray()         # partial-frame receive buffer
        self._pending: collections.deque[_Reply] = collections.deque()

    # -- lifecycle -------------------------------------------------------
    def spawn(self):
        parent, child = socket.socketpair()
        cmd = [sys.executable, "-m", "repro.serving.worker",
               "--shard-dir", self.shard_dir,
               "--shard-index", str(self.shard_index),
               "--mode", self.mode,
               "--fd", str(child.fileno()),
               "--plaid-json", json.dumps(self.plaid_params),
               "--ms-json", json.dumps(self.ms_params)]
        env = dict(os.environ if self.env is None else self.env)
        env["PYTHONPATH"] = _src_pythonpath()
        self.proc = subprocess.Popen(cmd, pass_fds=(child.fileno(),),
                                     env=env, stdin=subprocess.DEVNULL)
        child.close()
        self.sock = parent
        self.dead = False
        try:
            # first ping doubles as the readiness barrier: the worker
            # replies only after importing jax and mapping its subtree
            return self.call("ping", {}, timeout=self.spawn_timeout_s)
        except BaseException:
            # a worker that hung or died during startup must be reaped
            # here — the caller has no client slot for it yet, so an
            # unreaped child would be a permanent orphan
            try:
                self.proc.kill()
            except OSError:
                pass
            self.proc.wait()
            self.dead = True
            raise

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else None

    def alive(self) -> bool:
        return (not self.dead and self.proc is not None
                and self.proc.poll() is None)

    # -- request/response ------------------------------------------------
    def call_async(self, op: str, payload: Any) -> _Reply:
        rep = _Reply()
        with self._send_lock:
            if self.dead or self.sock is None:
                raise self._died_error("is not running")
            try:
                self.bytes_sent += send_msg(
                    self.sock, {"op": op, "payload": payload})
            except OSError as e:
                self._mark_dead()
                raise self._died_error(f"send failed ({e})") from e
            self._pending.append(rep)
        return rep

    def _pump_frame(self, slice_timeout: float):
        """Complete at most one frame within ``slice_timeout``; returns
        the decoded message or None.

        Two properties this must preserve (both were live bugs):
        partially received bytes persist in :attr:`_rx` across slices —
        a timeout mid-frame must never discard them, or the
        length-prefixed stream desynchronises and a healthy worker
        looks dead; and pacing uses ``select``, never
        ``sock.settimeout`` — socket timeouts are socket-wide, so a
        recv slice would also arm concurrent ``sendall`` calls, which
        then spuriously 'fail' whenever a busy worker (first-shape jax
        compile) lets the pipe fill for over a second. A blocked send
        is backpressure, not death. Caller holds ``_recv_lock``."""
        deadline = time.monotonic() + slice_timeout
        while True:
            if len(self._rx) >= _LEN.size:
                (n,) = _LEN.unpack(bytes(self._rx[:_LEN.size]))
                if len(self._rx) >= _LEN.size + n:
                    payload = bytes(self._rx[_LEN.size:_LEN.size + n])
                    del self._rx[:_LEN.size + n]
                    return decode(payload)
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            readable, _, _ = select.select([self.sock], [], [],
                                           remaining)
            if not readable:
                return None
            chunk = self.sock.recv(1 << 20)   # readable: won't block
            if not chunk:
                raise ConnectionError("RPC peer closed the connection")
            self._rx += chunk
            self.bytes_recv += len(chunk)

    def wait(self, rep: _Reply, timeout: Optional[float] = None,
             kill_on_timeout: bool = True):
        """Wait for one handle; any waiter pumps the shared socket, and
        frames resolve pending handles strictly in FIFO order.

        ``kill_on_timeout=False`` makes the deadline *soft*: expiry
        raises :class:`ShardWorkerError` without marking the worker
        dead — the discipline for health/heartbeat polls, which queue
        FIFO behind real work and must never kill a worker that is
        merely busy (a first-shape compile easily exceeds a monitor's
        patience). The abandoned reply stays pending and is consumed,
        in order, by the next waiter."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.call_timeout_s)
        while not rep.event.is_set():
            if not self._recv_lock.acquire(timeout=0.02):
                continue
            try:
                if rep.event.is_set():
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    if not kill_on_timeout:
                        raise ShardWorkerError(
                            f"shard {self.shard_index} soft RPC "
                            f"deadline expired (worker busy)")
                    self._mark_dead()
                    raise self._died_error("RPC timed out")
                try:
                    msg = self._pump_frame(min(remaining, 1.0))
                except (OSError, ConnectionError, ValueError,
                        RuntimeError) as e:
                    self._mark_dead()
                    raise self._died_error(f"recv failed ({e})") from e
                if msg is None:
                    continue               # slice expired; frame intact
                try:
                    head = self._pending.popleft()
                except IndexError:
                    # a concurrent _mark_dead (send failure on another
                    # thread) drained the deque between our pump and
                    # this pop — the client is dead, not corrupted
                    raise self._died_error(
                        "reply arrived after the client was marked "
                        "dead")
                head.resolve(value=msg)
            finally:
                self._recv_lock.release()
        if rep.error is not None:
            raise rep.error
        msg = rep.value
        if not msg.get("ok", False):
            raise ShardWorkerError(
                f"shard {self.shard_index} op failed:\n{msg.get('error')}")
        return msg.get("result")

    def call(self, op: str, payload: Any,
             timeout: Optional[float] = None,
             kill_on_timeout: bool = True):
        return self.wait(self.call_async(op, payload), timeout=timeout,
                         kill_on_timeout=kill_on_timeout)

    # -- failure / shutdown ----------------------------------------------
    def _mark_dead(self):
        self.dead = True
        # wake any sender blocked in sendall on a full pipe *before*
        # taking the send lock it holds — shutdown errors the send out
        if self.sock is not None:
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        err = self._died_error("died mid-conversation")
        with self._send_lock:
            while self._pending:
                self._pending.popleft().resolve(error=err)

    def _died_error(self, why: str) -> ShardWorkerDied:
        code = self.proc.poll() if self.proc is not None else None
        tail = "" if code is None else f"; exit code {code}"
        return ShardWorkerDied(
            f"shard {self.shard_index} worker (pid {self.pid}) {why}"
            f"{tail}")

    def terminate(self, grace_s: float = 5.0) -> Optional[int]:
        """Graceful shutdown escalation: ``shutdown`` RPC → SIGTERM →
        SIGKILL. Always reaps; returns the exit code."""
        if self.proc is None:
            return None
        if self.proc.poll() is None and not self.dead:
            try:
                self.call("shutdown", {}, timeout=grace_s)
            except (ShardWorkerDied, ShardWorkerError):
                pass
        try:
            self.proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            try:
                self.proc.send_signal(signal.SIGTERM)
                self.proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        self.dead = True
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None
        return self.proc.returncode
