"""Back-compat shim over :mod:`repro.serving.transport`.

The monolithic RPC module (codec + framing + socket + client lifecycle
interleaved in one file) was refactored into the layered
``transport/`` package:

* ``transport.codec``   — message values ⇄ control bytes, ndarrays as
  ``(dtype, shape, locator)``
* ``transport.framing`` — length-prefixed frames, ``sendmsg`` gather
* ``transport.shm``     — shared-memory ring arenas (zero-copy path)
* ``transport.channel`` — ``StreamChannel`` / ``ShmChannel``
* ``transport.client``  — ``ShardWorkerClient``

Every public name this module used to define is re-exported here, so
existing imports (``from repro.serving.rpc import ShardWorkerClient,
encode, decode, send_msg, recv_msg …``) keep working unchanged. New
code should import from :mod:`repro.serving.transport` directly.
"""

from repro.serving.transport import (  # noqa: F401
    HAVE_MSGPACK, ArenaDead, DeadlineExceeded, FaultSpec, FaultyChannel,
    SegmentSink, ShardUnavailable, ShardWorkerClient,
    ShardWorkerDied, ShardWorkerError, ShmArena, ShmChannel,
    StreamChannel, _Reply, _src_pythonpath, decode, decode_control,
    encode, encode_control, recv_msg, send_msg)
from repro.serving.transport.codec import (  # noqa: F401
    _nd_from_wire, _nd_to_wire)

__all__ = [
    "ArenaDead", "DeadlineExceeded", "FaultSpec", "FaultyChannel",
    "HAVE_MSGPACK", "SegmentSink", "ShardUnavailable",
    "ShardWorkerClient",
    "ShardWorkerDied", "ShardWorkerError", "ShmArena", "ShmChannel",
    "StreamChannel", "decode", "decode_control", "encode",
    "encode_control", "recv_msg", "send_msg",
]
