"""Stage-graph pipeline executor: overlap host mmap gathers with device
scoring across micro-batches.

The paper's tension is that memory-mapped scoring trades RAM for
page-fault latency. Serving a micro-batch strictly serially leaves the
device idle while the OS pages residuals in, and leaves the mmap idle
while kernels run. This module restructures the serving hot path around
*stages*:

* each retrieval method compiles to a :class:`StagePlan` — an ordered
  tuple of typed :class:`Stage` steps (``splade_stage1``,
  ``plaid_probe``, ``host_gather``, ``device_score``, ``fuse_topk``)
  that pass an immutable :class:`CandidateBatch` carrier instead of
  positional arrays threaded through ``multistage.py``;
* :class:`PipelineExecutor` runs host-bound and device-bound stages on
  separate kind-based worker threads connected by queues, with
  ``depth`` bounding the batches in flight, so micro-batch N+1's
  host-bound gather overlaps micro-batch N's device-bound dispatch
  (JAX dispatch and numpy mmap reads both release the GIL);
* :class:`PipelineStats` is the single per-stage instrumentation
  record — wall time, dispatches, queries, queue wait, EWMA service
  time, mmap pages/tokens touched (folded in from ``AccessStats``),
  and the measured host/device *overlap fraction* — surfaced through
  ``RetrievalServer.health()`` and ``benchmarks/bench_latency.py``.

Running a plan synchronously (``StagePlan.run``) and through the
executor are the *same stage functions in the same order*, so
``pipeline_depth=1`` (synchronous) vs ``>=2`` (pipelined) parity is
testable and holds bit-for-bit per method.

This module is a leaf: it imports nothing from ``repro.core`` so the
core retrievers can compile plans against it without cycles.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from types import MappingProxyType
from typing import Any, Callable, Mapping, Optional

import numpy as np

HOST = "host"
DEVICE = "device"

STAGE_KINDS = (HOST, DEVICE)


class PipelineStopped(RuntimeError):
    """Raised into futures whose CandidateBatch was still in flight (or
    still queued) when the executor stopped, and by ``submit`` on a
    stopped executor."""


# ---------------------------------------------------------------------------
# carrier
# ---------------------------------------------------------------------------

_EMPTY_STATE: Mapping[str, Any] = MappingProxyType({})


@dataclasses.dataclass(frozen=True)
class CandidateBatch:
    """Immutable carrier passed between stages.

    Stages never mutate a batch: they return a new instance via
    :meth:`evolve` / :meth:`with_state`, so a half-processed micro-batch
    can sit in a queue while the producing stage moves on to the next
    one without aliasing hazards. ``state`` holds named intermediate
    products (candidate sets, gathered codes/residuals, device scores);
    ``pids``/``scores`` are the final per-query results filled in by the
    terminal ``fuse_topk`` stage.

    ``shard_states`` is the batch's *shard axis*: under a sharded index
    (scatter-gather serving) each fanout stage writes one state mapping
    per shard, read back by the next fanout stage (same shard slot) or
    by a ``merge_topk`` fuse that combines per-shard candidates into
    global results.
    """

    method: str
    k: int
    q_embs: Optional[tuple] = None          # per-query (Lq_i, d) arrays
    term_ids: Optional[tuple] = None        # per-query (Qt_i,) arrays
    term_weights: Optional[tuple] = None
    alphas: Optional[np.ndarray] = None     # (B,) hybrid interpolation
    ctxs: Optional[tuple] = None            # per-query RequestContext
    state: Mapping[str, Any] = _EMPTY_STATE
    shard_states: Optional[tuple] = None    # per-shard state mappings
    pids: Optional[np.ndarray] = None       # (B, k) final, -1 padded
    scores: Optional[np.ndarray] = None     # (B, k) final, desc

    @property
    def n_queries(self) -> int:
        for seq in (self.q_embs, self.term_ids):
            if seq is not None:
                return len(seq)
        return 0

    def evolve(self, **fields) -> "CandidateBatch":
        return dataclasses.replace(self, **fields)

    def with_state(self, **kv) -> "CandidateBatch":
        merged = dict(self.state)
        merged.update(kv)
        return dataclasses.replace(self, state=MappingProxyType(merged))


# ---------------------------------------------------------------------------
# stages and plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One typed step of a plan. ``kind`` declares what the stage binds
    on (``host``: mmap gathers / numpy passes; ``device``: jitted or
    Pallas dispatches) — the executor uses it for worker placement in
    threaded mode, overlap accounting, and AccessStats attribution.

    ``opens_async`` marks a stage whose device dispatch returns *lazy*
    values (the async window opens when the stage ends);
    ``closes_async`` marks the downstream stage whose first host touch
    blocks on those values (the window closes when it starts). The
    single-worker scheduler parks a batch at its ``closes_async`` stage
    while younger batches still have pre-sync stages to run — software
    pipelining that hides device execution behind the next batch's host
    work without any thread (or GIL) contention.

    ``fanout > 0`` declares a *sharded* stage: ``fn`` has the signature
    ``fn(cb, shard) -> Mapping`` and runs once per shard, each
    invocation returning that shard's new state mapping; the executor
    assembles the results into ``cb.shard_states``. With ``pooled``
    (and a plan ``pool``) the per-shard calls run concurrently on
    threads — profitable exactly when the per-shard body releases the
    GIL, i.e. the mmap ``host_gather`` stages (big fancy-index copies
    and page faults overlap; this is the scatter half of scatter-gather
    serving). Device fanout stages leave ``pooled`` off: their
    dispatches are async already — shard i's accelerator crunches while
    shard i+1 is being dispatched — and pushing the GIL-bound Python
    dispatch overhead onto competing threads only serialises it with
    extra context switches."""

    name: str                                  # unique within the plan
    kind: str                                  # HOST | DEVICE
    fn: Callable[..., Any]
    opens_async: bool = False
    closes_async: bool = False
    fanout: int = 0                            # >0: per-shard execution
    pooled: bool = False                       # fanout via the plan pool
    device_dispatches: Optional[int] = None    # declared launches/run

    def __post_init__(self):
        if self.kind not in STAGE_KINDS:
            raise ValueError(f"stage kind {self.kind!r} not in "
                             f"{STAGE_KINDS}")

    @property
    def device_dispatch_count(self) -> int:
        """Device computations this stage launches per execution —
        declared at plan-build time (jitted calls plus eager jnp ops,
        each a separate XLA dispatch), defaulting to 1 for device
        stages and 0 for host stages. This is what makes the fused
        rerank tail's dispatch reduction *visible*: the split tail
        declares 3-4 launches per batch, the fused stage declares 1."""
        if self.device_dispatches is not None:
            return self.device_dispatches
        return 1 if self.kind == DEVICE else 0


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """An ordered stage graph for one retrieval method.

    ``access_stats``, when set (the mmap store's ``AccessStats``), is
    snapshotted around host-kind stages so pages/tokens touched are
    attributed per stage. Under concurrent execution two host stages of
    different in-flight batches can interleave gathers, so per-stage
    page attribution is approximate there; totals stay exact.

    ``pool`` (duck-typed: needs ``.map``) runs the per-shard calls of
    ``fanout`` stages concurrently — a ThreadPoolExecutor sized to the
    shard group in sharded serving; ``None`` degrades to sequential
    per-shard execution (correct, just unoverlapped).
    """

    method: str
    stages: tuple
    access_stats: Any = None   # duck-typed: needs .snapshot() -> dict
    pool: Any = None           # duck-typed: needs .map (fanout stages)

    def stage_names(self) -> tuple:
        return tuple(s.name for s in self.stages)

    def run_stage(self, stage: Stage, cb: CandidateBatch,
                  stats: Optional["PipelineStats"] = None,
                  queue_wait_s: float = 0.0) -> CandidateBatch:
        acc = self.access_stats if stage.kind == HOST else None
        before = acc.snapshot() if acc is not None else None
        if stats is not None:
            if stage.closes_async:
                stats.async_close()
            stats.stage_begin()
        t0 = time.perf_counter()
        try:
            out = self._call_stage(stage, cb)
        finally:
            wall = time.perf_counter() - t0
            if stats is not None:
                stats.stage_end()
        if stats is not None:
            if stage.opens_async:
                stats.async_open()
            pages = tokens = 0
            if before is not None:
                after = acc.snapshot()
                pages = after["pages_touched"] - before["pages_touched"]
                tokens = after["tokens_read"] - before["tokens_read"]
            stats.record(stage.name, stage.kind, wall,
                         queries=cb.n_queries, pages_touched=pages,
                         tokens_read=tokens, queue_wait_s=queue_wait_s,
                         device_dispatches=stage.device_dispatch_count
                         * max(1, stage.fanout))
        return out

    def _call_stage(self, stage: Stage, cb: CandidateBatch):
        """Dispatch one stage: plain stages run ``fn(cb)``; fanout
        stages run ``fn(cb, shard)`` once per shard — on the shard pool
        when available — and assemble the returned mappings into the
        batch's shard axis. A shard that raises fails the whole batch
        (scatter-gather has no partial answers), but only this batch:
        the executor resolves its future with the error and the other
        in-flight batches proceed."""
        if not stage.fanout:
            return stage.fn(cb)
        shards = range(stage.fanout)
        if stage.pooled and self.pool is not None:
            outs = list(self.pool.map(lambda i: stage.fn(cb, i), shards))
        else:
            outs = [stage.fn(cb, i) for i in shards]
        return cb.evolve(shard_states=tuple(outs))

    def run(self, cb: CandidateBatch,
            stats: Optional["PipelineStats"] = None) -> CandidateBatch:
        """Synchronous execution — the ``pipeline_depth=1`` path. Same
        stage functions, same order as the pipelined executor.

        A batch that dies between its ``opens_async`` and
        ``closes_async`` stages (a failed device sync, a shard worker
        crashing under its score RPC) must balance the async window on
        the way out — the executor does this in ``_finish``; here the
        raise path does it — or the shared overlap accounting would
        count "dispatch in flight" forever after one failure."""
        window_open = False
        try:
            for stage in self.stages:
                if stage.closes_async:
                    window_open = False    # run_stage closes it up front
                cb = self.run_stage(stage, cb, stats)
                if stage.opens_async:
                    window_open = True
            return cb
        except BaseException:
            if window_open and stats is not None:
                stats.async_close()
            raise


# ---------------------------------------------------------------------------
# instrumentation: the merged stage_stats + AccessStats record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageRecord:
    kind: str = HOST
    wall_s: float = 0.0
    dispatches: int = 0
    queries: int = 0
    queue_wait_s: float = 0.0
    pages_touched: int = 0
    tokens_read: int = 0
    device_dispatches: int = 0           # declared device launches
    ewma_ms: Optional[float] = None      # EWMA of per-dispatch wall time

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PipelineStats:
    """Thread-safe per-stage instrumentation shared by the synchronous
    path and every stage worker.

    Overlap accounting: workers bracket each stage with
    ``stage_begin``/``stage_end``, and lazy device dispatches open an
    *async window* (``async_open`` when the dispatching stage ends,
    ``async_close`` when the consuming sync stage starts). Time accrues
    to ``overlap_s`` whenever >= 2 stages execute simultaneously
    (threaded overlap) **or** a stage executes while a device dispatch
    is in flight (software pipelining: the device computes on its own
    execution thread while the host runs another batch's stage). The
    *overlap fraction* — overlapped time over any-stage-busy time — is
    the pipeline's win: 0.0 when execution is strictly serial (depth 1
    runs the sync stage immediately after the dispatch), > 0 when
    gathers and device scoring actually ran concurrently.
    """

    def __init__(self, ewma_alpha: float = 0.25):
        self._lock = threading.Lock()
        self._ewma_alpha = ewma_alpha
        self._stages: dict[str, StageRecord] = {}
        self._busy = 0
        self._async = 0
        self._t_mark: Optional[float] = None
        self._busy_any_s = 0.0
        self._overlap_s = 0.0
        self._counters: dict[str, int] = {}

    def reset(self):
        with self._lock:
            self._stages.clear()
            self._busy = 0
            self._async = 0
            self._t_mark = None
            self._busy_any_s = 0.0
            self._overlap_s = 0.0
            self._counters.clear()

    # -- overlap ---------------------------------------------------------
    def _tick(self, now: float):
        if self._t_mark is not None and self._busy > 0:
            dt = now - self._t_mark
            self._busy_any_s += dt
            if self._busy >= 2 or self._async >= 1:
                self._overlap_s += dt
        self._t_mark = now

    def stage_begin(self):
        with self._lock:
            self._tick(time.perf_counter())
            self._busy += 1

    def stage_end(self):
        with self._lock:
            self._tick(time.perf_counter())
            self._busy = max(0, self._busy - 1)

    def async_open(self):
        """A device dispatch went in flight (lazy results outstanding)."""
        with self._lock:
            self._tick(time.perf_counter())
            self._async += 1

    def async_close(self):
        """The consuming stage is about to block on those results."""
        with self._lock:
            self._tick(time.perf_counter())
            self._async = max(0, self._async - 1)

    # -- records ---------------------------------------------------------
    def record(self, name: str, kind: str, wall_s: float, *,
               queries: int = 0, dispatches: int = 1,
               pages_touched: int = 0, tokens_read: int = 0,
               queue_wait_s: float = 0.0, device_dispatches: int = 0):
        with self._lock:
            rec = self._stages.get(name)
            if rec is None:
                rec = self._stages[name] = StageRecord(kind=kind)
            rec.kind = kind
            rec.wall_s += wall_s
            rec.dispatches += dispatches
            rec.queries += queries
            rec.pages_touched += pages_touched
            rec.tokens_read += tokens_read
            rec.queue_wait_s += queue_wait_s
            rec.device_dispatches += device_dispatches
            ms = wall_s * 1e3
            rec.ewma_ms = (ms if rec.ewma_ms is None
                           else self._ewma_alpha * ms
                           + (1 - self._ewma_alpha) * rec.ewma_ms)

    def counter(self, name: str, delta: int = 1):
        """Bump a named monotonic counter (transport bytes, RPC
        dispatches, coalesced-op counts, …); surfaced in
        :meth:`snapshot` under ``"counters"``."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + delta

    @property
    def overlap_fraction(self) -> float:
        with self._lock:
            return (self._overlap_s / self._busy_any_s
                    if self._busy_any_s > 0 else 0.0)

    def snapshot(self) -> dict:
        """Atomic copy: {"stages": {name: record-dict}, "busy_s": ...,
        "overlap_s": ..., "overlap_fraction": ..., "counters": ...}."""
        with self._lock:
            stages = {n: r.as_dict() for n, r in self._stages.items()}
            busy, over = self._busy_any_s, self._overlap_s
            counters = dict(self._counters)
        return {"stages": stages, "busy_s": busy, "overlap_s": over,
                "overlap_fraction": over / busy if busy > 0 else 0.0,
                "counters": counters}


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

class _Job:
    __slots__ = ("cb", "future", "idx", "t_enq", "async_open")

    def __init__(self, cb: CandidateBatch, future: Future, t_enq: float):
        self.cb = cb
        self.future = future
        self.idx = 0                       # next stage to run
        self.t_enq = t_enq
        self.async_open = False            # opened an unclosed async window


WORKER_MODES = ("single", "kind")


class PipelineExecutor:
    """Runs a :class:`StagePlan` with ``depth`` micro-batches in flight.

    ``submit`` feeds the pipeline head and returns a Future resolved at
    the tail with the finished :class:`CandidateBatch`. At most
    ``depth`` batches are admitted: when the pipeline is full,
    ``submit`` *blocks* — producers are backpressured and in-flight
    memory is bounded. ``depth=2`` double-buffers: batch N's device
    scoring executes while batch N+1's host gather runs.

    Two scheduling modes (``workers``):

    * ``"single"`` (default) — one worker thread, software-pipelined:
      it runs every stage, but *parks* a batch at its ``closes_async``
      stage (the device-result sync) while younger batches still have
      pre-sync stages, so the device — whose dispatches are async and
      execute on the runtime's own (GIL-free) threads — crunches batch
      N while the worker gathers batch N+1. Measured on 2-core hosts
      this beats threaded stage workers, whose ms-scale GIL-holding
      numpy sections stall each other harder than the overlap pays.
    * ``"kind"`` — one worker per stage *kind* (host-gather worker +
      device-dispatch worker) connected by queues; worthwhile when host
      stages release the GIL for real work (large mmap fault storms,
      multi-core hosts, hardware accelerators with slow host syncs).
      Kind-based FIFO hand-off cannot deadlock: queue occupancy is
      capped by the admission semaphore.

    ``stop()`` fails still-queued batches with :class:`PipelineStopped`;
    the batch a worker is mid-stage on finishes that stage and then
    fails (or resolves, if it was the last stage) — every submitted
    future resolves or fails, none hang.
    """

    def __init__(self, plan: StagePlan, depth: int = 2,
                 stats: Optional[PipelineStats] = None,
                 name: Optional[str] = None, workers: str = "single"):
        if not plan.stages:
            raise ValueError("empty StagePlan")
        if workers not in WORKER_MODES:
            raise ValueError(f"workers {workers!r} not in {WORKER_MODES}")
        self.plan = plan
        self.depth = max(1, int(depth))
        self.stats = stats
        self.mode = workers
        self.running = True
        self._sem = threading.Semaphore(self.depth)   # admission permits
        self._cond = threading.Condition()
        self._inflight = 0
        self._qlock = threading.Lock()
        self._queued = {st.name: 0 for st in plan.stages}
        label = name or plan.method
        self.workers: list[threading.Thread] = []
        if workers == "single":
            self._intake: queue.Queue = queue.Queue()
            t = threading.Thread(target=self._worker_single,
                                 name=f"pipe-{label}", daemon=True)
            t.start()
            self.workers.append(t)
        else:
            kinds = list(dict.fromkeys(st.kind for st in plan.stages))
            self._queues = {kind: queue.Queue() for kind in kinds}
            for kind in kinds:
                t = threading.Thread(target=self._worker_kind, args=(kind,),
                                     name=f"pipe-{label}-{kind}",
                                     daemon=True)
                t.start()
                self.workers.append(t)

    # -- producer side ---------------------------------------------------
    def submit(self, cb: CandidateBatch) -> Future:
        if not self.running:
            raise PipelineStopped("executor is stopped")
        while not self._sem.acquire(timeout=0.05):   # backpressure
            if not self.running:
                raise PipelineStopped("executor stopped")
        if not self.running:
            self._sem.release()
            raise PipelineStopped("executor stopped")
        fut: Future = Future()
        fut.set_running_or_notify_cancel()   # internal: never cancelled
        job = _Job(cb, fut, time.perf_counter())
        with self._cond:
            self._inflight += 1
        self._mark_queued(job.idx, +1)
        if self.mode == "single":
            self._intake.put(job)
        else:
            self._queues[self.plan.stages[job.idx].kind].put(job)
        if not self.running:
            # raced stop(): its drain may already have passed this queue,
            # so drain again — get_nowait makes each job fail exactly once
            self._fail_queued()
        return fut

    def _mark_queued(self, idx: int, delta: int):
        with self._qlock:
            self._queued[self.plan.stages[idx].name] += delta

    # -- single-worker software pipelining -------------------------------
    def _next_job(self, jobs: list) -> "_Job":
        """Lookahead schedule: advance the oldest batch that is NOT
        parked at its device-result sync; if every admitted batch is
        parked (or there is just one), advance the oldest — by then its
        device results have had the younger batches' host stages to
        complete. Plans without ``closes_async`` stages degrade to plain
        FIFO."""
        for job in jobs:
            if not self.plan.stages[job.idx].closes_async:
                return job
        return jobs[0]

    def _admit(self, jobs: list):
        """Admit available batches. When every admitted batch is parked
        at its device-result sync (and there is admission room), wait a
        moment for fresh work before blocking on a sync: under load the
        producer's next batch arrives within microseconds, and running
        its host stages first keeps the parked batches' device work
        hidden — without this, depth=2 syncs too eagerly and exposes
        the execute it just dispatched."""
        while True:
            if not jobs:
                block, timeout = True, 0.05
            elif (len(jobs) < self.depth
                  and all(self.plan.stages[j.idx].closes_async
                          for j in jobs)):
                block, timeout = True, 0.002
            else:
                block, timeout = False, None
            try:
                jobs.append(self._intake.get(block=block, timeout=timeout))
            except queue.Empty:
                return

    def _worker_single(self):
        jobs: list[_Job] = []
        while True:
            self._admit(jobs)
            if not jobs:
                if not self.running:
                    return
                continue
            if not self.running:
                for job in jobs:
                    self._mark_queued(job.idx, -1)
                    self._finish(job, exc=PipelineStopped(
                        "executor stopped mid-flight"))
                jobs.clear()
                continue
            job = self._next_job(jobs)
            if self._advance(job):
                jobs.remove(job)

    # -- shared stage step -----------------------------------------------
    def _advance(self, job: _Job) -> bool:
        """Run the job's next stage on the calling worker (queued-count,
        queue-wait, and async-window bookkeeping included). Returns True
        when the job left the pipeline (finished or failed); False when
        it advanced to the next stage — already marked queued, but not
        yet handed to a worker queue."""
        stage = self.plan.stages[job.idx]
        self._mark_queued(job.idx, -1)
        wait_s = time.perf_counter() - job.t_enq
        if stage.closes_async:
            job.async_open = False         # run_stage closes the window
        try:
            cb = self.plan.run_stage(stage, job.cb, self.stats,
                                     queue_wait_s=wait_s)
        except Exception as e:
            self._finish(job, exc=e)
            return True
        if stage.opens_async:
            job.async_open = True
        job.idx += 1
        if job.idx == len(self.plan.stages):
            self._finish(job, cb=cb)
            return True
        job.cb = cb
        job.t_enq = time.perf_counter()
        self._mark_queued(job.idx, +1)
        return False

    # -- kind-threaded workers -------------------------------------------
    def _worker_kind(self, kind: str):
        q = self._queues[kind]
        while True:
            try:
                job = q.get(timeout=0.05)
            except queue.Empty:
                if not self.running:
                    return
                continue
            if not self.running:
                self._mark_queued(job.idx, -1)
                self._finish(job, exc=PipelineStopped(
                    "executor stopped before stage "
                    f"{self.plan.stages[job.idx].name!r}"))
                continue
            if not self._advance(job):
                self._queues[self.plan.stages[job.idx].kind].put(job)
                if not self.running:
                    # raced stop(): a worker that outlived the join (a
                    # long mid-stage gather) must not strand the job in
                    # a queue nobody reads — drain-and-fail it now
                    self._fail_queued()

    def _finish(self, job: _Job, cb: Optional[CandidateBatch] = None,
                exc: Optional[BaseException] = None):
        if job.async_open and self.stats is not None:
            # the batch dies between its opens_async and closes_async
            # stages (stage error / shutdown): balance the window so the
            # shared overlap accounting cannot stick at "in flight"
            job.async_open = False
            self.stats.async_close()
        if exc is not None:
            job.future.set_exception(exc)
        else:
            job.future.set_result(cb)
        self._sem.release()
        with self._cond:
            self._inflight -= 1
            self._cond.notify_all()

    # -- lifecycle / introspection --------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until no batches are in flight."""
        with self._cond:
            return self._cond.wait_for(lambda: self._inflight == 0,
                                       timeout)

    def queue_depths(self) -> dict:
        """Batches currently waiting per stage (not executing)."""
        with self._qlock:
            return dict(self._queued)

    def stop(self):
        """Stop workers; every in-flight future resolves (if its last
        stage already ran) or fails with :class:`PipelineStopped`."""
        self.running = False
        for t in self.workers:
            t.join(timeout=5.0)
        self.workers.clear()
        self._fail_queued()

    def _fail_queued(self):
        """Fail whatever still sits in the queues (shared by ``stop``
        and a ``submit`` that raced it; ``get_nowait`` guarantees each
        job is finished exactly once)."""
        leftovers = ([self._intake] if self.mode == "single"
                     else list(self._queues.values()))
        for q in leftovers:
            while True:
                try:
                    job = q.get_nowait()
                except queue.Empty:
                    break
                self._mark_queued(job.idx, -1)
                self._finish(job, exc=PipelineStopped(
                    "executor stopped with the batch still queued"))


def gather_futures(futs: list) -> Future:
    """Aggregate Futures into one resolving with the list of results
    (in order) once all complete, or failing with the first exception."""
    out: Future = Future()
    out.set_running_or_notify_cancel()
    if not futs:
        out.set_result([])
        return out
    remaining = [len(futs)]
    lock = threading.Lock()

    def on_done(_f):
        with lock:
            remaining[0] -= 1
            if remaining[0]:
                return
        for f in futs:
            e = f.exception()
            if e is not None:
                out.set_exception(e)
                return
        out.set_result([f.result() for f in futs])

    for f in futs:
        f.add_done_callback(on_done)
    return out
