"""Poisson load generator + latency aggregation (the paper's Fig 1/2
methodology: QPS sampled from a Poisson process, p95 latency observed
by concurrent clients)."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import Request
from repro.serving.server import RetrievalServer


@dataclasses.dataclass
class LoadResult:
    latencies: np.ndarray
    service_times: np.ndarray
    wall_time: float
    offered_qps: float

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) if len(self.latencies) else float("nan")

    @property
    def p50(self):
        return self.percentile(50)

    @property
    def p95(self):
        return self.percentile(95)

    @property
    def p99(self):
        return self.percentile(99)

    @property
    def achieved_qps(self) -> float:
        return len(self.latencies) / max(self.wall_time, 1e-9)

    def summary(self) -> dict:
        return {"offered_qps": self.offered_qps,
                "achieved_qps": self.achieved_qps,
                "p50": self.p50, "p95": self.p95, "p99": self.p99,
                "mean_service": float(np.mean(self.service_times))
                if len(self.service_times) else float("nan"),
                "n": int(len(self.latencies))}


def run_poisson_load(server: RetrievalServer, requests: list[Request],
                     qps: float, seed: int = 0,
                     time_scale: float = 1.0,
                     burst: int = 1,
                     on_result: Optional[Callable] = None) -> LoadResult:
    """Submit ``requests`` with Poisson(qps) inter-arrival gaps.

    Latency statistics are reported raw (client-observed). ``time_scale``
    > 1 compresses the arrival process for smoke tests where only
    mechanics matter — it distorts queueing, so benchmarks use 1.0 and
    instead choose QPS relative to the measured service rate.

    ``burst`` > 1 submits requests in groups of that size per arrival
    (total rate still ``qps``) — the arrival pattern that lets the
    server's micro-batcher coalesce co-arriving queries.
    """
    rng = np.random.default_rng(seed)
    burst = max(1, burst)
    n_arrivals = -(-len(requests) // burst)
    gaps = rng.exponential(burst / qps, n_arrivals) / time_scale

    futures = []
    t0 = time.perf_counter()
    for i, gap in zip(range(0, len(requests), burst), gaps):
        time.sleep(gap)
        for req in requests[i:i + burst]:
            futures.append(server.submit(req))

    lat, svc = [], []
    for fut in futures:
        res = fut.result(timeout=300)
        lat.append(res.latency)
        svc.append(res.service_time)
        if on_result is not None:
            on_result(res)
    wall = time.perf_counter() - t0
    return LoadResult(latencies=np.asarray(lat),
                      service_times=np.asarray(svc),
                      wall_time=wall, offered_qps=qps)
