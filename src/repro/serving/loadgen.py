"""Load generators + latency aggregation (the paper's Fig 1/2
methodology: QPS sampled from a Poisson process, p50/p95/p99 latency
observed by concurrent clients).

Three arrival disciplines:

* :func:`run_poisson_load` — Poisson arrivals on an absolute schedule,
  optionally bursty (``burst`` co-arriving requests per arrival) and
  time-compressed (``time_scale``) for smoke tests.
* :func:`run_open_loop` — strictly open-loop Poisson arrivals on an
  absolute schedule (``--arrival-rate``): submissions never wait on
  completions, so a saturated server cannot throttle its own offered
  load and queueing shows up in the latency tail — the discipline that
  makes pipeline wins visible at p95/p99, not just in QPS.
* :func:`run_closed_loop` — ``concurrency`` synchronous clients, each
  issuing its next request only after the previous completes
  (throughput self-limits to concurrency/latency).

For availability experiments, :class:`ChaosSchedule` runs a timed
kill/restart choreography on a side thread while a load generator
drives requests — the harness behind the chaos smoke in CI and
``bench_latency.py --chaos-sweep``.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.serving.admission import RequestShed
from repro.serving.engine import Request
from repro.serving.server import RetrievalServer


@dataclasses.dataclass
class LoadResult:
    latencies: np.ndarray
    service_times: np.ndarray
    wall_time: float
    offered_qps: float
    failed: int = 0          # requests that raised (tolerate_failures)
    # sample of the exceptions behind ``failed`` (first 8) — an
    # availability assert that trips should say what actually broke
    errors: list = dataclasses.field(default_factory=list)
    # outcome split: a shed is a measured overload response, never a
    # failure; degraded and cache-hit answers completed and are in the
    # latency arrays but are counted separately so a run's quality mix
    # is visible next to its tail latency
    shed: int = 0
    degraded: int = 0
    cache_hits: int = 0
    # query-identity mix of the submitted trace (``trace_id``, falling
    # back to qid): how much repeat traffic the cache could have seen
    unique_queries: int = 0
    repeat_queries: int = 0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) if len(self.latencies) else float("nan")

    @property
    def p50(self):
        return self.percentile(50)

    @property
    def p95(self):
        return self.percentile(95)

    @property
    def p99(self):
        return self.percentile(99)

    @property
    def achieved_qps(self) -> float:
        return len(self.latencies) / max(self.wall_time, 1e-9)

    def summary(self) -> dict:
        return {"offered_qps": self.offered_qps,
                "achieved_qps": self.achieved_qps,
                "p50": self.p50, "p95": self.p95, "p99": self.p99,
                "mean_service": float(np.mean(self.service_times))
                if len(self.service_times) else float("nan"),
                "n": int(len(self.latencies)),
                "failed": int(self.failed),
                "shed": int(self.shed),
                "degraded": int(self.degraded),
                "cache_hits": int(self.cache_hits),
                "unique_queries": int(self.unique_queries),
                "repeat_queries": int(self.repeat_queries)}


def _trace_counts(requests: list[Request]) -> tuple[int, int]:
    """(unique, repeat) over the submitted trace's query identities."""
    ids = [r.trace_id if r.trace_id is not None else r.qid
           for r in requests]
    unique = len(set(ids))
    return unique, len(ids) - unique


def zipf_trace(n_requests: int, n_unique: int, skew: float = 1.1,
               seed: int = 0) -> np.ndarray:
    """Query indices for a Zipf-skewed trace: request ``i`` asks query
    ``trace[i]`` in ``[0, n_unique)``, with popularity ∝ 1/rank^skew.
    ``skew <= 0`` degenerates to uniform sampling. Ranks are mapped to
    query indices through a seeded permutation so "popular" is not
    correlated with low query ids."""
    rng = np.random.default_rng(seed)
    if skew <= 0:
        return rng.integers(0, n_unique, size=n_requests)
    ranks = np.arange(1, n_unique + 1, dtype=np.float64)
    w = 1.0 / np.power(ranks, skew)
    w /= w.sum()
    picks = rng.choice(n_unique, size=n_requests, p=w)
    perm = rng.permutation(n_unique)
    return perm[picks]


def load_trace(path) -> np.ndarray:
    """Replay trace: one query index per line (blank lines and ``#``
    comments skipped)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.split("#", 1)[0].strip()
            if line:
                out.append(int(line))
    if not out:
        raise ValueError(f"replay trace {path} has no query indices")
    return np.asarray(out, dtype=np.int64)


def run_poisson_load(server: RetrievalServer, requests: list[Request],
                     qps: float, seed: int = 0,
                     time_scale: float = 1.0,
                     burst: int = 1,
                     on_result: Optional[Callable] = None,
                     tolerate_failures: bool = False) -> LoadResult:
    """Submit ``requests`` with Poisson(qps) inter-arrival gaps.

    Latency statistics are reported raw (client-observed). ``time_scale``
    > 1 compresses the arrival process for smoke tests where only
    mechanics matter — it distorts queueing, so benchmarks use 1.0 and
    instead choose QPS relative to the measured service rate.

    ``burst`` > 1 submits requests in groups of that size per arrival
    (total rate still ``qps``) — the arrival pattern that lets the
    server's micro-batcher coalesce co-arriving queries.

    Arrivals follow an **absolute** schedule (cumulative gaps against
    ``t0``), like :func:`run_open_loop`: a relative ``sleep(gap)`` per
    iteration accumulates scheduler lag and submit overhead, so the
    offered rate silently sags under load — the coordinated-omission
    trap. On the absolute schedule a late submitter skips its sleep and
    catches up, keeping offered ≈ requested QPS.
    """
    rng = np.random.default_rng(seed)
    burst = max(1, burst)
    n_arrivals = -(-len(requests) // burst)
    arrivals = np.cumsum(rng.exponential(burst / qps, n_arrivals)
                         / time_scale)
    return _run_scheduled(server, requests, arrivals, burst=burst,
                          offered_qps=qps, on_result=on_result,
                          tolerate_failures=tolerate_failures)


def run_open_loop(server: RetrievalServer, requests: list[Request],
                  arrival_rate: float, seed: int = 0,
                  timeout: float = 300.0) -> LoadResult:
    """Strictly open-loop Poisson arrivals at ``arrival_rate`` QPS.

    Arrival times are drawn up-front (cumulative exponential gaps) and
    each request is submitted at its absolute scheduled instant — the
    submitter sleeps to the schedule and never waits on a result, so a
    slow server cannot slow the offered load down. Under overload the
    queue grows and p95/p99 latency explodes, which is exactly the
    signal the pipelined server is meant to push out to higher rates.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate,
                                         len(requests)))
    return _run_scheduled(server, requests, arrivals, burst=1,
                          offered_qps=arrival_rate, timeout=timeout)


def _run_scheduled(server: RetrievalServer, requests: list[Request],
                   arrivals: np.ndarray, *, burst: int,
                   offered_qps: float, timeout: float = 300.0,
                   on_result: Optional[Callable] = None,
                   tolerate_failures: bool = False) -> LoadResult:
    """Shared submit-on-absolute-schedule loop: ``burst`` requests enter
    at each arrival instant (a late submitter skips its sleep and
    catches up), then every future is drained into a
    :class:`LoadResult`. Both Poisson generators are this loop with
    different schedules — fixes to the discipline land once.

    ``tolerate_failures`` counts failed requests into
    ``LoadResult.failed`` instead of aborting the run on the first
    exception — the discipline for availability experiments (e.g. a
    shard worker crashing and healing mid-load), where the question is
    how many requests a fault cost, not whether one happened."""
    futures = []
    t0 = time.perf_counter()
    for i, t_sched in zip(range(0, len(requests), burst), arrivals):
        delay = t0 + t_sched - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        for req in requests[i:i + burst]:
            futures.append(server.submit(req))
    lat, svc = [], []
    failed = shed = degraded = cache_hits = 0
    errors: list = []
    for fut in futures:
        try:
            res = fut.result(timeout=timeout)
        except RequestShed:
            # admission control rejecting under overload is a measured
            # outcome of the experiment, not a failure to tolerate
            shed += 1
            continue
        except Exception as e:
            if not tolerate_failures:
                raise
            failed += 1
            if len(errors) < 8:      # a diagnosable sample, not a flood
                errors.append(e)
            continue
        lat.append(res.latency)
        svc.append(res.service_time)
        if getattr(res, "degraded", False):
            degraded += 1
        if getattr(res, "cache_hit", False):
            cache_hits += 1
        if on_result is not None:
            on_result(res)
    wall = time.perf_counter() - t0
    unique, repeat = _trace_counts(requests)
    return LoadResult(latencies=np.asarray(lat),
                      service_times=np.asarray(svc),
                      wall_time=wall, offered_qps=offered_qps,
                      failed=failed, errors=errors, shed=shed,
                      degraded=degraded, cache_hits=cache_hits,
                      unique_queries=unique, repeat_queries=repeat)


@dataclasses.dataclass
class ChaosAction:
    """One timed fault: run ``fn`` at ``at_s`` seconds into the
    schedule. ``label`` names the action in ``ChaosSchedule.fired``."""
    at_s: float
    fn: Callable[[], None]
    label: str = ""


class ChaosSchedule:
    """Run a sorted list of :class:`ChaosAction` on a daemon thread,
    against an absolute clock started at :meth:`start` — the fault
    choreography beside a load generator. Action exceptions are
    collected into ``errors`` instead of killing the thread (an
    already-dead victim must not abort the experiment); fired labels
    land in ``fired`` so the test can assert the faults actually
    happened."""

    def __init__(self, actions: list[ChaosAction]):
        self.actions = sorted(actions, key=lambda a: a.at_s)
        self.fired: list[str] = []
        self.errors: list[BaseException] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ChaosSchedule":
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="chaos-schedule")
        self._thread.start()
        return self

    def _run(self):
        t0 = time.perf_counter()
        for a in self.actions:
            delay = t0 + a.at_s - time.perf_counter()
            if delay > 0 and self._stop.wait(delay):
                return
            try:
                a.fn()
                self.fired.append(a.label or getattr(a.fn, "__name__",
                                                     "action"))
            except BaseException as e:    # noqa: BLE001 — collected
                self.errors.append(e)

    def join(self, timeout: Optional[float] = None):
        if self._thread is not None:
            self._thread.join(timeout)

    def stop(self, timeout: float = 5.0):
        """Cancel not-yet-fired actions and join the thread."""
        self._stop.set()
        self.join(timeout)


def kill_shard_replica(group, shard: int, rid: int = 0,
                       sig: int = signal.SIGKILL):
    """SIGKILL the local child process behind one replica of a process
    shard group — the canonical chaos action. Remote replicas have no
    child pid here (the harness that spawned the standalone worker
    kills its own Popen handle); a replica that is already down is a
    no-op."""
    rep = group._replica_sets[shard].replicas[rid]
    cli = rep.client
    pid = cli.pid if cli is not None else None
    if pid is None:
        return
    try:
        os.kill(pid, sig)
    except (ProcessLookupError, PermissionError):
        pass


def run_closed_loop(server: RetrievalServer, requests: list[Request],
                    concurrency: int = 1,
                    timeout: float = 300.0) -> LoadResult:
    """Closed-loop clients: ``concurrency`` threads, each submitting its
    next request only after the previous one completes. Offered load is
    whatever the server sustains — useful as the service-rate probe the
    open-loop sweep is calibrated against."""
    concurrency = max(1, concurrency)
    lock = threading.Lock()
    next_i = [0]
    lat = [None] * len(requests)
    svc = [None] * len(requests)
    errors: list[BaseException] = []
    counts = {"shed": 0, "degraded": 0, "cache_hits": 0}

    def client():
        while True:
            with lock:
                i = next_i[0]
                if i >= len(requests):
                    return
                next_i[0] += 1
            try:
                res = server.submit(requests[i]).result(timeout=timeout)
            except RequestShed:
                with lock:
                    counts["shed"] += 1
                continue
            except Exception as e:
                # record and keep the loop alive: one failed request must
                # not silently kill the client thread and strand the rest
                with lock:
                    errors.append(e)
                continue
            lat[i] = res.latency
            svc[i] = res.service_time
            if getattr(res, "degraded", False):
                with lock:
                    counts["degraded"] += 1
            if getattr(res, "cache_hit", False):
                with lock:
                    counts["cache_hits"] += 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, daemon=True)
               for _ in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    ok_lat = [x for x in lat if x is not None]
    ok_svc = [x for x in svc if x is not None]
    if errors and not ok_lat:
        raise errors[0]
    unique, repeat = _trace_counts(requests)
    return LoadResult(latencies=np.asarray(ok_lat, np.float64),
                      service_times=np.asarray(ok_svc, np.float64),
                      wall_time=wall,
                      offered_qps=len(requests) / max(wall, 1e-9),
                      failed=len(errors), errors=errors[:8],
                      shed=counts["shed"], degraded=counts["degraded"],
                      cache_hits=counts["cache_hits"],
                      unique_queries=unique, repeat_queries=repeat)
