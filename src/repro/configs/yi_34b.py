"""yi-34b [arXiv:2403.04652; hf]: llama-architecture dense 60L,
d_model 7168, 56 q heads / 8 kv heads (GQA), head_dim 128,
d_ff 20480 (SwiGLU), vocab 64000."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as C
from repro.configs.base import ArchDef
from repro.models import transformer as T


def full_cfg() -> T.LMCfg:
    blk = C.gqa_block(7168, 56, 8, 128, 20480, rope_theta=5e6)
    return T.LMCfg(name="yi-34b", d_model=7168, vocab=64000,
                   segments=(((blk,), 60),), remat="full",
                   attn_chunk=1024, dtype=jnp.bfloat16)


def smoke_cfg() -> T.LMCfg:
    blk = C.gqa_block(64, 4, 2, 16, 192)
    return T.LMCfg(name="yi-smoke", d_model=64, vocab=512,
                   segments=(((blk,), 2),), remat="none",
                   attn_chunk=16, dtype=jnp.float32)


ARCH = ArchDef(
    name="yi-34b", family="lm",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg,
    shapes=C.lm_shapes(long_skip_reason=C.FULL_ATTN_SKIP),
    notes="llama-arch dense GQA",
)
