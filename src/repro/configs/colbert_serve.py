"""colbert-serve — the paper's own system as an architecture config.

Encoder: BERT-base-class bidirectional encoder (~110M params) with the
ColBERT 128-d projection head. Index: MS MARCO-scale compressed pool
(8.84M passages, ~592M tokens, 4-bit residuals, 2^17 centroids).

Shapes (the serving workloads the paper evaluates):
  * train_contrastive — in-batch-negative ColBERT training (the
    end-to-end driver scale: ~110M model)
  * encode_corpus     — bulk document encoding (index build stage)
  * serve_rerank      — the paper's Rerank/Hybrid path: exact scoring
    of SPLADE's top-200 per query from the compressed pool
  * serve_plaid       — full PLAID stages 1-4 (in-memory baseline)
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchDef, ShapeDef
from repro.models import encoder as E
from repro.models.colbert import ColBERTCfg


@dataclasses.dataclass(frozen=True)
class ServeIndexCfg:
    n_docs: int = 8_841_823          # MS MARCO passage count
    avg_doclen: int = 67
    doc_maxlen: int = 180
    query_maxlen: int = 32
    dim: int = 128
    nbits: int = 4
    n_centroids: int = 131_072       # 2^17 (~16·sqrt(120·N) heuristic)
    ivf_pad: int = 32

    @property
    def n_tokens(self) -> int:
        raw = self.n_docs * self.avg_doclen
        return -(-raw // 512) * 512     # pad: pool rows shard 16/512-way

    @property
    def packed_dim(self) -> int:
        return self.dim * self.nbits // 8


@dataclasses.dataclass(frozen=True)
class ColbertServeCfg:
    colbert: ColBERTCfg
    index: ServeIndexCfg


def full_cfg() -> ColbertServeCfg:
    # vocab padded 30522 → 30720 so the (V, D) embedding shards 16-way
    enc = E.EncoderCfg(name="bert-base", vocab=30720, d_model=768,
                       n_layers=12, n_heads=12, d_ff=3072, max_len=512)
    return ColbertServeCfg(
        colbert=ColBERTCfg(encoder=enc, dim=128, query_maxlen=32,
                           doc_maxlen=180),
        index=ServeIndexCfg())


def smoke_cfg() -> ColbertServeCfg:
    enc = E.EncoderCfg(name="bert-smoke", vocab=512, d_model=64,
                       n_layers=2, n_heads=4, d_ff=128, max_len=64)
    return ColbertServeCfg(
        colbert=ColBERTCfg(encoder=enc, dim=32, query_maxlen=8,
                           doc_maxlen=24),
        index=ServeIndexCfg(n_docs=512, avg_doclen=16, doc_maxlen=24,
                            query_maxlen=8, dim=32, n_centroids=64,
                            ivf_pad=16))


SHAPES = {
    "train_contrastive": ShapeDef("train", {"batch": 512}),
    "encode_corpus": ShapeDef("serve", {"batch": 4096}),
    "serve_rerank": ShapeDef("serve", {"batch": 32, "first_k": 200}),
    "serve_plaid": ShapeDef("serve", {"batch": 32, "nprobe": 4,
                                      "candidate_cap": 4096, "ndocs": 256}),
}

ARCH = ArchDef(
    name="colbert-serve", family="retrieval",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="the paper's system: memory-mapped multi-stage late interaction",
)
