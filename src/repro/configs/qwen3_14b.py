"""qwen3-14b [hf:Qwen/Qwen3-8B family; hf]: dense 40L, d_model 5120,
40 q heads / 8 kv heads (GQA) with per-head qk-norm, head_dim 128,
d_ff 17408 (SwiGLU), vocab 151936, RoPE theta 1e6."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as C
from repro.configs.base import ArchDef
from repro.models import transformer as T


def full_cfg() -> T.LMCfg:
    blk = C.gqa_block(5120, 40, 8, 128, 17408, qk_norm=True,
                      rope_theta=1e6)
    return T.LMCfg(name="qwen3-14b", d_model=5120, vocab=151936,
                   segments=(((blk,), 40),), remat="full",
                   attn_chunk=1024, dtype=jnp.bfloat16)


def smoke_cfg() -> T.LMCfg:
    blk = C.gqa_block(64, 4, 2, 16, 128, qk_norm=True)
    return T.LMCfg(name="qwen3-smoke", d_model=64, vocab=512,
                   segments=(((blk,), 2),), remat="none",
                   attn_chunk=16, dtype=jnp.float32)


ARCH = ArchDef(
    name="qwen3-14b", family="lm",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg,
    shapes=C.lm_shapes(long_skip_reason=C.FULL_ATTN_SKIP),
    notes="dense GQA with qk_norm",
)
