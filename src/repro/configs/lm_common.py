"""Shared builders for the LM-family architecture configs."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ShapeDef
from repro.models import layers as L
from repro.models import transformer as T


def gqa_block(d_model, n_heads, kv_heads, head_dim, d_ff, *,
              qkv_bias=False, qk_norm=False, window=0, use_rope=True,
              rope_theta=1e4) -> T.BlockCfg:
    return T.BlockCfg(
        attn_kind="gqa", ffn_kind="dense", d_ff=d_ff,
        attn=L.AttnCfg(d_model=d_model, n_heads=n_heads, kv_heads=kv_heads,
                       head_dim=head_dim, qkv_bias=qkv_bias, qk_norm=qk_norm,
                       window=window, use_rope=use_rope,
                       rope_theta=rope_theta))


def gqa_moe_block(d_model, n_heads, kv_heads, head_dim, moe: L.MoECfg, *,
                  window=0, use_rope=True, rope_theta=1e4) -> T.BlockCfg:
    return T.BlockCfg(
        attn_kind="gqa", ffn_kind="moe", moe=moe,
        attn=L.AttnCfg(d_model=d_model, n_heads=n_heads, kv_heads=kv_heads,
                       head_dim=head_dim, window=window, use_rope=use_rope,
                       rope_theta=rope_theta))


def mla_block(mla: L.MLACfg, *, ffn_kind="dense", d_ff=0,
              moe: L.MoECfg | None = None) -> T.BlockCfg:
    return T.BlockCfg(attn_kind="mla", ffn_kind=ffn_kind, mla=mla,
                      d_ff=d_ff, moe=moe)


# The assigned LM shape set (identical across the five LM archs).
def lm_shapes(*, long_skip_reason: str | None) -> dict[str, ShapeDef]:
    return {
        "train_4k": ShapeDef("train", {"seq": 4096, "global_batch": 256}),
        "prefill_32k": ShapeDef("prefill", {"seq": 32768,
                                            "global_batch": 32}),
        "decode_32k": ShapeDef("decode", {"seq": 32768,
                                          "global_batch": 128}),
        "long_500k": ShapeDef("decode", {"seq": 524288, "global_batch": 1},
                              skip=long_skip_reason),
    }


FULL_ATTN_SKIP = ("pure full-attention architecture: O(L^2) attention at "
                  "524k context; assignment rule runs long_500k only for "
                  "sub-quadratic (SSM/hybrid/linear/chunked-local) archs — "
                  "see DESIGN.md §Arch-applicability")

SMOKE_DTYPE = jnp.float32
