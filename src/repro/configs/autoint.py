"""autoint [arXiv:1810.11921; paper]: 39 sparse fields, embed_dim 16,
3 self-attention interacting layers, 2 heads, d_attn 32. Field
vocabularies follow a Criteo-like power-law mix (3×10M hashed heavy
fields down to 1k-row tail fields, ~38M table rows total)."""

from __future__ import annotations

from repro.configs.base import ArchDef, ShapeDef
from repro.models.recsys import embedding as EB
from repro.models.recsys.autoint import AutoIntCfg

VOCABS = tuple([10_000_000] * 3 + [1_000_000] * 7 + [100_000] * 9
               + [10_000] * 10 + [1_000] * 10)     # 39 fields, ~38M rows
ITEM_FIELD = 3          # the candidate-item field for retrieval_cand


def full_cfg() -> AutoIntCfg:
    return AutoIntCfg(fields=EB.FieldSpec(VOCABS), embed_dim=16,
                      n_attn_layers=3, n_heads=2, d_attn=32)


def smoke_cfg() -> AutoIntCfg:
    return AutoIntCfg(fields=EB.FieldSpec(tuple([64] * 8)), embed_dim=8,
                      n_attn_layers=2, n_heads=2, d_attn=16)


SHAPES = {
    "train_batch": ShapeDef("train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve", {"batch": 512}),
    "serve_bulk": ShapeDef("serve", {"batch": 262144}),
    "retrieval_cand": ShapeDef("retrieval",
                               {"batch": 1, "n_candidates": 1_048_576}),
}

ARCH = ArchDef(
    name="autoint", family="recsys",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="self-attn field interaction; packed 38M-row table",
    extra={"item_field": ITEM_FIELD},
)
