"""qwen2.5-32b [hf:Qwen/Qwen2.5 family; hf]: dense 64L, d_model 5120,
40 q heads / 8 kv heads (GQA) with QKV bias, head_dim 128,
d_ff 27648 (SwiGLU), vocab 152064, RoPE theta 1e6."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as C
from repro.configs.base import ArchDef
from repro.models import transformer as T


def full_cfg() -> T.LMCfg:
    blk = C.gqa_block(5120, 40, 8, 128, 27648, qkv_bias=True,
                      rope_theta=1e6)
    return T.LMCfg(name="qwen2.5-32b", d_model=5120, vocab=152064,
                   segments=(((blk,), 64),), remat="full",
                   attn_chunk=1024, dtype=jnp.bfloat16)


def smoke_cfg() -> T.LMCfg:
    blk = C.gqa_block(64, 4, 2, 16, 160, qkv_bias=True)
    return T.LMCfg(name="qwen2.5-smoke", d_model=64, vocab=512,
                   segments=(((blk,), 2),), remat="none",
                   attn_chunk=16, dtype=jnp.float32)


ARCH = ArchDef(
    name="qwen2.5-32b", family="lm",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg,
    shapes=C.lm_shapes(long_skip_reason=C.FULL_ATTN_SKIP),
    notes="dense GQA with QKV bias",
)
