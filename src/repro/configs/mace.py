"""mace [arXiv:2206.07697; paper]: 2-layer E(3)-equivariant higher-order
message passing, d_hidden 128 channels, l_max 2, correlation order 3,
8 radial Bessel functions.

The assigned shape set spans citation graphs (cora, ogbn-products),
sampled Reddit minibatches, and batched molecules. Citation graphs have
no 3-D geometry — nodes get synthetic unit positions and features enter
through the channel embedding (DESIGN.md §Arch-applicability)."""

from __future__ import annotations

from repro.configs.base import ArchDef, ShapeDef
from repro.models.gnn.mace import MACECfg


def full_cfg() -> MACECfg:          # d_in / n_out are per-shape (dataset)
    return MACECfg(n_layers=2, d_hidden=128, l_max=2, correlation=3,
                   n_rbf=8)


def smoke_cfg() -> MACECfg:
    return MACECfg(n_layers=2, d_hidden=16, l_max=2, correlation=3,
                   n_rbf=4)


# Node counts are padded to multiples of 32 (the widest batch-axis
# product) and edge counts to multiples of 512 (the full mesh) so input
# shards divide evenly; pad nodes are masked, pad edges are 0→0 self
# loops that the zero-length-edge mask eliminates. Raw sizes kept below.
SHAPES = {
    # cora: full-batch node classification (raw 2708 / 10556)
    "full_graph_sm": ShapeDef("train", {
        "n_nodes": 2720, "n_edges": 10752, "d_feat": 1433, "n_classes": 7,
        "readout": "node", "raw_n_nodes": 2708, "raw_n_edges": 10556}),
    # reddit, fanout 15-10 from 1024 seeds → fixed-size padded subgraph
    "minibatch_lg": ShapeDef("train", {
        "n_nodes": 172032, "n_edges": 169984, "d_feat": 602,
        "n_classes": 41, "readout": "node",
        "graph_nodes": 232965, "graph_edges": 114615892,
        "batch_nodes": 1024, "fanout": (15, 10)}),
    # ogbn-products: full-batch large (raw 2449029 / 61859140)
    "ogb_products": ShapeDef("train", {
        "n_nodes": 2449056, "n_edges": 61860352, "d_feat": 100,
        "n_classes": 47, "readout": "node",
        "raw_n_nodes": 2449029, "raw_n_edges": 61859140}),
    # batched small molecules: 128 graphs × 30 nodes / 64 edges
    "molecule": ShapeDef("train", {
        "n_nodes": 3840, "n_edges": 8192, "d_feat": 16, "n_graphs": 128,
        "readout": "graph"}),
}

ARCH = ArchDef(
    name="mace", family="gnn",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="E(3)-ACE equivariant message passing; segment_sum scatter",
)
