"""Beyond-paper optimized cell variants for the hillclimb targets.

Each optimized builder keeps the *same inputs and outputs* as its
baseline cell (dryrun compares like for like) and changes only the
distribution strategy:

* ``colbert-serve × serve_plaid`` / ``serve_rerank`` — owner-compute
  late interaction: MaxSim is max-decomposable over token shards, so
  each 'model' shard scores candidates against its local slice of the
  compressed pool and partial per-query-token maxima combine with a
  tiny ``pmax`` instead of all-gathering candidate token ranges.
* ``sasrec`` / ``bert4rec`` ``× retrieval_cand`` — candidate-bitmap
  owner-compute: scatter a boolean membership flag to the table's row
  owners (one small collective), score locally, merge per-shard top-k.
* ``llama4 × long_500k`` — iRoPE-aware decode: chunked-local layers
  slice only the last ``window`` cache positions; global layers use
  split-S attention (score tensor pinned to the cache's sequence
  sharding so softmax/PV reduce in place).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchDef, CellSpec
from repro.configs.cells import (_data_ways, _flat_axes, _index_sds,
                                 _param_sds, _recsys_batch_sds,
                                 _recsys_module, build_lm_cell)
from repro.distributed import sharding as S
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# colbert-serve: owner-compute late interaction
# ---------------------------------------------------------------------------

def _local_gather_decompress(index, icfg, pids, rows_loc, off, nbits):
    """Per-shard gather+decode of candidate token rows that live in the
    LOCAL pool slice; non-local rows come back masked invalid."""
    from repro.index.residual import unpack_codes
    safe = jnp.clip(pids, 0, icfg.n_docs - 1)
    starts = index["doc_offsets"][safe]                       # global rows
    tok = starts[..., None] + jnp.arange(icfg.doc_maxlen)
    local = tok - off
    in_range = (local >= 0) & (local < rows_loc)
    lidx = jnp.clip(local, 0, rows_loc - 1)
    cids = index["codes"][lidx]
    packed = index["residuals"][lidx]
    codes = unpack_codes(packed, nbits)
    emb = (index["centroids"][cids]
           + index["bucket_weights"][codes.astype(jnp.int32)])
    valid = (in_range
             & (jnp.arange(icfg.doc_maxlen)
                < index["doclens"][safe][..., None])
             & (pids >= 0)[..., None])
    return emb * valid[..., None], valid


def _partial_maxsim(q_emb, emb, valid):
    """(B,Lq,d)×(B,C,Ld,d) → per-shard partial maxima (B, C, Lq)."""
    s = jnp.einsum("bqd,bcld->bcql", q_emb, emb,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, :, None, :], s, -jnp.inf)
    return jnp.max(s, axis=-1)                                # (B, C, Lq)


def _finish_maxsim(partial_max, pids):
    """Combine pmax'd partials → scores (B, C)."""
    per_q = jnp.where(jnp.isfinite(partial_max), partial_max, 0.0)
    scores = jnp.sum(per_q, axis=-1)
    return jnp.where(pids >= 0, scores, -jnp.inf)


def build_plaid_opt(arch: ArchDef, shape_name, mesh, cfg=None, dims=None):
    cfg = cfg or arch.full_cfg()
    sd = arch.shapes[shape_name]
    dims = dims or sd.dims
    icfg = cfg.index
    ba = S.batch_axes(mesh)
    B = dims["batch"]
    nprobe, cap, ndocs = (dims["nprobe"], dims["candidate_cap"],
                          dims["ndocs"])
    index_sds = dict(_index_sds(icfg, mesh))
    index_sds["ivf"] = S.sds((icfg.n_centroids, icfg.ivf_pad), jnp.int32,
                             mesh, P())
    q_sds = S.sds((B, icfg.query_maxlen, icfg.dim), jnp.float32, mesh,
                  P(ba, None, None))
    model_ways = dict(zip(mesh.axis_names,
                          mesh.devices.shape))["model"]
    rows_loc = icfg.n_tokens // model_ways
    in_specs = ({k: P("model") if k == "codes"
                 else P("model", None) if k == "residuals" else P()
                 for k in index_sds}, P(ba, None, None))
    out_specs = (P(ba, None), P(ba, None))

    def shard_fn(index, q_emb):
        # stage 1+2 run replicated across 'model' (identical work, no
        # comm): centroid probe + IVF candidate generation
        midx = jax.lax.axis_index("model")
        off = midx.astype(jnp.int64) * rows_loc
        sc = jnp.einsum("bqd,kd->bqk", q_emb, index["centroids"],
                        preferred_element_type=jnp.float32)
        _, cids = jax.lax.top_k(sc, nprobe)

        def gen(cid):
            cand = index["ivf"][cid.reshape(-1)].reshape(-1)
            return jnp.unique(cand, size=cap, fill_value=-1)

        cand = jax.vmap(gen)(cids)                            # (B, cap)

        # stage 3: approx scoring from LOCAL codes only, pmax-combined
        safe = jnp.clip(cand, 0, icfg.n_docs - 1)
        starts = index["doc_offsets"][safe]
        tok = starts[..., None] + jnp.arange(icfg.doc_maxlen)
        local = tok - off
        in_range = (local >= 0) & (local < rows_loc)
        codes = index["codes"][jnp.clip(local, 0, rows_loc - 1)]
        valid = (in_range
                 & (jnp.arange(icfg.doc_maxlen)
                    < index["doclens"][safe][..., None])
                 & (cand >= 0)[..., None])                    # (B,cap,Ld)

        def approx_one(scb, cb, vb):
            s = scb[:, cb]                                    # (Lq,cap,Ld)
            s = jnp.where(vb[None], s, -jnp.inf)
            return jnp.max(s, axis=-1)                        # (Lq, cap)

        part = jax.vmap(approx_one)(sc, codes, valid)         # (B,Lq,cap)
        part = jax.lax.pmax(part, "model")
        per_q = jnp.where(jnp.isfinite(part), part, 0.0)
        approx = jnp.sum(per_q, axis=1)                       # (B, cap)
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        _, keep = jax.lax.top_k(approx, ndocs)
        pids = jnp.take_along_axis(cand, keep, axis=1)        # (B, ndocs)

        # stage 4: exact scoring from LOCAL residuals, pmax-combined
        emb, val = _local_gather_decompress(index, icfg, pids, rows_loc,
                                            off, icfg.nbits)
        part = _partial_maxsim(q_emb, emb, val)               # (B,ndocs,Lq)
        part = jax.lax.pmax(part, "model")
        exact = _finish_maxsim(part, pids)
        top, idx = jax.lax.top_k(exact, min(100, ndocs))
        return jnp.take_along_axis(pids, idx, axis=1), top

    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)

    def plaid_step(index, q_emb):
        return fn(index, q_emb)

    return CellSpec(arch.name, shape_name, "serve", plaid_step,
                    (index_sds, q_sds), note="opt: owner-compute maxsim")


def build_rerank_opt(arch: ArchDef, shape_name, mesh, cfg=None, dims=None):
    cfg = cfg or arch.full_cfg()
    sd = arch.shapes[shape_name]
    dims = dims or sd.dims
    icfg = cfg.index
    ba = S.batch_axes(mesh)
    B, K = dims["batch"], dims["first_k"]
    index_sds = _index_sds(icfg, mesh)
    q_sds = S.sds((B, icfg.query_maxlen, icfg.dim), jnp.float32, mesh,
                  P(ba, None, None))
    pids_sds = S.sds((B, K), jnp.int32, mesh, P(ba, None))
    s_sds = S.sds((B, K), jnp.float32, mesh, P(ba, None))
    model_ways = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    rows_loc = icfg.n_tokens // model_ways
    in_specs = ({k: P("model") if k == "codes"
                 else P("model", None) if k == "residuals" else P()
                 for k in index_sds},
                P(ba, None, None), P(ba, None), P(ba, None))

    def shard_fn(index, q_emb, pids, splade_scores):
        from repro.core import hybrid as H
        midx = jax.lax.axis_index("model")
        off = midx.astype(jnp.int64) * rows_loc
        emb, val = _local_gather_decompress(index, icfg, pids, rows_loc,
                                            off, icfg.nbits)
        part = jax.lax.pmax(_partial_maxsim(q_emb, emb, val), "model")
        c_scores = _finish_maxsim(part, pids)
        fused = H.hybrid_scores(splade_scores, c_scores, pids >= 0,
                                alpha=0.3)
        top, idx = jax.lax.top_k(fused, min(100, K))
        return jnp.take_along_axis(pids, idx, axis=1), top

    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(ba, None), P(ba, None)), check_rep=False)
    return CellSpec(arch.name, shape_name, "serve",
                    lambda *a: fn(*a), (index_sds, q_sds, pids_sds, s_sds),
                    note="opt: owner-compute rerank")


# ---------------------------------------------------------------------------
# sasrec / bert4rec retrieval: candidate-bitmap owner-compute
# ---------------------------------------------------------------------------

def build_seqrec_retrieval_opt(arch: ArchDef, shape_name, mesh, cfg=None,
                               dims=None):
    mod = _recsys_module(arch.name)
    cfg = cfg or arch.full_cfg()
    sd = arch.shapes[shape_name]
    dims = dims or sd.dims
    abs_params = jax.eval_shape(lambda: mod.init(jax.random.PRNGKey(0), cfg))
    params_sds, _ = _param_sds(abs_params, mesh, S.RECSYS_RULES)
    # iteration 2: the serving replica of the item table is row-sharded
    # over the WHOLE mesh (512-way), so each device streams only
    # n_items/512 rows — declared as the cell's input sharding
    fa = _flat_axes(mesh)
    params_sds = dict(params_sds)
    params_sds["item_embed"] = S.sds(
        tuple(abs_params["item_embed"].shape),
        abs_params["item_embed"].dtype, mesh, P(fa, None))
    has_bias = arch.name == "bert4rec"
    if has_bias:
        params_sds["out_bias"] = S.sds(
            tuple(abs_params["out_bias"].shape),
            abs_params["out_bias"].dtype, mesh, P(fa))
    batch_sds = _recsys_batch_sds(arch, cfg, sd.kind, dims, mesh)
    ways = 1
    for ax in fa:
        ways *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    rows_loc = cfg.n_items // ways

    def local_score(table_loc, bias_loc, flags_loc, u):
        scores = (table_loc @ u[0]).astype(jnp.float32)       # (rows_loc,)
        if has_bias:
            scores = scores + bias_loc
        scores = jnp.where(flags_loc, scores, -jnp.inf)
        v, i = jax.lax.top_k(scores, min(100, rows_loc))
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        lin = jax.lax.axis_index(fa[0])
        for ax in fa[1:]:
            lin = lin * sizes[ax] + jax.lax.axis_index(ax)
        gidx = i + lin * rows_loc
        av = jax.lax.all_gather(v, fa)                        # (W, 100)
        ai = jax.lax.all_gather(gidx, fa)
        return av.reshape(-1), ai.reshape(-1)

    in_specs = (P(fa, None), P(fa) if has_bias else P(), P(fa), P())
    local = shard_map(local_score, mesh=mesh, in_specs=in_specs,
                      out_specs=(P(), P()), check_rep=False)

    def retrieval_step(params, batch):
        table = params["item_embed"]
        u = mod.user_state(params, cfg, batch["query"]["items"][None],
                           batch["query"]["length"][None],
                           shard_axis=None)                   # (1, d)
        # candidate membership bitmap, scattered to the row owners —
        # the only O(n_candidates) collective in the step
        flags = jnp.zeros((cfg.n_items,), bool)
        flags = flags.at[batch["cand_ids"]].set(True)
        flags = jax.lax.with_sharding_constraint(flags, P(fa))
        bias = (params["out_bias"] if has_bias
                else jnp.zeros((), jnp.float32))
        v, gidx = local(table, bias, flags, u)
        top, idx = jax.lax.top_k(v, min(100, v.shape[0]))
        return gidx[idx].astype(jnp.int32), top

    return CellSpec(arch.name, shape_name, "retrieval", retrieval_step,
                    (params_sds, batch_sds),
                    note="opt: bitmap owner-compute, 512-way table")


# ---------------------------------------------------------------------------
# llama4 long_500k: iRoPE-aware decode
# ---------------------------------------------------------------------------

def build_long_decode_opt(arch: ArchDef, shape_name, mesh, cfg=None,
                          dims=None):
    base = cfg or arch.full_cfg()
    fa = _flat_axes(mesh)
    opt_cfg = dataclasses.replace(
        base, decode_opt=True,
        decode_score_spec=P(None, None, None, fa))
    return build_lm_cell(arch, shape_name, mesh, cfg=opt_cfg, dims=dims)


def build_lm_train_opt(arch: ArchDef, shape_name, mesh, cfg=None,
                       dims=None):
    """Hillclimbed LM training: batch-sharded activations, head-sharded
    attention score panels, flash-style chunk backward, vocab-sharded
    cross-entropy."""
    base = cfg or arch.full_cfg()
    ba = S.batch_axes(mesh)
    # note: seq_shard_axis='model' (sequence parallelism) was tried and
    # REFUTED here — memory −24% but collective +6% and the dominant
    # term rose (EXPERIMENTS.md §Perf, iteration T4.4)
    opt_cfg = dataclasses.replace(
        base, batch_spec=ba, sharded_ce=True, remat_attn_chunks=True,
        moe_dp_slices=_data_ways(mesh))
    return build_lm_cell(arch, shape_name, mesh, cfg=opt_cfg, dims=dims)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

OPT_BUILDERS = {
    ("colbert-serve", "serve_plaid"): build_plaid_opt,
    ("colbert-serve", "serve_rerank"): build_rerank_opt,
    ("sasrec", "retrieval_cand"): build_seqrec_retrieval_opt,
    ("bert4rec", "retrieval_cand"): build_seqrec_retrieval_opt,
    ("llama4-maverick-400b-a17b", "long_500k"): build_long_decode_opt,
    # general LM-train sharding fixes, measured on every LM arch
    ("qwen3-14b", "train_4k"): build_lm_train_opt,
    ("yi-34b", "train_4k"): build_lm_train_opt,
    ("qwen2.5-32b", "train_4k"): build_lm_train_opt,
    ("llama4-maverick-400b-a17b", "train_4k"): build_lm_train_opt,
    ("deepseek-v3-671b", "train_4k"): build_lm_train_opt,
    ("qwen3-14b", "prefill_32k"): build_lm_train_opt,
}


def build_cell_opt(arch: ArchDef, shape_name: str, mesh, *, cfg=None,
                   dims=None) -> Optional[CellSpec]:
    b = OPT_BUILDERS.get((arch.name, shape_name))
    if b is None:
        return None
    return b(arch, shape_name, mesh, cfg=cfg, dims=dims)
