"""sasrec [arXiv:1808.09781; paper]: embed_dim 50, 2 blocks, 1 head,
seq_len 50, causal self-attention; binary CE with one sampled negative
per position. 10M-item vocabulary (padded to 10,000,384 rows for 512-way sharding)."""

from __future__ import annotations

from repro.configs.base import ArchDef, ShapeDef
from repro.models.recsys.sasrec import SASRecCfg


def full_cfg() -> SASRecCfg:
    return SASRecCfg(n_items=10_000_384, embed_dim=50, n_blocks=2,
                     n_heads=1, seq_len=50)


def smoke_cfg() -> SASRecCfg:
    return SASRecCfg(n_items=500, embed_dim=16, n_blocks=2, n_heads=1,
                     seq_len=10)


SHAPES = {
    "train_batch": ShapeDef("train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve", {"batch": 512, "n_cand": 100}),
    "serve_bulk": ShapeDef("serve", {"batch": 262144, "n_cand": 100}),
    "retrieval_cand": ShapeDef("retrieval",
                               {"batch": 1, "n_candidates": 1_048_576}),
}

ARCH = ArchDef(
    name="sasrec", family="recsys",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="causal self-attn seq rec",
)
