"""dien [arXiv:1809.03672; unverified]: embed_dim 18, behaviour
seq_len 100, GRU/AUGRU hidden 108, MLP 200-80 with Dice, auxiliary
loss. 10M items / 10M users / 10k categories."""

from __future__ import annotations

from repro.configs.base import ArchDef, ShapeDef
from repro.models.recsys.dien import DIENCfg


def full_cfg() -> DIENCfg:
    return DIENCfg(n_users=10_000_000, n_items=10_000_000, n_cates=10_000,
                   embed_dim=18, seq_len=100, gru_dim=108,
                   mlp_dims=(200, 80), use_aux_loss=True)


def smoke_cfg() -> DIENCfg:
    return DIENCfg(n_users=100, n_items=200, n_cates=20, embed_dim=6,
                   seq_len=12, gru_dim=16, mlp_dims=(20, 8),
                   use_aux_loss=True)


SHAPES = {
    "train_batch": ShapeDef("train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve", {"batch": 512}),
    "serve_bulk": ShapeDef("serve", {"batch": 262144}),
    "retrieval_cand": ShapeDef("retrieval",
                               {"batch": 1, "n_candidates": 1_048_576}),
}

ARCH = ArchDef(
    name="dien", family="recsys",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="AUGRU interest evolution; aux loss; Dice MLP",
)
