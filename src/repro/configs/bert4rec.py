"""bert4rec [arXiv:1904.06690; paper]: embed_dim 64, 2 blocks, 2 heads,
seq_len 200, bidirectional Cloze training. 10M-item vocabulary (padded to 10,000,384 = 512·19532 rows so the table shards over the full mesh) with
sampled-softmax (256 shared negatives). Encoder-only — all four shapes
are forward scoring (no autoregressive decode)."""

from __future__ import annotations

from repro.configs.base import ArchDef, ShapeDef
from repro.models.recsys.bert4rec import BERT4RecCfg


def full_cfg() -> BERT4RecCfg:
    return BERT4RecCfg(n_items=10_000_384, embed_dim=64, n_blocks=2,
                       n_heads=2, seq_len=200, n_masked=30,
                       n_negatives=256)


def smoke_cfg() -> BERT4RecCfg:
    return BERT4RecCfg(n_items=500, embed_dim=16, n_blocks=2, n_heads=2,
                       seq_len=12, n_masked=3, n_negatives=8)


SHAPES = {
    "train_batch": ShapeDef("train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve", {"batch": 512, "n_cand": 100}),
    "serve_bulk": ShapeDef("serve", {"batch": 262144, "n_cand": 100}),
    "retrieval_cand": ShapeDef("retrieval",
                               {"batch": 1, "n_candidates": 1_048_576}),
}

ARCH = ArchDef(
    name="bert4rec", family="recsys",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg, shapes=SHAPES,
    notes="bidirectional seq rec; sampled-softmax Cloze",
)
