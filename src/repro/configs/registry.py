"""--arch registry: every assigned architecture + the paper's own."""

from __future__ import annotations

from repro.configs import (autoint, bert4rec, colbert_serve,
                           deepseek_v3_671b, dien, llama4_maverick_400b_a17b,
                           mace, qwen2_5_32b, qwen3_14b, sasrec, yi_34b)
from repro.configs.base import ArchDef

_MODULES = [llama4_maverick_400b_a17b, deepseek_v3_671b, qwen3_14b,
            yi_34b, qwen2_5_32b, mace, autoint, dien, bert4rec, sasrec,
            colbert_serve]

ARCHS: dict[str, ArchDef] = {m.ARCH.name: m.ARCH for m in _MODULES}

ASSIGNED = [m.ARCH.name for m in _MODULES[:-1]]   # the 10 assigned archs


def get_arch(name: str) -> ArchDef:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells(*, include_paper: bool = True, include_skipped: bool = False):
    """→ [(arch_name, shape_name, ShapeDef)] in registry order."""
    out = []
    for name, arch in ARCHS.items():
        if not include_paper and arch.family == "retrieval":
            continue
        for shape_name, sd in arch.shapes.items():
            if sd.skip and not include_skipped:
                continue
            out.append((name, shape_name, sd))
    return out
