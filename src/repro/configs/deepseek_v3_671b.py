"""deepseek-v3-671b [arXiv:2412.19437; hf]: 61L, d_model 7168, 128-head
MLA (q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128); first 3
layers dense (d_ff 18432), remaining 58 MoE with 1 shared + 256 routed
experts, top-8, expert d_ff 2048, sigmoid router with aux-loss-free
bias; vocab 129280; multi-token prediction (MTP depth 1).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as C
from repro.configs.base import ArchDef
from repro.models import layers as L
from repro.models import transformer as T

D, V = 7168, 129280


def _cfg(d, n_heads, q_lora, kv_lora, nope, rope, vh, ff_dense, ff_exp,
         n_exp, top_k, n_dense, n_moe, vocab, dtype, use_mtp, remat,
         attn_chunk):
    mla = L.MLACfg(d_model=d, n_heads=n_heads, q_lora_rank=q_lora,
                   kv_lora_rank=kv_lora, qk_nope_head_dim=nope,
                   qk_rope_head_dim=rope, v_head_dim=vh)
    moe = L.MoECfg(d_model=d, d_ff_expert=ff_exp, n_experts=n_exp,
                   top_k=top_k, n_shared=1, d_ff_shared=ff_exp,
                   sigmoid_router=True)
    return T.LMCfg(
        name="deepseek-v3-671b", d_model=d, vocab=vocab,
        segments=(
            ((C.mla_block(mla, ffn_kind="dense", d_ff=ff_dense),), n_dense),
            ((C.mla_block(mla, ffn_kind="moe", moe=moe),), n_moe),
        ),
        use_mtp=use_mtp, remat=remat, attn_chunk=attn_chunk, dtype=dtype)


def full_cfg() -> T.LMCfg:
    return _cfg(D, 128, 1536, 512, 128, 64, 128, 18432, 2048, 256, 8,
                3, 58, V, jnp.bfloat16, True, "full", 1024)


def smoke_cfg() -> T.LMCfg:
    return _cfg(64, 4, 32, 16, 16, 8, 16, 128, 32, 8, 2,
                1, 2, 512, jnp.float32, True, "none", 16)


ARCH = ArchDef(
    name="deepseek-v3-671b", family="lm",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg,
    shapes=C.lm_shapes(long_skip_reason=C.FULL_ATTN_SKIP),
    notes="MLA latent KV, fine-grained MoE 1s+256r top-8, MTP",
    extra={"quantize_opt_state": True},
)
