"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]: 48L, d_model 5120, 40 q heads / 8 kv heads (GQA), dense
d_ff 8192, vocab 202048, MoE 128 routed experts top-1 + 1 shared expert
on alternating layers; iRoPE — 3 of 4 layers use chunked-local (8192)
attention with RoPE, every 4th layer is global with NoPE.

Tagged [moe], early fusion: the multimodal frontend is out of scope for
the LM backbone cells (text tokens in, per the assignment's stub rule).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as C
from repro.configs.base import ArchDef
from repro.models import layers as L
from repro.models import transformer as T

D, H, KV, HD, FF, V = 5120, 40, 8, 128, 8192, 202048
WINDOW = 8192


def _segments(d, h, kv, hd, ff, n_exp, window, n_repeat):
    moe = L.MoECfg(d_model=d, d_ff_expert=ff, n_experts=n_exp, top_k=1,
                   n_shared=1, d_ff_shared=ff)
    blocks = (
        C.gqa_block(d, h, kv, hd, ff, window=window),
        C.gqa_moe_block(d, h, kv, hd, moe, window=window),
        C.gqa_block(d, h, kv, hd, ff, window=window),
        C.gqa_moe_block(d, h, kv, hd, moe, window=0, use_rope=False),
    )
    return ((blocks, n_repeat),)


def full_cfg() -> T.LMCfg:
    return T.LMCfg(
        name="llama4-maverick-400b-a17b", d_model=D, vocab=V,
        segments=_segments(D, H, KV, HD, FF, 128, WINDOW, 12),
        remat="full", attn_chunk=1024, dtype=jnp.bfloat16)


def smoke_cfg() -> T.LMCfg:
    return T.LMCfg(
        name="llama4-smoke", d_model=64, vocab=512,
        segments=_segments(64, 4, 2, 16, 128, 8, 16, 1),
        remat="none", attn_chunk=16, dtype=jnp.float32)


ARCH = ArchDef(
    name="llama4-maverick-400b-a17b", family="lm",
    full_cfg=full_cfg, smoke_cfg=smoke_cfg,
    # chunked-local attention (3/4 of layers) makes long-context decode
    # sub-quadratic → long_500k RUNS for this arch.
    shapes=C.lm_shapes(long_skip_reason=None),
    notes="MoE top-1 interleave, iRoPE chunked-local attention",
    extra={"quantize_opt_state": True},
)
