"""Cell builders: (architecture × input shape × mesh) → a CellSpec
holding the step function and fully-sharded ShapeDtypeStruct inputs.

``jax.jit(cell.fn, donate_argnums=...).lower(*cell.args)`` is the whole
dry-run contract; nothing here allocates device memory for the full
configs (parameters come from ``jax.eval_shape``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchDef, CellSpec
from repro.distributed import sharding as S
from repro.models import transformer as T
from repro.training.optimizer import AdamWCfg, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _param_sds(abs_params, mesh, rules):
    specs = S.make_param_specs(abs_params, rules)
    shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
    return S.attach(abs_params, shard), specs


def _opt_sds(params_sds, pspecs, mesh, opt_cfg):
    opt_abs = jax.eval_shape(
        functools.partial(adamw_init, cfg=opt_cfg), params_sds)
    opt_sh = S.opt_state_shardings(mesh, pspecs, opt_abs)
    return S.attach(opt_abs, opt_sh)


def _flat_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def _data_ways(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return functools.reduce(
        lambda a, b: a * b, (sizes[ax] for ax in S.batch_axes(mesh)), 1)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_has_moe(cfg: T.LMCfg) -> bool:
    return any(b.ffn_kind == "moe" for blocks, _ in cfg.segments
               for b in blocks)


def _lm_opt_cfg(arch: ArchDef) -> AdamWCfg:
    return AdamWCfg(quantize_state=bool(
        arch.extra.get("quantize_opt_state", False)))


def build_lm_cell(arch: ArchDef, shape_name: str, mesh,
                  cfg: Optional[T.LMCfg] = None,
                  dims: Optional[dict] = None) -> CellSpec:
    sd = arch.shapes[shape_name]
    cfg = cfg or arch.full_cfg()
    dims = dims or sd.dims
    B, L = dims["global_batch"], dims["seq"]
    ba = S.batch_axes(mesh)
    ep = "model" if _lm_has_moe(cfg) else None
    dp = "data" if ep else None

    params_sds, pspecs = _param_sds(T.abstract_init(cfg), mesh, S.LM_RULES)

    if sd.kind == "train":
        opt_cfg = _lm_opt_cfg(arch)
        opt_sds = _opt_sds(params_sds, pspecs, mesh, opt_cfg)
        tokens = S.sds((B, L), jnp.int32, mesh, P(ba, None))
        labels = S.sds((B, L), jnp.int32, mesh, P(ba, None))

        def train_step(params, opt_state, batch):
            def loss(p):
                return T.lm_loss(p, cfg, batch["tokens"], batch["labels"],
                                 ep_axis=ep, dp_axis=dp)
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {**metrics, **om}

        return CellSpec(arch.name, shape_name, "train", train_step,
                        (params_sds, opt_sds,
                         {"tokens": tokens, "labels": labels}),
                        donate_argnums=(0, 1))

    if sd.kind == "prefill":
        tokens = S.sds((B, L), jnp.int32, mesh, P(ba, None))

        def prefill_step(params, tokens):
            return T.prefill(params, cfg, tokens)

        return CellSpec(arch.name, shape_name, "prefill", prefill_step,
                        (params_sds, tokens))

    if sd.kind == "decode":
        cache_abs = T.abstract_cache(cfg, B, L)
        cache_sh = S.make_cache_shardings(mesh, cache_abs, batch=B)
        cache_sds = S.attach(cache_abs, cache_sh)
        bspec = P(ba, None) if B >= _data_ways(mesh) else P(None, None)
        token = S.sds((B, 1), jnp.int32, mesh, bspec)
        pos = S.sds((B, 1), jnp.int32, mesh, bspec)

        def decode(params, token, pos, caches):
            return T.decode_step(params, cfg, token, pos, caches)

        return CellSpec(arch.name, shape_name, "decode", decode,
                        (params_sds, token, pos, cache_sds),
                        donate_argnums=(3,))

    raise ValueError(sd.kind)


# ---------------------------------------------------------------------------
# GNN family (MACE)
# ---------------------------------------------------------------------------

def build_gnn_cell(arch: ArchDef, shape_name: str, mesh,
                   cfg=None, dims: Optional[dict] = None) -> CellSpec:
    from repro.models.gnn import mace as M
    sd = arch.shapes[shape_name]
    base = cfg or arch.full_cfg()
    dims = dims or sd.dims
    N, E = dims["n_nodes"], dims["n_edges"]
    readout = dims.get("readout", "node")
    n_out = dims.get("n_classes", 1) if readout == "node" else 1
    mcfg = dataclasses.replace(base, d_in=dims["d_feat"], n_out=n_out,
                               readout=readout)
    ba = S.batch_axes(mesh)
    fa = _flat_axes(mesh)

    abs_params = jax.eval_shape(
        lambda: M.init(jax.random.PRNGKey(0), mcfg))
    params_sds, pspecs = _param_sds(abs_params, mesh, S.GNN_RULES)
    opt_cfg = AdamWCfg()
    opt_sds = _opt_sds(params_sds, pspecs, mesh, opt_cfg)

    batch_sds = {
        "feats": S.sds((N, dims["d_feat"]), jnp.float32, mesh, P(ba, None)),
        "pos": S.sds((N, 3), jnp.float32, mesh, P(ba, None)),
        "senders": S.sds((E,), jnp.int32, mesh, P(fa)),
        "receivers": S.sds((E,), jnp.int32, mesh, P(fa)),
    }
    n_graphs = dims.get("n_graphs", 1)
    if readout == "graph":
        batch_sds["graph_ids"] = S.sds((N,), jnp.int32, mesh, P(ba))
        batch_sds["targets"] = S.sds((n_graphs,), jnp.float32, mesh, P(ba))
    else:
        batch_sds["labels"] = S.sds((N,), jnp.int32, mesh, P(ba))
        batch_sds["label_mask"] = S.sds((N,), jnp.float32, mesh, P(ba))

    def train_step(params, opt_state, batch):
        if readout == "graph":
            batch = dict(batch, n_graphs=n_graphs)
        (l, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, mcfg, batch), has_aux=True)(params)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, {**metrics, **om}

    return CellSpec(arch.name, shape_name, "train", train_step,
                    (params_sds, opt_sds, batch_sds), donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_module(arch_name: str):
    from repro.models.recsys import autoint, bert4rec, dien, sasrec
    return {"autoint": autoint, "dien": dien, "bert4rec": bert4rec,
            "sasrec": sasrec}[arch_name]


def _recsys_batch_sds(arch: ArchDef, cfg, kind: str, dims, mesh):
    ba = S.batch_axes(mesh)
    fa = _flat_axes(mesh)
    B = dims.get("batch", 1)
    i32, f32 = jnp.int32, jnp.float32

    def b(shape, dtype=i32, spec=None):
        return S.sds(shape, dtype, mesh,
                     spec if spec is not None else P(ba, *([None] * (len(shape) - 1))))

    name = arch.name
    if name == "autoint":
        batch = {"fields": b((B, cfg.n_fields))}
        if kind == "train":
            batch["label"] = b((B,), f32)
        if kind == "retrieval":
            return {"user_fields": S.sds((cfg.n_fields,), i32, mesh, P()),
                    "cand_ids": S.sds((dims["n_candidates"],), i32, mesh,
                                      P(fa))}
        return batch
    if name == "dien":
        Lh = cfg.seq_len
        if kind == "retrieval":
            return {
                "query": {
                    "user": S.sds((), i32, mesh, P()),
                    "hist_items": S.sds((Lh,), i32, mesh, P()),
                    "hist_cates": S.sds((Lh,), i32, mesh, P()),
                    "hist_len": S.sds((), i32, mesh, P()),
                },
                "cand_items": S.sds((dims["n_candidates"],), i32, mesh,
                                    P(fa)),
                "cand_cates": S.sds((dims["n_candidates"],), i32, mesh,
                                    P(fa)),
            }
        batch = {"user": b((B,)), "target_item": b((B,)),
                 "target_cate": b((B,)), "hist_items": b((B, Lh)),
                 "hist_cates": b((B, Lh)), "hist_len": b((B,))}
        if kind == "train":
            batch["label"] = b((B,), f32)
        return batch
    if name in ("sasrec", "bert4rec"):
        Lh = cfg.seq_len
        if kind == "retrieval":
            return {
                "query": {"items": S.sds((Lh,), i32, mesh, P()),
                          "length": S.sds((), i32, mesh, P())},
                "cand_ids": S.sds((dims["n_candidates"],), i32, mesh,
                                  P(fa)),
            }
        if kind == "serve":
            return {"items": b((B, Lh)), "lengths": b((B,)),
                    "cand": b((B, dims.get("n_cand", 100)))}
        if name == "sasrec":
            return {"items": b((B, Lh)), "pos_labels": b((B, Lh)),
                    "neg_labels": b((B, Lh)),
                    "valid": b((B, Lh), jnp.bool_)}
        return {"items": b((B, Lh)), "valid": b((B, Lh), jnp.bool_),
                "mask_positions": b((B, cfg.n_masked)),
                "mask_labels": b((B, cfg.n_masked)),
                "negatives": S.sds((cfg.n_negatives,), i32, mesh, P())}
    raise ValueError(name)


def build_recsys_cell(arch: ArchDef, shape_name: str, mesh,
                      cfg=None, dims: Optional[dict] = None) -> CellSpec:
    mod = _recsys_module(arch.name)
    sd = arch.shapes[shape_name]
    cfg = cfg or arch.full_cfg()
    dims = dims or sd.dims
    shard_axis = "model"

    abs_params = jax.eval_shape(lambda: mod.init(jax.random.PRNGKey(0), cfg))
    params_sds, pspecs = _param_sds(abs_params, mesh, S.RECSYS_RULES)
    batch_sds = _recsys_batch_sds(arch, cfg, sd.kind, dims, mesh)

    if sd.kind == "train":
        opt_cfg = AdamWCfg()
        opt_sds = _opt_sds(params_sds, pspecs, mesh, opt_cfg)

        def train_step(params, opt_state, batch):
            (l, metrics), grads = jax.value_and_grad(
                lambda p: mod.loss_fn(p, cfg, batch,
                                      shard_axis=shard_axis),
                has_aux=True)(params)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {**metrics, **om}

        return CellSpec(arch.name, shape_name, "train", train_step,
                        (params_sds, opt_sds, batch_sds),
                        donate_argnums=(0, 1))

    if sd.kind == "serve":
        def serve_step(params, batch):
            return mod.serve_score(params, cfg, batch,
                                   shard_axis=shard_axis)
        return CellSpec(arch.name, shape_name, "serve", serve_step,
                        (params_sds, batch_sds))

    # retrieval: 1 query × n_candidates, multi-stage where the exact
    # model is expensive (the paper's candidate-narrowing transplanted)
    if arch.name == "autoint":
        from repro.models.recsys import embedding as EB
        from repro.models.recsys.retrieval import (TwoStageParams,
                                                   two_stage_retrieve)
        item_field = arch.extra["item_field"]
        offsets = cfg.fields.offsets()

        def retrieval_step(params, batch):
            user_fields, cand_ids = batch["user_fields"], batch["cand_ids"]
            table = params["tables"]["packed"]
            urows = EB.pack_field_ids(cfg.fields, user_fields)
            u = jnp.sum(EB.lookup(table, urows, shard_axis=shard_axis),
                        axis=0)                              # (d,)
            crows = cand_ids + int(offsets[item_field])
            e = EB.lookup(table, crows, shard_axis=shard_axis)  # (N, d)
            coarse = e @ u
            exact = lambda ids: mod.retrieval_scores(
                params, cfg, user_fields, ids, item_field,
                shard_axis=shard_axis)
            return two_stage_retrieve(coarse, exact, cand_ids,
                                      TwoStageParams(first_k=200, k=100))

        return CellSpec(arch.name, shape_name, "retrieval", retrieval_step,
                        (params_sds, batch_sds))

    if arch.name == "dien":
        from repro.core import hybrid as H
        from repro.models.recsys import embedding as EB

        def retrieval_step(params, batch):
            q = batch["query"]
            eh = EB.lookup(params["tables"]["item"], q["hist_items"],
                           shard_axis=shard_axis)            # (L, d)
            m = (jnp.arange(cfg.seq_len) < q["hist_len"])[:, None]
            u = jnp.sum(eh * m, axis=0) / jnp.maximum(q["hist_len"], 1)
            e = EB.lookup(params["tables"]["item"], batch["cand_items"],
                          shard_axis=shard_axis)             # (N, d)
            coarse = e @ u
            s1, keep = jax.lax.top_k(coarse, 200)
            ids = batch["cand_items"][keep]
            cates = batch["cand_cates"][keep]
            s2 = mod.retrieval_scores(params, cfg, q, ids, cates,
                                      shard_axis=shard_axis, chunk=200)
            mask = jnp.ones_like(s1, bool)
            fused = H.hybrid_scores(s1, s2, mask, alpha=0.3)
            top, idx = jax.lax.top_k(fused, 100)
            return ids[idx], top

        return CellSpec(arch.name, shape_name, "retrieval", retrieval_step,
                        (params_sds, batch_sds))

    # sasrec / bert4rec: the exact model IS a dot product — single-stage
    def retrieval_step(params, batch):
        scores = mod.retrieval_scores(params, cfg, batch["query"],
                                      batch["cand_ids"],
                                      shard_axis=shard_axis)
        top, idx = jax.lax.top_k(scores, 100)
        return batch["cand_ids"][idx], top

    return CellSpec(arch.name, shape_name, "retrieval", retrieval_step,
                    (params_sds, batch_sds))


# ---------------------------------------------------------------------------
# colbert-serve (the paper's system)
# ---------------------------------------------------------------------------

def _index_sds(icfg, mesh):
    """Device-resident compressed pool, document-sharded over 'model'."""
    return {
        "codes": S.sds((icfg.n_tokens,), jnp.int32, mesh, P("model")),
        "residuals": S.sds((icfg.n_tokens, icfg.packed_dim), jnp.uint8,
                           mesh, P("model", None)),
        "centroids": S.sds((icfg.n_centroids, icfg.dim), jnp.float32,
                           mesh, P()),
        "bucket_weights": S.sds((2 ** icfg.nbits,), jnp.float32, mesh, P()),
        "doc_offsets": S.sds((icfg.n_docs,), jnp.int32, mesh, P()),
        "doclens": S.sds((icfg.n_docs,), jnp.int32, mesh, P()),
    }


def _gather_decompress(index, icfg, pids):
    """pids (..., C) → decompressed doc embeddings + valid masks."""
    from repro.index.residual import unpack_codes
    safe = jnp.clip(pids, 0, icfg.n_docs - 1)
    starts = index["doc_offsets"][safe]                      # (..., C)
    tok = starts[..., None] + jnp.arange(icfg.doc_maxlen)
    tok = jnp.minimum(tok, icfg.n_tokens - 1)
    cids = index["codes"][tok]                               # (..., C, Ld)
    packed = index["residuals"][tok]                         # (..., C, Ld, pd)
    codes = unpack_codes(packed, icfg.nbits)
    emb = (index["centroids"][cids]
           + index["bucket_weights"][codes.astype(jnp.int32)])
    valid = (jnp.arange(icfg.doc_maxlen) <
             index["doclens"][safe][..., None]) & (pids >= 0)[..., None]
    return emb * valid[..., None], valid


def _batched_maxsim(q_emb, emb, valid):
    """q_emb (B, Lq, d); emb (B, C, Ld, d); valid (B, C, Ld) → (B, C)."""
    s = jnp.einsum("bqd,bcld->bcql", q_emb, emb,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, :, None, :], s, -1e30)
    per_q = jnp.max(s, axis=-1)
    per_q = jnp.where(per_q <= -1e29, 0.0, per_q)
    return jnp.sum(per_q, axis=-1)


def build_retrieval_cell(arch: ArchDef, shape_name: str, mesh,
                         cfg=None, dims: Optional[dict] = None) -> CellSpec:
    from repro.models import colbert as CB
    sd = arch.shapes[shape_name]
    cfg = cfg or arch.full_cfg()
    dims = dims or sd.dims
    ccfg, icfg = cfg.colbert, cfg.index
    ba = S.batch_axes(mesh)
    B = dims["batch"]

    if shape_name == "train_contrastive":
        abs_params = jax.eval_shape(
            lambda: CB.init(jax.random.PRNGKey(0), ccfg))
        params_sds, pspecs = _param_sds(abs_params, mesh, S.LM_RULES)
        opt_cfg = AdamWCfg()
        opt_sds = _opt_sds(params_sds, pspecs, mesh, opt_cfg)
        batch_sds = {
            "q_tokens": S.sds((B, ccfg.query_maxlen), jnp.int32, mesh,
                              P(ba, None)),
            "q_lens": S.sds((B,), jnp.int32, mesh, P(ba)),
            "d_tokens": S.sds((B, ccfg.doc_maxlen), jnp.int32, mesh,
                              P(ba, None)),
            "d_lens": S.sds((B,), jnp.int32, mesh, P(ba)),
        }

        def loss_fn(params, batch):
            q = CB.encode_queries(params, ccfg, batch["q_tokens"],
                                  batch["q_lens"])           # (B, Lq, d)
            d, dv = CB.encode_docs(params, ccfg, batch["d_tokens"],
                                   batch["d_lens"])          # (B, Ld, d)

            # all-pairs MaxSim, scanned over doc chunks to bound memory
            CH = min(64, B)
            dch = d.reshape(B // CH, CH, *d.shape[1:])
            vch = dv.reshape(B // CH, CH, *dv.shape[1:])

            def chunk_scores(_, xs):
                dc, vc = xs                                  # (CH, Ld, d)
                s = jnp.einsum("bqd,cld->bcql", q, dc,
                               preferred_element_type=jnp.float32)
                s = jnp.where(vc[None, :, None, :], s, -1e30)
                m = jnp.max(s, axis=-1)
                m = jnp.where(m <= -1e29, 0.0, m)
                return None, jnp.sum(m, axis=-1)             # (B, CH)

            _, sc = jax.lax.scan(chunk_scores, None, (dch, vch))
            scores = jnp.concatenate(jnp.unstack(sc, axis=0), axis=-1)
            labels = jnp.arange(B)
            logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
            loss = -jnp.mean(jnp.take_along_axis(
                logp, labels[:, None], axis=-1))
            return loss, {"nll": loss}

        def train_step(params, opt_state, batch):
            (l, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {**metrics, **om}

        return CellSpec(arch.name, shape_name, "train", train_step,
                        (params_sds, opt_sds, batch_sds),
                        donate_argnums=(0, 1))

    if shape_name == "encode_corpus":
        abs_params = jax.eval_shape(
            lambda: CB.init(jax.random.PRNGKey(0), ccfg))
        params_sds, _ = _param_sds(abs_params, mesh, S.LM_RULES)
        toks = S.sds((B, ccfg.doc_maxlen), jnp.int32, mesh, P(ba, None))
        lens = S.sds((B,), jnp.int32, mesh, P(ba))

        def encode_step(params, tokens, lengths):
            return CB.encode_docs(params, ccfg, tokens, lengths)

        return CellSpec(arch.name, shape_name, "serve", encode_step,
                        (params_sds, toks, lens))

    if shape_name == "serve_rerank":
        index_sds = _index_sds(icfg, mesh)
        K = dims["first_k"]
        q_emb = S.sds((B, icfg.query_maxlen, icfg.dim), jnp.float32,
                      mesh, P(ba, None, None))
        pids = S.sds((B, K), jnp.int32, mesh, P(ba, None))
        s_scores = S.sds((B, K), jnp.float32, mesh, P(ba, None))

        def rerank_step(index, q_emb, pids, splade_scores):
            from repro.core import hybrid as H
            emb, valid = _gather_decompress(index, icfg, pids)
            c_scores = _batched_maxsim(q_emb, emb, valid)    # (B, K)
            mask = pids >= 0
            fused = H.hybrid_scores(splade_scores, c_scores, mask,
                                    alpha=0.3)
            top, idx = jax.lax.top_k(fused, 100)
            return jnp.take_along_axis(pids, idx, axis=1), top

        return CellSpec(arch.name, shape_name, "serve", rerank_step,
                        (index_sds, q_emb, pids, s_scores))

    if shape_name == "serve_plaid":
        index_sds = dict(_index_sds(icfg, mesh))
        index_sds["ivf"] = S.sds((icfg.n_centroids, icfg.ivf_pad),
                                 jnp.int32, mesh, P())
        nprobe, cap, ndocs = (dims["nprobe"], dims["candidate_cap"],
                              dims["ndocs"])
        q_emb = S.sds((B, icfg.query_maxlen, icfg.dim), jnp.float32,
                      mesh, P(ba, None, None))

        def plaid_step(index, q_emb):
            # stage 1: centroid probe (batched over queries)
            sc = jnp.einsum("bqd,kd->bqk", q_emb, index["centroids"],
                            preferred_element_type=jnp.float32)
            _, cids = jax.lax.top_k(sc, nprobe)              # (B, Lq, np)

            def per_query(scores_c, cid):
                cand = index["ivf"][cid.reshape(-1)].reshape(-1)
                uniq = jnp.unique(cand, size=cap, fill_value=-1)
                safe = jnp.clip(uniq, 0, icfg.n_docs - 1)
                starts = index["doc_offsets"][safe]
                tok = starts[:, None] + jnp.arange(icfg.doc_maxlen)
                tok = jnp.minimum(tok, icfg.n_tokens - 1)
                codes = index["codes"][tok]                  # (cap, Ld)
                valid = (jnp.arange(icfg.doc_maxlen) <
                         index["doclens"][safe][:, None]) & \
                    (uniq >= 0)[:, None]
                s = scores_c[:, codes]                       # (Lq, cap, Ld)
                s = jnp.where(valid[None], s, -1e30)
                approx = jnp.sum(jnp.where(
                    jnp.max(s, -1) <= -1e29, 0.0, jnp.max(s, -1)), axis=0)
                approx = jnp.where(uniq >= 0, approx, -jnp.inf)
                _, keep = jax.lax.top_k(approx, ndocs)
                return uniq[keep]

            final_pids = jax.vmap(per_query)(sc, cids)       # (B, ndocs)
            emb, valid = _gather_decompress(index, icfg, final_pids)
            exact = _batched_maxsim(q_emb, emb, valid)
            exact = jnp.where(final_pids >= 0, exact, -jnp.inf)
            top, idx = jax.lax.top_k(exact, 100)
            return jnp.take_along_axis(final_pids, idx, axis=1), top

        return CellSpec(arch.name, shape_name, "serve", plaid_step,
                        (index_sds, q_emb))

    raise ValueError(shape_name)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_BUILDERS = {"lm": build_lm_cell, "gnn": build_gnn_cell,
             "recsys": build_recsys_cell, "retrieval": build_retrieval_cell}


def build_cell(arch: ArchDef, shape_name: str, mesh, *, cfg=None,
               dims=None) -> CellSpec:
    sd = arch.shapes[shape_name]
    if sd.skip:
        raise ValueError(
            f"cell {arch.name}×{shape_name} is skipped: {sd.skip}")
    return _BUILDERS[arch.family](arch, shape_name, mesh, cfg=cfg,
                                  dims=dims)


def input_specs(arch: ArchDef, shape_name: str, mesh, **kw):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return build_cell(arch, shape_name, mesh, **kw).args
