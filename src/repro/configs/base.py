"""Architecture/shape registry plumbing.

Each assigned architecture module exposes an ``ArchDef``:

* ``full_cfg()``  — the exact published configuration (dry-run only;
  parameters are never materialised, everything goes through
  ``jax.eval_shape``),
* ``smoke_cfg()`` — a reduced same-family configuration that runs a real
  forward/train step on one CPU device (per-arch smoke tests),
* ``shapes``      — the assigned input-shape set; each entry carries the
  step ``kind`` (train | prefill | decode | serve | retrieval) and its
  dimensions. ``skip`` marks assigned-but-inapplicable cells (e.g.
  ``long_500k`` for pure full-attention archs) with the reason recorded
  in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    kind: str                      # train | prefill | decode | serve | retrieval
    dims: dict
    skip: Optional[str] = None     # reason, if this cell is skipped


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                    # lm | gnn | recsys
    full_cfg: Callable[[], Any]
    smoke_cfg: Callable[[], Any]
    shapes: dict[str, ShapeDef]
    notes: str = ""
    # family-specific extras (e.g. recsys model module)
    extra: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CellSpec:
    """One lowered dry-run cell: jit(fn).lower(*args)."""
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                    # ShapeDtypeStructs with shardings attached
    donate_argnums: tuple = ()
    static_argnums: tuple = ()
    note: str = ""
