"""IR quality metrics: MRR@k, Recall@k, Success@k."""

from __future__ import annotations

import numpy as np


def mrr_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int = 10) -> float:
    """ranked_pids: (Q, depth); relevant: per-query set of relevant pids."""
    total = 0.0
    for q in range(len(relevant)):
        for rank, pid in enumerate(ranked_pids[q][:k]):
            if int(pid) in relevant[q]:
                total += 1.0 / (rank + 1)
                break
    return total / max(len(relevant), 1)


def recall_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int) -> float:
    total = 0.0
    for q in range(len(relevant)):
        if not relevant[q]:
            continue
        hits = sum(1 for pid in ranked_pids[q][:k] if int(pid) in relevant[q])
        total += hits / len(relevant[q])
    return total / max(len(relevant), 1)


def success_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int = 5) -> float:
    total = 0.0
    for q in range(len(relevant)):
        if any(int(pid) in relevant[q] for pid in ranked_pids[q][:k]):
            total += 1.0
    return total / max(len(relevant), 1)
