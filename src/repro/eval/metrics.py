"""IR quality metrics: MRR@k, Recall@k, Success@k, nDCG@k."""

from __future__ import annotations

import numpy as np


def mrr_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int = 10) -> float:
    """ranked_pids: (Q, depth); relevant: per-query set of relevant pids."""
    total = 0.0
    for q in range(len(relevant)):
        for rank, pid in enumerate(ranked_pids[q][:k]):
            if int(pid) in relevant[q]:
                total += 1.0 / (rank + 1)
                break
    return total / max(len(relevant), 1)


def recall_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int) -> float:
    total = 0.0
    for q in range(len(relevant)):
        if not relevant[q]:
            continue
        hits = sum(1 for pid in ranked_pids[q][:k] if int(pid) in relevant[q])
        total += hits / len(relevant[q])
    return total / max(len(relevant), 1)


def success_at_k(ranked_pids: np.ndarray, relevant: list[set], k: int = 5) -> float:
    total = 0.0
    for q in range(len(relevant)):
        if any(int(pid) in relevant[q] for pid in ranked_pids[q][:k]):
            total += 1.0
    return total / max(len(relevant), 1)


def ndcg_at_k(ranked_pids: np.ndarray, relevant: list, k: int = 10) -> float:
    """Graded-relevance nDCG@k.

    ``relevant`` is per-query either a set (binary gains) or a dict
    ``pid -> gain``. Queries with no relevant docs contribute 0. DCG
    uses the standard ``gain / log2(rank + 2)`` discount; the ideal DCG
    takes the top-k gains sorted descending."""
    total = 0.0
    for q in range(len(relevant)):
        rel = relevant[q]
        if not rel:
            continue
        gains = (rel if isinstance(rel, dict)
                 else {pid: 1.0 for pid in rel})
        dcg = 0.0
        for rank, pid in enumerate(ranked_pids[q][:k]):
            g = gains.get(int(pid), 0.0)
            if g:
                dcg += g / np.log2(rank + 2)
        ideal = sorted(gains.values(), reverse=True)[:k]
        idcg = sum(g / np.log2(r + 2) for r, g in enumerate(ideal))
        if idcg > 0:
            total += dcg / idcg
    return total / max(len(relevant), 1)
