"""Fault-tolerant checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per leaf (path-encoded
file names) plus ``manifest.json`` (treedef paths, shapes, dtypes,
step). Commit protocol: write into ``step_<N>.tmp`` then atomic
``rename`` — a crash mid-save never corrupts the latest checkpoint, and
``latest_step`` only sees committed directories.

Elastic restore: arrays are saved in *global* (unsharded) form, so a
checkpoint written on mesh A restores onto mesh B (different data-
parallel width, or a single host) by ``jax.device_put`` with the new
shardings — re-sharding is a placement decision, not a data transform.
For multi-host deployments, ``shard_slice_save`` writes only this
host's addressable shards (one file per host) with the same manifest.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"#{p.idx}")
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "|".join(parts)


def save_checkpoint(ckpt_dir, step: int, tree, extra: Optional[dict] = None):
    """Atomic global-array checkpoint. Returns the committed path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step}"
    tmp = ckpt_dir / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": _path_str(path), "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir, step: Optional[int] = None, *,
                    template: Any = None, shardings: Any = None):
    """Load (step, tree). ``template`` supplies the treedef (required —
    manifests store paths for validation, not structure). ``shardings``
    (optional pytree of Sharding) re-shards on load (elastic restore).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())

    if template is None:
        raise ValueError("template pytree required")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(flat_t) != len(manifest["leaves"]):
        raise ValueError(
            f"leaf count mismatch: template {len(flat_t)} vs "
            f"checkpoint {len(manifest['leaves'])}")
    leaves = []
    for (path, tleaf), rec in zip(flat_t, manifest["leaves"]):
        if _path_str(path) != rec["path"]:
            raise ValueError(f"tree mismatch at {_path_str(path)} "
                             f"vs {rec['path']}")
        arr = np.load(d / rec["file"])
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise ValueError(f"shape mismatch at {rec['path']}: "
                             f"{arr.shape} vs {tuple(tleaf.shape)}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return manifest["step"], tree


def prune_checkpoints(ckpt_dir, keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted([int(m.group(1)) for p in ckpt_dir.iterdir()
                    if (m := _STEP_RE.match(p.name))])
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


class BackgroundCheckpointer:
    """Non-blocking saves: the training loop hands off a host copy and
    keeps stepping while the previous save commits (single in-flight
    save; a newer request supersedes a queued one)."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[tuple] = None
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list[int] = []

    def submit(self, step: int, tree, extra: Optional[dict] = None):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        with self._lock:
            self._pending = (step, host_tree, extra)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain,
                                                daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    return
                step, tree, extra = self._pending
                self._pending = None
            save_checkpoint(self.ckpt_dir, step, tree, extra)
            prune_checkpoints(self.ckpt_dir, self.keep)
            self.saved_steps.append(step)

    def wait(self, timeout: float = 60.0):
        t0 = time.time()
        while self._thread is not None and self._thread.is_alive():
            if time.time() - t0 > timeout:
                raise TimeoutError("checkpoint save did not finish")
            time.sleep(0.01)
