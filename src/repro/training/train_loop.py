"""Generic fault-tolerant training loop.

Features exercised by tests/examples on CPU and designed for pods:

* jitted train_step = loss grad → (optional) gradient compression with
  error feedback → AdamW update; microbatch gradient accumulation via
  ``lax.scan`` when ``accum_steps > 1``.
* checkpoint/restart: background atomic saves every ``ckpt_every``
  steps; ``run()`` resumes from the latest checkpoint, and the data
  pipeline is *seekable* (batch index → sample ids) so a restart
  replays the exact stream.
* preemption: SIGTERM (or an injected flag) triggers a synchronous
  final save before exit — the restart test kills mid-run and checks
  bit-exact continuation.
* straggler mitigation: per-step wall times feed a rolling median; a
  step slower than ``straggler_factor``× median is logged and counted —
  on real fleets this signal drives hot-spare swaps; here it feeds the
  serving-style health endpoint and tests.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as ckpt_mod
from repro.training.compression import CompressionCfg, compress_tree, ef_init
from repro.training.optimizer import AdamWCfg, AdamWState, adamw_init, adamw_update


@dataclasses.dataclass
class LoopCfg:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    accum_steps: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0
    compression: CompressionCfg = dataclasses.field(
        default_factory=CompressionCfg)


class SeekableData:
    """Deterministic batch stream: step → batch, replayable after restart."""

    def __init__(self, make_batch: Callable[[int], Any]):
        self.make_batch = make_batch

    def batch(self, step: int):
        return self.make_batch(step)


def make_train_step(loss_fn, opt_cfg: AdamWCfg, loop_cfg: LoopCfg):
    """loss_fn(params, batch) → (loss, metrics). Returns jitted step:
    (params, opt_state, ef, batch) → (params, opt_state, ef, metrics)."""
    use_ef = loop_cfg.compression.kind != "none"

    def step(params, opt_state: AdamWState, ef, batch):
        if loop_cfg.accum_steps > 1:
            # batch leaves have a leading accum axis
            def micro(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zero_g, jnp.zeros((), jnp.float32)), batch)
            n = loop_cfg.accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            metrics = dict(metrics)
            metrics["loss"] = loss

        if use_ef:
            grads, ef = compress_tree(grads, ef, loop_cfg.compression)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, ef, metrics

    return jax.jit(step, donate_argnums=(0, 1, 2))


@dataclasses.dataclass
class LoopReport:
    final_step: int = 0
    losses: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)
    preempted: bool = False
    resumed_from: Optional[int] = None
    step_times: list = dataclasses.field(default_factory=list)


def run(loss_fn, params, data: SeekableData, opt_cfg: AdamWCfg,
        loop_cfg: LoopCfg, *, preempt_flag: Optional[Callable[[], bool]] = None,
        install_sigterm: bool = False) -> tuple[Any, AdamWState, LoopReport]:
    """Run (or resume) training. Returns (params, opt_state, report)."""
    report = LoopReport()
    # the jitted step donates its inputs; copy so the caller's initial
    # params survive (they may seed several runs, e.g. restart tests)
    params = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                    params)
    opt_state = adamw_init(params, opt_cfg)
    ef = ef_init(params) if loop_cfg.compression.kind != "none" else ()
    start_step = 0

    saver = None
    if loop_cfg.ckpt_dir is not None:
        saver = ckpt_mod.BackgroundCheckpointer(loop_cfg.ckpt_dir,
                                                keep=loop_cfg.keep_ckpts)
        last = ckpt_mod.latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            _, state = ckpt_mod.load_checkpoint(
                loop_cfg.ckpt_dir, last,
                template={"params": params, "opt": opt_state, "ef": ef})
            params, opt_state, ef = (state["params"], state["opt"],
                                     state["ef"])
            start_step = last
            report.resumed_from = last

    preempted = {"flag": False}
    if install_sigterm:
        def _handler(signum, frame):
            preempted["flag"] = True
        signal.signal(signal.SIGTERM, _handler)

    train_step = make_train_step(loss_fn, opt_cfg, loop_cfg)
    times: list[float] = []

    step = start_step
    for step in range(start_step, loop_cfg.total_steps):
        if (preempt_flag is not None and preempt_flag()) or preempted["flag"]:
            report.preempted = True
            break
        t0 = time.perf_counter()   # straggler window includes data fetch
        batch = data.batch(step)
        params, opt_state, ef, metrics = train_step(
            params, opt_state, ef, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        report.step_times.append(dt)
        if len(times) >= 5:
            med = float(np.median(times[-50:]))
            if dt > loop_cfg.straggler_factor * med:
                report.straggler_steps.append(step)
        loss = float(metrics["loss"])
        report.losses.append(loss)
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}: {loss}")
        done = step + 1
        if saver is not None and (done % loop_cfg.ckpt_every == 0
                                  or done == loop_cfg.total_steps):
            saver.submit(done, {"params": params, "opt": opt_state, "ef": ef})

    done = step + 1 if not report.preempted else step
    report.final_step = done
    if saver is not None:
        # synchronous final save (preemption path included)
        saver.submit(done, {"params": params, "opt": opt_state, "ef": ef})
        saver.wait()
    return params, opt_state, report
