"""Optimizers for large-scale training.

AdamW with optional int8-quantised first/second moments (block-wise
scales, à la 8-bit Adam / bitsandbytes) — the state-memory trick that
lets the 400B/671B MoEs fit a 256×16 GB pod (see EXPERIMENTS.md). The
quantised state stores, per moment, an int8 payload plus one fp32 scale
per 128-element block of the trailing axis.

All functions are pure pytree→pytree; under pjit the states inherit the
parameter shardings (payloads have the same shape as the params).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Q_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_state: bool = False   # int8 m/v (8-bit Adam)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


# ---------------------------------------------------------------------------
# int8 block quantisation for optimizer state
# ---------------------------------------------------------------------------

def _pad_to_block(x):
    n = x.shape[-1]
    pad = (-n) % Q_BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


def quantize_q8(x):
    """x: (..., n) fp32 → {q: int8 (..., n), scale: fp32 (..., n/B)}."""
    xp, n = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], -1, Q_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return {"q": q.reshape(xp.shape)[..., :n], "scale": scale}


def dequantize_q8(state, orig_shape):
    q, scale = state["q"], state["scale"]
    qp, n = _pad_to_block(q)
    blocks = qp.reshape(*qp.shape[:-1], -1, Q_BLOCK).astype(jnp.float32)
    x = blocks * scale[..., None]
    return x.reshape(qp.shape)[..., :n].reshape(orig_shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, cfg: AdamWCfg) -> AdamWState:
    def zeros_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return quantize_q8(z) if cfg.quantize_state else z
    zl = jax.tree_util.tree_map(zeros_like, params)
    m = zl
    v = jax.tree_util.tree_map(zeros_like, params)
    return AdamWState(count=jnp.zeros((), jnp.int32), m=m, v=v)


def lr_schedule(cfg: AdamWCfg, step):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g_norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g_norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), g_norm


def adamw_update(grads, state: AdamWState, params, cfg: AdamWCfg):
    """→ (new_params, new_state, metrics)."""
    grads, g_norm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32)
        if cfg.quantize_state:
            m = dequantize_q8(m_s, g.shape)
            v = dequantize_q8(v_s, g.shape)
        else:
            m, v = m_s, v_s
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay
                                              * p.astype(jnp.float32))
        if cfg.quantize_state:
            m, v = quantize_q8(m), quantize_q8(v)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = AdamWState(count=count, m=new_m, v=new_v)
    return new_params, new_state, {"lr": lr, "grad_norm": g_norm}


def abstract_adamw_state(params_abstract, cfg: AdamWCfg):
    """Optimizer state as ShapeDtypeStructs — dry-run companion."""
    return jax.eval_shape(partial(adamw_init, cfg=cfg), params_abstract)


# ---------------------------------------------------------------------------
# SGD (baseline / tests)
# ---------------------------------------------------------------------------

def sgd_update(grads, params, lr: float):
    return jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
