"""Gradient compression for cross-pod data parallelism.

Intra-pod gradient reduction rides the fast ICI links; the pod↔pod hop
crosses DCN where bandwidth is ~10× scarcer. Two compressors:

* ``q8``   — int8 block-quantised all-reduce: quantise (per-128 block
             scales), sum int32 payloads + fp32 scales, dequantise.
* ``topk`` — error-feedback top-k sparsification (Stich et al.): send
             the k largest-|g| entries, accumulate the residual locally
             into the next step's gradient.

Both are exposed two ways: ``compress_tree``/EF for use inside a plain
pjit step (the quantisation error then models the lossy sync), and
``q8_psum`` for explicit use inside ``shard_map`` over the 'pod' axis —
the deployment path, demonstrated in tests on a host-device mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import Q_BLOCK, dequantize_q8, quantize_q8


# ---------------------------------------------------------------------------
# int8 all-reduce
# ---------------------------------------------------------------------------

def q8_psum(x, axis_name: str):
    """Quantise → psum(int32) → dequantise, inside shard_map.

    Summing int8 payloads in int32 with per-shard scales requires the
    scales too; we psum payload·scale reconstructions blockwise in fp32
    after an int32 payload sum per *matching* scale is impossible —
    instead each shard contributes its dequantised blocks, but the
    payload that crosses the wire is the int8 tensor + fp32 scales
    (1/4 + 1/128 of fp32 bytes). The collective models that: we psum
    the int8 (as int32) and the scales separately when shards share a
    scale grid (max-scale agreement via pmax first).
    """
    xq = quantize_q8(x)
    # agree on a common scale (max over shards) so int payloads are summable
    common = jax.lax.pmax(xq["scale"], axis_name)
    # requantise against the common scale
    xp = x.astype(jnp.float32)
    pad = (-xp.shape[-1]) % Q_BLOCK
    xpad = jnp.pad(xp, [(0, 0)] * (xp.ndim - 1) + [(0, pad)])
    blocks = xpad.reshape(*xpad.shape[:-1], -1, Q_BLOCK)
    q = jnp.clip(jnp.round(blocks / jnp.maximum(common[..., None], 1e-12)),
                 -127, 127).astype(jnp.int32)
    qsum = jax.lax.psum(q, axis_name)
    out = (qsum.astype(jnp.float32) * common[..., None])
    out = out.reshape(xpad.shape)[..., :xp.shape[-1]]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Error-feedback compressors (pjit-friendly form)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompressionCfg:
    kind: str = "none"            # none | q8 | topk
    topk_frac: float = 0.01


def ef_init(params):
    """Error-feedback residual buffer (zeros, fp32, param-shaped)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8_roundtrip(g):
    return dequantize_q8(quantize_q8(g), g.shape)


def _topk_roundtrip(g, frac: float):
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(g.shape)


def compress_tree(grads, residual, cfg: CompressionCfg):
    """→ (compressed_grads, new_residual). Error feedback: the part of
    (g + r) the compressor drops is carried to the next step."""
    if cfg.kind == "none":
        return grads, residual

    def one(g, r):
        full = g.astype(jnp.float32) + r
        if cfg.kind == "q8":
            sent = _q8_roundtrip(full)
        elif cfg.kind == "topk":
            sent = _topk_roundtrip(full, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return sent.astype(g.dtype), full - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def compression_ratio(cfg: CompressionCfg) -> float:
    """Bytes on the wire vs fp32 all-reduce."""
    if cfg.kind == "q8":
        return (1 + 4 / Q_BLOCK) / 4
    if cfg.kind == "topk":
        return cfg.topk_frac * 2    # value + index
    return 1.0
