"""Hybrid scoring: S = α·N(S_SPLADE) + (1−α)·N(S_ColBERT).

The paper compares three normalisers N and selects per-query z-norm.
All operate per query over the candidate list; padding entries
(score mask False) are excluded from the statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-9


def _masked_stats(x, mask):
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
    mean = jnp.sum(x * m, axis=-1, keepdims=True) / n
    var = jnp.sum(jnp.square(x - mean) * m, axis=-1, keepdims=True) / n
    return mean, jnp.sqrt(var)


def znorm(x, mask):
    """Per-query z-normalisation (the paper's pick)."""
    mean, std = _masked_stats(x, mask)
    return (x - mean) / jnp.maximum(std, _EPS)


def minmax_norm(x, mask):
    big = jnp.where(mask, x, jnp.inf)
    small = jnp.where(mask, x, -jnp.inf)
    lo = jnp.min(big, axis=-1, keepdims=True)
    hi = jnp.max(small, axis=-1, keepdims=True)
    return (x - lo) / jnp.maximum(hi - lo, _EPS)


def linear_scale(x, mask):
    """Map to [0, 1] by dividing by the per-query max magnitude."""
    hi = jnp.max(jnp.where(mask, jnp.abs(x), 0.0), axis=-1, keepdims=True)
    return x / jnp.maximum(hi, _EPS)


NORMALIZERS = {"znorm": znorm, "minmax": minmax_norm, "linear": linear_scale}


@functools.partial(jax.jit, static_argnames=("normalizer",))
def hybrid_scores(splade_scores, colbert_scores, mask, *, alpha,
                  normalizer: str = "znorm"):
    """Both score arrays: (..., C) aligned on the same candidate list.
    α = 0 → pure Rerank (ColBERT order); α = 1 → pure SPLADE.

    ``alpha`` is a scalar, or — for batched (B, C) inputs — a (B,) array
    of per-query interpolation weights.

    Jitted as ONE computation on purpose: fed a *pending* device value
    (the serving pipeline's lazy MaxSim scores), a single async dispatch
    chains on it without blocking, whereas eager op-by-op execution on
    CPU runs small ops inline and would force the sync right here —
    robbing the pipeline of its gather/score overlap."""
    norm = NORMALIZERS[normalizer]
    # padded slots may carry -inf (e.g. rerank scores for -1 pids);
    # zero them before the stats so 0·(-inf)=NaN cannot poison the
    # masked mean/std — they are re-masked to -inf on the way out
    splade_scores = jnp.where(mask, splade_scores, 0.0)
    colbert_scores = jnp.where(mask, colbert_scores, 0.0)
    ns = norm(splade_scores, mask)
    nc = norm(colbert_scores, mask)
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim:
        alpha = alpha[..., None]          # broadcast over the C axis
    out = alpha * ns + (1.0 - alpha) * nc
    return jnp.where(mask, out, -jnp.inf)
