"""ColBERT-serve's multi-stage retrieval pipeline.

Four systems, exactly as the paper's evaluation defines them:

  * ``colbert``  — full PLAID end-to-end (in-memory or MMAP per store mode)
  * ``splade``   — SPLADEv2 w/ PISA-style impact index only
  * ``rerank``   — SPLADE top-``first_k`` → MMAP ColBERT exact rescoring
  * ``hybrid``   — rerank + α-interpolated z-normed score fusion
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid as hybrid_mod
from repro.core.plaid import PLAIDSearcher
from repro.index.splade_device import SpladeDeviceCache
from repro.index.splade_index import SpladeIndex

SPLADE_BACKENDS = ("host", "jax", "pallas")


@dataclasses.dataclass(frozen=True)
class MultiStageParams:
    first_k: int = 200            # SPLADE candidates (paper: top-200)
    k: int = 100                  # final depth
    alpha: float = 0.3            # paper's MS MARCO-tuned value
    normalizer: str = "znorm"
    splade_backend: str = "host"  # stage-1 scorer: host | jax | pallas
    splade_max_df: Optional[int] = None  # padded-postings df cap (None=exact)


class MultiStageRetriever:
    def __init__(self, splade_index: SpladeIndex, searcher: PLAIDSearcher,
                 params: MultiStageParams = MultiStageParams()):
        self.splade = splade_index
        self.searcher = searcher
        self.params = params
        self._splade_device: Optional[SpladeDeviceCache] = None
        self._lock = threading.Lock()
        self.set_splade_backend(params.splade_backend)  # validates
        self.reset_stage_stats()
        if params.splade_backend != "host":
            self.splade_device_cache()    # pay the transfer up front

    # ------------------------------------------------------------------
    # stage-1 backend selection
    # ------------------------------------------------------------------
    def set_splade_backend(self, backend: str):
        if backend not in SPLADE_BACKENDS:
            raise ValueError(f"splade backend {backend!r} not in "
                             f"{SPLADE_BACKENDS}")
        self.splade_backend = backend

    def splade_device_cache(self) -> SpladeDeviceCache:
        """Padded-postings device arrays, materialised once and reused
        across every jax/pallas stage-1 dispatch (locked: concurrent
        server workers must not each pay the host→device transfer)."""
        with self._lock:
            if self._splade_device is None:
                self._splade_device = SpladeDeviceCache(
                    self.splade, max_df=self.params.splade_max_df)
            return self._splade_device

    def _splade_impl(self, backend: str) -> str:
        # the Pallas kernel body runs in interpret mode off-TPU so the
        # selector stays honest (same code path, Mosaic-free execution)
        if backend == "jax":
            return "ref"
        return "pallas" if jax.default_backend() == "tpu" else "interpret"

    def reset_stage_stats(self):
        """Per-stage accounting for benchmarks: stage-1 wall time /
        dispatch count vs everything after (stages 2–4 + fusion)."""
        with self._lock:
            self.stage_stats = {"stage1_s": 0.0, "stage1_dispatches": 0,
                                "stage1_queries": 0, "rest_s": 0.0}

    def _account(self, **deltas):
        with self._lock:
            for key, d in deltas.items():
                self.stage_stats[key] += d

    # ------------------------------------------------------------------
    def run_splade(self, term_ids, term_weights, k: Optional[int] = None,
                   backend: Optional[str] = None):
        pids, scores = self.run_splade_batch(
            [term_ids], [term_weights], k=k, backend=backend)
        return pids[0], scores[0]

    def run_splade_batch(self, term_ids, term_weights,
                         k: Optional[int] = None,
                         backend: Optional[str] = None):
        """Stage 1 for a whole micro-batch in one dispatch.

        term_ids/term_weights: sequences of per-query (Qt_i,) arrays.
        backend 'host' → vectorised CSR pass (`score_batch_host`);
        'jax'/'pallas' → device-resident padded postings (segment-sum /
        block kernel) with a fused per-query top-k."""
        backend = backend or self.splade_backend
        if backend not in SPLADE_BACKENDS:
            raise ValueError(f"splade backend {backend!r} not in "
                             f"{SPLADE_BACKENDS}")
        k = self.params.first_k if k is None else k
        t0 = time.perf_counter()
        if backend == "host":
            out = self.splade.score_batch_host(term_ids, term_weights, k)
        else:
            cache = self.splade_device_cache()
            out = cache.score_topk(term_ids, term_weights, k,
                                   impl=self._splade_impl(backend))
        self._account(stage1_s=time.perf_counter() - t0,
                      stage1_dispatches=1, stage1_queries=len(term_ids))
        return out

    # ------------------------------------------------------------------
    def search(self, method: str, q_emb=None, term_ids=None,
               term_weights=None, alpha: Optional[float] = None,
               k: Optional[int] = None):
        """Returns (pids (k,), scores (k,)), -1 padded, descending."""
        p = self.params
        k = p.k if k is None else k
        alpha = p.alpha if alpha is None else alpha

        if method == "colbert":
            pids, scores, _ = self.searcher.search(q_emb, k=k)
            return pids, scores

        pids, s_scores = self.run_splade(term_ids, term_weights, p.first_k)
        if method == "splade":
            return pids[:k], s_scores[:k]

        t0 = time.perf_counter()
        c_scores = self.searcher.rerank(q_emb, pids)
        mask = pids >= 0
        if method == "rerank":
            final = np.where(mask, c_scores, -np.inf)
        elif method == "hybrid":
            final = np.asarray(hybrid_mod.hybrid_scores(
                jnp.asarray(s_scores), jnp.asarray(c_scores),
                jnp.asarray(mask), alpha=alpha, normalizer=p.normalizer))
        else:
            raise ValueError(method)

        order = np.argsort(-final, kind="stable")[:k]
        out_pids = np.where(final[order] > -np.inf, pids[order], -1)
        self._account(rest_s=time.perf_counter() - t0)
        return out_pids, final[order]

    # ------------------------------------------------------------------
    def search_batch(self, method, q_embs=None, term_ids=None,
                     term_weights=None, alpha=None, k: Optional[int] = None):
        """Cross-query batched retrieval over any of the four methods.

        ``method``: one method name for the whole batch, or a sequence of
        per-query names (mixed batches are grouped and each group runs
        batched). ``q_embs``/``term_ids``/``term_weights``: per-query
        sequences (ragged lengths fine). ``alpha``: scalar, per-query
        sequence, or None (per-params default). Returns
        (pids (B, k), scores (B, k)) matching per-query :meth:`search`.
        """
        p = self.params
        k = p.k if k is None else k
        n = len(q_embs) if q_embs is not None else len(term_ids)

        if not isinstance(method, str):
            methods = list(method)
            if len(set(methods)) > 1:
                return self._search_batch_mixed(methods, q_embs, term_ids,
                                                term_weights, alpha, k)
            method = methods[0]

        alphas = self._alpha_array(alpha, n)

        if method == "colbert":
            pids, scores, _ = self.searcher.search_batch(q_embs, k=k)
            return pids, scores

        # SPLADE first stage: one batched dispatch for the whole
        # micro-batch (host vectorised pass or device-resident kernel)
        pids_b, s_scores = self.run_splade_batch(
            term_ids[:n], term_weights[:n], p.first_k)  # (B, first_k)
        if method == "splade":
            return pids_b[:, :k], s_scores[:, :k]

        t0 = time.perf_counter()
        # batched ColBERT rescoring: one dedup gather + one dispatch
        c_scores = self.searcher.rerank_batch(q_embs, pids_b)
        mask = pids_b >= 0
        if method == "rerank":
            final = np.where(mask, c_scores, -np.inf)
        elif method == "hybrid":
            final = np.asarray(hybrid_mod.hybrid_scores(
                jnp.asarray(s_scores), jnp.asarray(c_scores),
                jnp.asarray(mask), alpha=jnp.asarray(alphas),
                normalizer=p.normalizer))
        else:
            raise ValueError(method)

        order = np.argsort(-final, axis=1, kind="stable")[:, :k]
        sorted_final = np.take_along_axis(final, order, axis=1)
        out_pids = np.where(sorted_final > -np.inf,
                            np.take_along_axis(pids_b, order, axis=1), -1)
        self._account(rest_s=time.perf_counter() - t0)
        return out_pids, sorted_final

    def _alpha_array(self, alpha, n: int) -> np.ndarray:
        if alpha is None:
            return np.full(n, self.params.alpha, np.float32)
        if np.ndim(alpha) == 0:
            return np.full(n, float(alpha), np.float32)
        return np.asarray([self.params.alpha if a is None else float(a)
                           for a in alpha], np.float32)

    def _search_batch_mixed(self, methods, q_embs, term_ids, term_weights,
                            alpha, k: int):
        """Group a mixed-method batch by method, run each group batched,
        and scatter results back into request order."""
        n = len(methods)
        alphas = self._alpha_array(alpha, n)
        out_pids = np.full((n, k), -1, np.int64)
        out_scores = np.full((n, k), -np.inf, np.float32)
        for m in dict.fromkeys(methods):
            idx = [i for i, mi in enumerate(methods) if mi == m]
            pick = (lambda seq: None if seq is None
                    else [seq[i] for i in idx])
            pids, scores = self.search_batch(
                m, q_embs=pick(q_embs), term_ids=pick(term_ids),
                term_weights=pick(term_weights), alpha=alphas[idx], k=k)
            # splade-first groups return min(k, first_k) columns — scatter
            # into the prefix, leaving the (-1, -inf) tail as padding
            w = pids.shape[1]
            out_pids[idx, :w] = pids
            out_scores[idx, :w] = scores
        return out_pids, out_scores
